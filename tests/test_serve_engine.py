"""Continuous-batching engine: slot lifecycle, decode equivalence, and
chunked-prefill carry equivalence (repro.serve)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SamplingParams, get_config
from repro.core.mingru import MinimalistNetwork
from repro.models import build_model
from repro.serve import (DecoderStepModel, MinimalistStepModel, ServeEngine,
                         chunked_prefill)


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("minimalist-lm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def net():
    net = MinimalistNetwork((3, 8, 8, 4))
    params = net.init(jax.random.PRNGKey(1))
    return net, params


def _ref_generate(cfg, model, params, prompt, gen, max_len):
    """Per-request, per-token greedy decode — the definitional server."""
    cache = model.init_cache(1, max_len)
    tok = None
    for t, p in enumerate(prompt):
        logits, cache = model.decode_step(
            params, jnp.asarray([[p]], jnp.int32), cache, jnp.int32(t))
        tok = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
    out = [tok]
    for t in range(gen - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), cache,
            jnp.int32(len(prompt) + t))
        tok = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------

def test_slot_admission_retirement_recycling(lm):
    """More requests than slots, mixed lengths: every request finishes with
    exactly its budget, slots are recycled, and the free mask closes."""
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=3)
    rng = np.random.default_rng(0)
    lens = [(5, 4), (13, 7), (3, 2), (9, 5), (21, 3), (2, 6), (7, 1)]
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=p), max_new_tokens=g)
            for p, g in lens]
    assert eng.free_mask == 0b111 and len(eng.waiting) == 7
    done = eng.run()
    assert len(done) == len(reqs) and all(r.finished for r in reqs)
    for r, (_p, g) in zip(reqs, lens):
        assert len(r.outputs) == g
    # all slots returned to the free pool; nothing left queued or active
    assert eng.free_mask == 0b111
    assert not eng.waiting and not eng.active.any()
    # recycling actually happened: 7 requests through 3 slots
    assert eng.n_emitted == sum(g for _p, g in lens)
    assert eng.utilization > 0.5


def test_engine_matches_sequential_reference(lm):
    """Continuous-batched greedy decode == per-request per-token decode."""
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=3)
    rng = np.random.default_rng(1)
    lens = [(5, 4), (13, 7), (3, 2), (9, 5), (21, 3)]
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=p), max_new_tokens=g)
            for p, g in lens]
    eng.run()
    for r in reqs:
        ref = _ref_generate(cfg, model, params, r.prompt,
                            r.max_new_tokens, 64)
        assert list(r.tokens) == ref


def test_windowed_attention_takes_chunked_fast_path():
    """Sliding-window GQA stacks now take the chunked fast path (wrap-aware
    ring scatter).  Greedy tokens on a random-init bf16 model can flip on
    one-ULP logit ties across different XLA programs, so the token-exact
    check runs against the engine's own numeric path with serialized
    admission (slot isolation), and the prefill numerics are checked
    against full-sequence __call__ at bf16 tolerance."""
    cfg = get_config("gemma3-4b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert model.supports_prefill()      # PR 2: no scanned fallback needed
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=2)
    rng = np.random.default_rng(4)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=p), max_new_tokens=g)
            for p, g in [(5, 3), (9, 4), (3, 2)]]
    eng.run()
    for r in reqs:
        solo = ServeEngine(sm, params, slots=2)
        sr = solo.submit(r.prompt, max_new_tokens=r.max_new_tokens)
        solo.run()
        assert list(r.tokens) == list(sr.tokens)
    # fast-path prefill numerics == full-sequence evaluation (bf16 noise)
    toks = jnp.asarray(reqs[1].prompt[None], jnp.int32)
    last, _cache = chunked_prefill(sm, params, toks, chunk=8)
    full = model(params, toks)[:, -1, :]
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full, np.float32),
                               atol=0.05, rtol=0.05)
    # the scanned per-token fallback stays available as the reference
    scan, _ = chunked_prefill(sm, params, toks, chunk=8, force_scan=True)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(scan, np.float32),
                               atol=0.05, rtol=0.05)


def test_submit_rejects_bad_requests(lm):
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=16)
    eng = ServeEngine(sm, params, slots=1)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros(0, np.int64), max_new_tokens=2)
    # positional stacks must also reject prompts that overflow the cache
    acfg = get_config("smollm-360m-smoke")
    amodel = build_model(acfg)
    asm = DecoderStepModel(amodel, max_len=8)
    aeng = ServeEngine(asm, amodel.init(jax.random.PRNGKey(0)), slots=1)
    with pytest.raises(ValueError, match="max_len"):
        aeng.submit(np.arange(20) % acfg.vocab, max_new_tokens=3)


def test_eos_retires_early(lm):
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=2)
    prompt = np.arange(6) % cfg.vocab
    ref = _ref_generate(cfg, model, params, prompt, 8, 32)
    eos = ref[2]
    req = eng.submit(prompt, max_new_tokens=8, eos_id=int(eos))
    eng.run()
    # generation stops at (and includes) the FIRST eos occurrence
    expect = ref[:ref.index(eos) + 1]
    assert list(req.tokens) == expect and len(expect) < 8


# ---------------------------------------------------------------------------
# bitwise slot isolation (the continuous-batching correctness claim)
# ---------------------------------------------------------------------------

def test_streaming_decode_bitwise_slot_isolation(net):
    """A request's outputs are bit-identical whether it shares the slot
    batch with a churning mix of other requests or runs alone through the
    same slot-shaped program — admissions, retirements and the masked
    state merge never perturb a neighbor."""
    netw, params = net
    rng = np.random.default_rng(2)
    streams = [rng.standard_normal((T, 3)).astype(np.float32)
               for T in (6, 3, 9, 4, 7)]
    eng = ServeEngine(MinimalistStepModel(netw), params, slots=2)
    reqs = [eng.submit(s) for s in streams]
    eng.run()
    for s, r in zip(streams, reqs):
        solo = ServeEngine(MinimalistStepModel(netw), params, slots=2)
        solo_req = solo.submit(s)
        solo.run()
        assert len(r.outputs) == len(s)
        for a, b in zip(r.outputs, solo_req.outputs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_streaming_decode_matches_network_step(net):
    """Engine outputs match sequential per-request MinimalistNetwork.step
    (tight tolerance; bitwise identity across different XLA batch shapes
    is not defined — see test_streaming_decode_bitwise_slot_isolation)."""
    netw, params = net
    rng = np.random.default_rng(3)
    streams = [rng.standard_normal((T, 3)).astype(np.float32)
               for T in (6, 3, 9)]
    eng = ServeEngine(MinimalistStepModel(netw), params, slots=2)
    reqs = [eng.submit(s) for s in streams]
    eng.run()
    for s, r in zip(streams, reqs):
        st = netw.initial_state(1)
        for t in range(len(s)):
            o, st = netw.step(params, jnp.asarray(s[None, t]), st)
            np.testing.assert_allclose(np.asarray(r.outputs[t]),
                                       np.asarray(o[0]), atol=1e-6)


def test_fused_kernel_step_model(net):
    """The fused single-step Pallas path serves the hardware model."""
    netw = MinimalistNetwork((4, 8, 8, 4),
                             qcfg=__import__("repro.core.quant",
                                             fromlist=["QuantConfig"]
                                             ).QuantConfig.hardware())
    params = netw.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    streams = [(rng.random((T, 4)) > 0.5).astype(np.float32)
               for T in (5, 3)]
    eng = ServeEngine(MinimalistStepModel(netw, use_fused_kernel=True),
                      params, slots=2)
    reqs = [eng.submit(s) for s in streams]
    eng.run()
    for s, r in zip(streams, reqs):
        st = netw.initial_state(1)
        for t in range(len(s)):
            o, st = netw.step(params, jnp.asarray(s[None, t]), st)
            np.testing.assert_allclose(np.asarray(r.outputs[t]),
                                       np.asarray(o[0]), atol=2e-5)


# ---------------------------------------------------------------------------
# sampling (per-request stochastic decode through the slot batch)
# ---------------------------------------------------------------------------

def test_sampled_decode_reproducible_across_cobatch(lm):
    """Same (seed, uid, prompt) -> bitwise-identical tokens no matter which
    other requests share the slot batch.  The target is submitted FIRST in
    both runs (uid 0) with a unique prompt length (its admission wave is
    alone, so the same compiled wave program runs both times); neighbors
    differ completely between runs."""
    cfg, model, params = lm
    rng = np.random.default_rng(7)
    target_prompt = rng.integers(0, cfg.vocab, size=11)
    sp = SamplingParams(temperature=0.9, top_k=24, top_p=0.9, seed=123)

    def run(neighbors):
        sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
        eng = ServeEngine(sm, params, slots=3)
        tgt = eng.submit(target_prompt, max_new_tokens=9, sampling=sp)
        for prompt, gen, nsp in neighbors:
            eng.submit(prompt, max_new_tokens=gen, sampling=nsp)
        eng.run()
        return list(tgt.tokens)

    a = run([(rng.integers(0, cfg.vocab, size=5), 4, None),
             (rng.integers(0, cfg.vocab, size=7), 6,
              SamplingParams(temperature=1.3, seed=9))])
    b = run([(rng.integers(0, cfg.vocab, size=3), 8,
              SamplingParams(temperature=0.7, top_k=5, seed=1)),
             (rng.integers(0, cfg.vocab, size=9), 2, None),
             (rng.integers(0, cfg.vocab, size=13), 5, None)])
    assert a == b
    # also reproducible when the target runs completely alone (seed
    # divergence itself is pinned at the unit level in
    # tests/test_serve_sampling.py — this smoke model's random-init
    # logits are too peaked to make engine-level divergence reliable)
    assert a == run([])


def test_mixed_sampled_greedy_traffic_single_program(lm):
    """Greedy and sampled requests with churning knobs all flow through
    ONE compiled decode step (knobs are arrays, not trace constants)."""
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=3)
    rng = np.random.default_rng(8)
    samplings = [None,
                 SamplingParams(temperature=1.0, seed=4),
                 SamplingParams(temperature=0.5, top_k=3, seed=5),
                 SamplingParams(temperature=2.0, top_p=0.5, seed=6),
                 None,
                 SamplingParams(temperature=0.8, top_k=50, top_p=0.95,
                                seed=7)]
    for i, sp in enumerate(samplings):
        eng.submit(rng.integers(0, cfg.vocab, size=3 + 2 * i),
                   max_new_tokens=4 + i, sampling=sp)
    done = eng.run()
    assert len(done) == len(samplings)
    # compile accounting through the metrics surface: the engine's
    # _jit_programs discovery sees the same cache the raw wrapper does
    m = eng.metrics()
    assert m["jit"]["step_compiles"] == 1
    assert m["jit"]["step_compiles"] == sm._jit_step._cache_size()
    # greedy rows through the sampling path == the pure argmax emit
    assert eng.free_mask == 0b111


def test_sampled_greedy_rows_match_pure_greedy(lm):
    """temperature=0 through the sampling pipeline emits exactly the
    tokens of an all-greedy engine run (same program family)."""
    cfg, model, params = lm
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=p) for p in (5, 9, 13)]

    def run(sampling):
        sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
        eng = ServeEngine(sm, params, slots=2)
        reqs = [eng.submit(p, max_new_tokens=6, sampling=sampling)
                for p in prompts]
        eng.run()
        return [list(r.tokens) for r in reqs]

    assert run(None) == run(SamplingParams(temperature=0.0, seed=42))


def test_engine_lifecycle_sampled_and_streaming_interleaved(lm, net):
    """Sampled LM requests (distinct seeds, one eos-retired early) and
    streaming MinimalistNetwork requests run interleaved step-for-step in
    their engines; slots recycle cleanly and per-request outputs are
    isolated (identical to undisturbed runs of the same submissions)."""
    cfg, model, params = lm
    netw, nparams = net
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab, size=p) for p in (5, 8, 11, 6, 9)]
    # pick an eos that the third request actually emits (probe greedily)
    probe = _ref_generate(cfg, model, params, prompts[2], 6, 64)
    eos_len = probe.index(probe[1]) + 1     # first occurrence stops it
    streams = [rng.standard_normal((T, 3)).astype(np.float32)
               for T in (6, 3, 9, 4)]

    def submit_lm(eng):
        return [
            eng.submit(prompts[0], max_new_tokens=7,
                       sampling=SamplingParams(temperature=1.1, seed=1)),
            eng.submit(prompts[1], max_new_tokens=5,
                       sampling=SamplingParams(temperature=0.6, top_k=10,
                                               seed=2)),
            eng.submit(prompts[2], max_new_tokens=6, eos_id=int(probe[1])),
            eng.submit(prompts[3], max_new_tokens=4,
                       sampling=SamplingParams(temperature=0.9, top_p=0.8,
                                               seed=3)),
            eng.submit(prompts[4], max_new_tokens=8,
                       sampling=SamplingParams(temperature=1.4, seed=1)),
        ]

    lm_eng = ServeEngine(DecoderStepModel(model, max_len=64,
                                          prefill_chunk=8), params, slots=2)
    st_eng = ServeEngine(MinimalistStepModel(netw), nparams, slots=2)
    lm_reqs = submit_lm(lm_eng)
    st_reqs = [st_eng.submit(s) for s in streams]
    while (lm_eng.waiting or lm_eng.active.any()
           or st_eng.waiting or st_eng.active.any()):
        if lm_eng.waiting or lm_eng.active.any():
            lm_eng.step()
        if st_eng.waiting or st_eng.active.any():
            st_eng.step()
    # clean lifecycle: everything finished, every slot back in the pool
    assert all(r.finished for r in lm_reqs + st_reqs)
    assert lm_eng.free_mask == 0b11 and st_eng.free_mask == 0b11
    assert not lm_eng.waiting and not st_eng.waiting
    # eos retired request #2 early, budget respected everywhere else
    assert [len(r.outputs) for r in lm_reqs] == [7, 5, eos_len, 4, 8]
    assert eos_len < 6
    assert [len(r.outputs) for r in st_reqs] == [len(s) for s in streams]
    # isolation: an undisturbed identical run reproduces every output
    solo_lm = ServeEngine(DecoderStepModel(model, max_len=64,
                                           prefill_chunk=8), params,
                          slots=2)
    solo_reqs = submit_lm(solo_lm)
    solo_lm.run()
    for r, s in zip(lm_reqs, solo_reqs):
        assert list(r.tokens) == list(s.tokens)
    for s, r in zip(streams, st_reqs):
        solo = ServeEngine(MinimalistStepModel(netw), nparams, slots=2)
        sr = solo.submit(s)
        solo.run()
        for a, b in zip(r.outputs, sr.outputs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_default_sampling_params_not_shared_between_requests(lm):
    """Every default-sampled request owns its OWN SamplingParams instance
    (default_factory) — mutating one request's params (even forcibly,
    through the frozen dataclass) must never leak into another request's
    knobs."""
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=2)
    r1 = eng.submit(np.arange(3) % cfg.vocab, max_new_tokens=1)
    r2 = eng.submit(np.arange(4) % cfg.vocab, max_new_tokens=1)
    assert r1.sampling is not r2.sampling
    from repro.serve.engine import Request
    assert Request(0, np.arange(2)).sampling \
        is not Request(1, np.arange(2)).sampling
    object.__setattr__(r1.sampling, "temperature", 9.9)
    assert r2.sampling.temperature == 0.0
    assert Request(2, np.arange(2)).sampling.temperature == 0.0


def test_uid_collision_beyond_32_bits_regression():
    """Counter keys fold the FULL request uid: uids that differ by 2**31
    (the old ``& 0x7FFFFFFF`` mask period) or by 2**32 (beyond one
    32-bit word) must NOT produce bitwise-identical sampled streams."""
    from repro.serve.engine import Request, _knob_values
    from repro.serve.sampling import KNOB_DTYPES, sample_tokens

    def stream(uid, n=16, V=1024):
        req = Request(uid, np.arange(3),
                      sampling=SamplingParams(temperature=1.0, seed=7))
        kv = _knob_values(req)
        lg = jnp.zeros((1, V))            # flat: draws expose the key
        return [int(sample_tokens(
            lg, *(jnp.asarray([kv[k]], KNOB_DTYPES[k])
                  for k in ("seed", "uid", "uid_hi")),
            jnp.asarray([p], jnp.int32),
            jnp.asarray([1.0], jnp.float32),
            jnp.asarray([0], jnp.int32),
            jnp.asarray([1.0], jnp.float32))[0]) for p in range(n)]

    base = stream(5)
    assert base == stream(5)                      # stable
    assert base != stream(5 + 2**31)              # the pinned collision
    assert base != stream(5 + 2**32)              # folds the high word too


def test_submit_rejects_bad_sampling(lm, net):
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=16)
    eng = ServeEngine(sm, params, slots=1)
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(np.arange(3), max_new_tokens=2,
                   sampling=SamplingParams(temperature=-1.0))
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(np.arange(3), max_new_tokens=2,
                   sampling=SamplingParams(temperature=float("nan")))
    with pytest.raises(ValueError, match="top_p"):
        eng.submit(np.arange(3), max_new_tokens=2,
                   sampling=SamplingParams(top_p=0.0))
    # knob-dtype overflow is rejected at submit, not mid-admission (a
    # uint32/int32 overflow there would leak the allocated slot)
    with pytest.raises(ValueError, match="seed"):
        eng.submit(np.arange(3), max_new_tokens=2,
                   sampling=SamplingParams(temperature=1.0, seed=2**32))
    with pytest.raises(ValueError, match="top_k"):
        eng.submit(np.arange(3), max_new_tokens=2,
                   sampling=SamplingParams(top_k=2**31))
    # top_p above 1 just disables the nucleus filter (documented)
    r = eng.submit(np.arange(3) % cfg.vocab, max_new_tokens=2,
                   sampling=SamplingParams(temperature=1.0, top_p=1.5))
    eng.run()
    assert len(r.outputs) == 2
    netw, nparams = net
    seng = ServeEngine(MinimalistStepModel(netw), nparams, slots=1)
    with pytest.raises(ValueError, match="autoregressive"):
        seng.submit(np.zeros((4, 3), np.float32),
                    sampling=SamplingParams(temperature=1.0))


# ---------------------------------------------------------------------------
# mesh serving (the 1x1 bitwise regression; multi-device lives in
# tests/test_serve_sharded.py behind the forced-8-device subprocess)
# ---------------------------------------------------------------------------

def test_mesh_1x1_engine_bitwise_matches_no_mesh(lm):
    """The mesh-sharded serving path on a 1x1 local mesh is the SAME
    program as the classic single-device engine: identical token streams
    (greedy and sampled, bitwise) for one traffic mix, still exactly one
    compiled decode step.  Pins that the sharded refactor (explicit
    NamedShardings, device_put transfers, donated state) is a placement
    change, not a numerics change."""
    from repro.launch.mesh import make_local_mesh
    cfg, model, params = lm
    rng = np.random.default_rng(11)
    lens = [(5, 4), (13, 7), (3, 2), (9, 5), (21, 3)]
    prompts = [rng.integers(0, cfg.vocab, size=p) for p, _ in lens]
    sps = [None, SamplingParams(temperature=0.9, top_k=12, seed=3), None,
           SamplingParams(temperature=1.2, top_p=0.8, seed=5),
           SamplingParams(temperature=0.7, seed=8)]

    def run(mesh):
        sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
        eng = ServeEngine(sm, params, slots=3, mesh=mesh)
        reqs = [eng.submit(p, max_new_tokens=g, sampling=sp)
                for p, (_, g), sp in zip(prompts, lens, sps)]
        eng.run()
        return [list(r.tokens) for r in reqs], sm, eng

    ref, _, _ = run(None)
    mesh = make_local_mesh(model=1, data=1)
    got, sm, eng = run(mesh)
    assert got == ref
    assert sm._jit_step._cache_size() == 1
    assert eng.mesh is mesh and sm.mesh is mesh
    # the engine's state really carries the bound placement
    leaf = jax.tree_util.tree_leaves(eng.state)[0]
    assert leaf.sharding.mesh is mesh


def test_mesh_1x1_streaming_bitwise(net):
    """Frame streaming (DP-only sharding) under a 1x1 mesh: bitwise."""
    from repro.launch.mesh import make_local_mesh
    netw, params = net
    rng = np.random.default_rng(12)
    streams = [rng.standard_normal((T, 3)).astype(np.float32)
               for T in (6, 3, 9, 4)]

    def run(mesh):
        eng = ServeEngine(MinimalistStepModel(netw), params, slots=2,
                          mesh=mesh)
        reqs = [eng.submit(s) for s in streams]
        eng.run()
        return reqs

    ref = run(None)
    got = run(make_local_mesh(model=1, data=1))
    for a, b in zip(ref, got):
        for x, y in zip(a.outputs, b.outputs):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_make_local_mesh_rejects_oversubscription():
    """make_local_mesh raises a named ValueError (not a bare assert)
    when the requested mesh exceeds the device count."""
    from repro.launch.mesh import make_local_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError, match=f"needs {2 * (n + 1)} devices"):
        make_local_mesh(model=2, data=n + 1)
    with pytest.raises(ValueError, match=">= 1"):
        make_local_mesh(model=0, data=1)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["minimalist-lm-360m", "falcon-mamba-7b",
                                  "smollm-360m"])
def test_chunked_prefill_carry_equivalence(arch):
    """Chunked prefill carry == full-sequence evaluation: the last-token
    logits agree with __call__ on the whole prompt, for every chunking."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = 2, 13
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0, cfg.vocab)
    full = model(params, toks)[:, -1, :]
    sm = DecoderStepModel(model, max_len=24)
    outs = {}
    for chunk in (P, 5, 1):
        last, cache = chunked_prefill(sm, params, toks, chunk=chunk)
        outs[chunk] = last
        np.testing.assert_allclose(
            np.asarray(last, np.float32), np.asarray(full, np.float32),
            atol=0.1, rtol=0.1)   # bf16 compute, different reduction order
        assert jnp.argmax(last[:, :cfg.vocab], -1).tolist() \
            == jnp.argmax(full[:, :cfg.vocab], -1).tolist()
    # chunkings agree with each other much more tightly
    np.testing.assert_allclose(np.asarray(outs[5], np.float32),
                               np.asarray(outs[P], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_minimalist_network_prefill_carry(net):
    """Network chunked prefill == one full __call__, and handing the carry
    to step() continues the stream exactly."""
    netw, params = net
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 12, 3)).astype(np.float32))
    logits = netw(params, x)
    # chunked: 7 frames, then 5
    y1, st = netw.prefill(params, x[:, :7])
    y2, st = netw.prefill(params, x[:, 7:], st)
    np.testing.assert_allclose(np.asarray(y2[:, -1]), np.asarray(logits),
                               atol=1e-5)
    # prefill 11 frames then step the last one
    _y, st = netw.prefill(params, x[:, :11])
    out, st = netw.step(params, x[:, 11], st)
    np.testing.assert_allclose(np.asarray(out), np.asarray(logits),
                               atol=1e-5)
