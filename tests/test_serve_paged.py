"""Paged KV-cache serving: paged == dense bitwise for every attention
family, page-pool lifecycle (allocate-on-append, free-on-finish/cancel,
OOM-vs-defer admission), PagedConfig validation, and the submit()
request-validation contract.

The bitwise claim is the load-bearing one, and it is pinned against the
``paged_impl="gather"`` ORACLE: that path reconstructs each slot's dense
in-cache view through the block table and runs the exact dense decode
math, so the ENGINE token streams (greedy and sampled, under mixed
traffic and chunked prefill) must match the dense-layout engine bit for
bit while the page pool is churning underneath.  The DEFAULT impl is
now ``"pallas"`` (page-indirect kernel; fp32 online softmax, so
numerically ~= but not bitwise the oracle) — the bitwise tests below
pin gather explicitly, and the default path gets its own engine-level
coverage (greedy agreement + int8 storage) plus per-family tolerance
pins in tests/test_kernels_paged_attention.py.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import SamplingParams, get_config
from repro.models import build_model
from repro.serve import (DecoderStepModel, PagedConfig, PagePool,
                         ServeEngine)

LENS = [(5, 4), (13, 7), (3, 2), (9, 5), (21, 3), (6, 6)]
SPS = [None, dict(temperature=0.9, top_k=12, seed=3), None,
       dict(temperature=1.2, top_p=0.8, seed=5),
       dict(temperature=0.7, seed=8), None]


@pytest.fixture(scope="module")
def gqa():
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _gather_model(cfg):
    """Model pinned to the bitwise gather oracle.  params from the
    default-impl model are reusable: init() never depends on paged_impl
    (or kv_dtype) — those only steer the decode cache."""
    return build_model(dataclasses.replace(cfg, paged_impl="gather"))


def _serve(cfg, model, params, layout, *, slots=3, max_len=64, chunk=8,
           page_size=4, num_pages=0, lens=LENS, sps=SPS, seed=1):
    kw = {}
    if layout == "paged":
        kw = dict(kv_layout="paged",
                  paged=PagedConfig(page_size=page_size,
                                    num_pages=num_pages))
    sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk,
                          **kw)
    eng = ServeEngine(sm, params, slots=slots)
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (p, g) in enumerate(lens):
        sp = SamplingParams(**sps[i % len(sps)]) if sps[i % len(sps)] \
            else None
        reqs.append(eng.submit(rng.integers(0, cfg.vocab, size=p),
                               max_new_tokens=g, sampling=sp))
    eng.run()
    return [list(r.tokens) for r in reqs], sm, eng


# ---------------------------------------------------------------------------
# paged == dense, bitwise, per attention family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-360m-smoke",      # global GQA
                                  "gemma3-4b-smoke",        # sliding window
                                  "deepseek-v3-671b-smoke"  # MLA latents
                                  ])
def test_paged_engine_bitwise_matches_dense(arch):
    """Greedy AND sampled token streams under mixed traffic + chunked
    prefill are bit-identical between the paged and dense engines, with
    exactly one compiled decode step.  page_size=4 does not divide most
    of the prompt lengths, so chains end mid-page and prompts span
    partial pages — the awkward cases ride along."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ref, _, _ = _serve(cfg, model, params, "dense")
    got, sm, eng = _serve(cfg, _gather_model(cfg), params, "paged")
    assert got == ref
    assert sm._jit_step._cache_size() == 1
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


@pytest.mark.slow
def test_paged_bitwise_hybrid_stack():
    """Jamba-style hybrid (mamba + attention + MoE): attention layers
    page, the O(1)-state mamba layers keep per-slot leaves — same
    stream.  (slow: the per-family bitwise tests above are the tier-1
    signal; this heavyweight stack runs nightly.)"""
    cfg = get_config("jamba-1.5-large-398b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [(6, 4), (11, 3), (4, 5), (9, 2)]
    ref, _, _ = _serve(cfg, model, params, "dense", max_len=48, lens=lens)
    got, _, eng = _serve(cfg, _gather_model(cfg), params, "paged",
                         max_len=48, lens=lens)
    assert got == ref
    assert eng.pool.pages_in_use == 0


def test_paged_bitwise_under_constrained_pool(gqa):
    """A pool FAR below dense-equivalent capacity (admissions defer,
    pages recycle constantly) still yields the identical streams — the
    allocator changes scheduling, never numerics."""
    cfg, model, params = gqa
    ref, _, _ = _serve(cfg, model, params, "dense", max_len=32,
                       lens=[(9, 6), (5, 4), (12, 8), (3, 3), (7, 5)])
    got, sm, eng = _serve(cfg, _gather_model(cfg), params, "paged",
                          max_len=32, num_pages=8,
                          lens=[(9, 6), (5, 4), (12, 8), (3, 3), (7, 5)])
    assert got == ref
    assert sm._jit_step._cache_size() == 1
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


def test_paged_mesh_1x1_bitwise(gqa):
    """Paged engine on a 1x1 mesh == paged engine with no mesh (the
    sharded-path regression, extended to pools + block tables)."""
    from repro.launch.mesh import make_local_mesh
    cfg, model, params = gqa

    def run(mesh):
        sm = DecoderStepModel(model, max_len=64, prefill_chunk=8,
                              kv_layout="paged",
                              paged=PagedConfig(page_size=4))
        eng = ServeEngine(sm, params, slots=3, mesh=mesh)
        rng = np.random.default_rng(11)
        reqs = [eng.submit(rng.integers(0, cfg.vocab, size=p),
                           max_new_tokens=g) for p, g in LENS[:4]]
        eng.run()
        return [list(r.tokens) for r in reqs]

    assert run(make_local_mesh(1, 1)) == run(None)


def test_paged_default_is_pallas_and_matches_gather_greedy(gqa):
    """The DEFAULT paged impl is the Pallas page-indirect kernel
    (interpret on CPU, compiled on TPU) and it drives the engine loop
    end to end.  Its fp32 online softmax is numerically ~= the gather
    oracle, not bitwise — kernel-vs-oracle accuracy is pinned per family
    in tests/test_kernels_paged_attention.py; here we pin the lifecycle
    and that greedy streams agree on this comfortably-margined smoke
    model."""
    cfg, model, params = gqa
    assert cfg.paged_impl == "pallas"
    lens = [(7, 4), (4, 3)]
    ref, _, _ = _serve(cfg, _gather_model(cfg), params, "paged",
                       lens=lens, sps=[None])
    got, _, eng = _serve(cfg, model, params, "paged", lens=lens,
                         sps=[None])
    assert got == ref
    assert eng.pool.pages_in_use == 0


@pytest.mark.parametrize("arch", ["smollm-360m-smoke",      # global GQA
                                  "gemma3-4b-smoke",        # sliding window
                                  "deepseek-v3-671b-smoke"  # MLA latents
                                  ])
def test_paged_int8_greedy_matches_bf16(arch):
    """int8 per-page KV storage under the default Pallas impl: greedy
    streams are identical to the bf16 paged engine for every attention
    family (the acceptance bar for flipping capacity 2x).  One compiled
    step, pool drains — the quantized pools change no engine
    semantics."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qmodel = build_model(dataclasses.replace(cfg, kv_dtype="int8"))
    lens = [(5, 4), (13, 6), (3, 3), (9, 5)]
    greedy = [None]
    ref, _, _ = _serve(cfg, model, params, "paged", lens=lens, sps=greedy)
    got, sm, eng = _serve(cfg, qmodel, params, "paged", lens=lens,
                          sps=greedy)
    assert got == ref
    assert sm._jit_step._cache_size() == 1
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


def test_int8_pool_capacity_gain_pinned(gqa):
    """Acceptance bar: at a FIXED byte budget, int8 pools admit >= 1.9x
    the long-context requests of bf16 pools — pages halve, the per-page
    float32 scale rows are the small print.  Pure spec arithmetic (no
    engine run); the benchmark's paged_capacity row asserts the same
    bound on real pools."""
    cfg, model, params = gqa
    qmodel = build_model(dataclasses.replace(cfg, kv_dtype="int8"))

    def per_req_bytes(m, req_len=512, max_len=4096, ps=64):
        sm = DecoderStepModel(m, max_len=max_len, kv_layout="paged",
                              paged=PagedConfig(page_size=ps))
        spec = sm.state_spec(1)
        nb = lambda t: sum(int(np.prod(s.shape)) * s.dtype.itemsize
                           for s in jax.tree_util.tree_leaves(t))
        pool = nb({k: v for k, v in spec.items() if k in sm._pool_names})
        rest = nb({k: v for k, v in spec.items()
                   if k not in sm._pool_names})
        return sm.pages_for(req_len) * (pool // sm.max_pages) + rest

    gain = per_req_bytes(model) / per_req_bytes(qmodel)
    assert gain >= 1.9, f"int8 capacity gain {gain:.2f}x < pinned 1.9x"


def test_paged_int8_constrained_pool_recycles_scales(gqa):
    """int8 + a tight pool: pages (codes AND scale rows) recycle across
    requests without stale-scale leakage — the fresh-page scale reset in
    the decode write path.  Greedy streams match the int8 run with an
    abundant pool."""
    cfg, model, params = gqa
    qmodel = build_model(dataclasses.replace(cfg, kv_dtype="int8"))
    lens = [(9, 6), (5, 4), (12, 8), (3, 3), (7, 5)]
    greedy = [None]
    ref, _, _ = _serve(cfg, qmodel, params, "paged", max_len=32,
                       lens=lens, sps=greedy)
    got, _, eng = _serve(cfg, qmodel, params, "paged", max_len=32,
                         num_pages=8, lens=lens, sps=greedy)
    assert got == ref
    assert eng.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# pool lifecycle
# ---------------------------------------------------------------------------

def test_lifecycle_finish_and_cancel_return_pages(gqa):
    """A full traffic mix — eos-early retirement, cancel of a running
    request, cancel of a queued request — drains the pool back to empty:
    every page in the free list, zero reservations, block tables
    zeroed."""
    cfg, model, params = gqa
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8,
                          kv_layout="paged", paged=PagedConfig(page_size=4))
    eng = ServeEngine(sm, params, slots=2)
    rng = np.random.default_rng(5)
    a = eng.submit(rng.integers(0, cfg.vocab, size=9), max_new_tokens=20)
    b = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new_tokens=6)
    c = eng.submit(rng.integers(0, cfg.vocab, size=6), max_new_tokens=8)
    eng.step()
    assert eng.pool.pages_in_use > 0
    eng.cancel(a)                          # running -> slot + pages freed
    assert a.cancelled and a.finished and a not in eng.finished
    assert c in eng.waiting
    eng.cancel(c)                          # queued -> just dequeued
    assert c.cancelled and not c.outputs
    eng.run()
    assert b.finished and not b.cancelled
    assert eng.pool.pages_in_use == 0
    assert eng.pool.reserved_total == 0
    assert len(eng.pool._free) == eng.pool.num_pages
    np.testing.assert_array_equal(eng.pool.block_tables, 0)
    np.testing.assert_array_equal(eng.pool.chain_len, 0)
    # cancelling an already-finished request is a no-op
    eng.cancel(b)
    assert not b.cancelled


def test_slot_reuse_never_reads_stale_pages(gqa):
    """After heavy churn (pages recycled across many requests), a target
    request's stream equals its solo run through a fresh engine — the
    recycled pages' stale contents never leak into attention."""
    cfg, model, params = gqa
    rng = np.random.default_rng(6)
    churn = [(rng.integers(0, cfg.vocab, size=p), g)
             for p, g in [(11, 5), (7, 8), (15, 3), (5, 9), (9, 4)]]
    target = rng.integers(0, cfg.vocab, size=8)
    gmodel = _gather_model(cfg)          # bitwise-vs-dense needs the oracle

    def paged_engine():
        sm = DecoderStepModel(gmodel, max_len=32, prefill_chunk=8,
                              kv_layout="paged",
                              paged=PagedConfig(page_size=4, num_pages=16))
        return ServeEngine(sm, params, slots=2)

    eng = paged_engine()
    for p, g in churn:
        eng.submit(p, max_new_tokens=g)
    eng.run()                                  # churn the pool
    assert eng.pool.pages_in_use == 0
    tr = eng.submit(target, max_new_tokens=7,
                    sampling=SamplingParams(temperature=0.8, seed=42))
    eng.run()
    solo = paged_engine()
    sr = solo.submit(target, max_new_tokens=7,
                     sampling=SamplingParams(temperature=0.8, seed=42))
    solo.run()
    # same counter keys (uid differs) — compare through a dense engine
    # instead: identical submission order, dense layout
    dense = ServeEngine(DecoderStepModel(model, max_len=32,
                                         prefill_chunk=8), params, slots=2)
    for p, g in churn:
        dense.submit(p, max_new_tokens=g)
    dense.run()
    dr = dense.submit(target, max_new_tokens=7,
                      sampling=SamplingParams(temperature=0.8, seed=42))
    dense.run()
    assert list(tr.tokens) == list(dr.tokens)
    assert len(sr.tokens) == len(tr.tokens)


def test_admission_defers_until_pages_free(gqa):
    """With pages for only one live request, admission is strictly
    serial: the queue defers (never raises, never bypasses FIFO order)
    and everyone finishes as pages recycle."""
    cfg, model, params = gqa
    sm = DecoderStepModel(model, max_len=16, prefill_chunk=8,
                          kv_layout="paged",
                          paged=PagedConfig(page_size=4, num_pages=4))
    eng = ServeEngine(sm, params, slots=4)
    rng = np.random.default_rng(7)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=6),
                       max_new_tokens=8) for _ in range(3)]
    eng.admit()
    # 6+8=14 positions -> 4 pages: exactly one request fits at a time
    assert eng.active.sum() == 1 and len(eng.waiting) == 2
    done = eng.run()
    assert len(done) == 3 and all(r.finished for r in reqs)
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


def test_page_pool_allocator_unit():
    pool = PagePool(6, slots=2, max_pages=3)
    assert pool.available == 6 and pool.pages_in_use == 0
    pool.reserve(0, 3)
    pool.grow(0, 2)
    assert pool.pages_in_use == 2 and pool.available == 3
    with pytest.raises(RuntimeError, match="already holds"):
        pool.reserve(0, 1)
    with pytest.raises(RuntimeError, match="exceeds its reservation"):
        pool.grow(0, 4)
    pool.reserve(1, 3)
    with pytest.raises(RuntimeError, match="exceeds available"):
        pool.reserve(1, 1)
    assert not pool.can_admit(1)
    # chains are disjoint
    pool.grow(1, 3)
    used = list(pool.block_tables[0, :2]) + list(pool.block_tables[1, :3])
    assert len(set(used)) == 5
    pool.release(0)
    assert pool.available == 3 and pool.pages_in_use == 3
    pool.release(1)
    assert pool.available == 6 and pool.pages_in_use == 0
    # double-release raises instead of silently no-opping: a second
    # release means two owners believed they freed the slot
    with pytest.raises(ValueError, match="double-release"):
        pool.release(1)


# ---------------------------------------------------------------------------
# validation (PagedConfig + submit satellites)
# ---------------------------------------------------------------------------

def test_model_config_paged_field_validation():
    """Satellite: ``paged_impl`` / ``kv_dtype`` are validated at
    ModelConfig construction with a ValueError naming the allowed
    values — a typo'd impl used to survive until the first decode step
    and die as an opaque dispatch error inside the jitted model."""
    from repro.configs.base import KV_DTYPES, PAGED_IMPLS
    cfg = get_config("smollm-360m-smoke")
    with pytest.raises(ValueError, match=r"paged_impl.*gather"):
        dataclasses.replace(cfg, paged_impl="palas")      # the typo
    with pytest.raises(ValueError, match=r"kv_dtype.*int8"):
        dataclasses.replace(cfg, kv_dtype="fp8")
    for impl in PAGED_IMPLS:                # every documented value builds
        assert dataclasses.replace(cfg, paged_impl=impl).paged_impl == impl
    for kd in KV_DTYPES:
        assert dataclasses.replace(cfg, kv_dtype=kd).kv_dtype == kd


def test_paged_config_validation(gqa):
    cfg, model, params = gqa
    with pytest.raises(ValueError, match="page_size"):
        PagedConfig(page_size=0)
    with pytest.raises(ValueError, match="num_pages"):
        PagedConfig(num_pages=-1)
    # a pool that cannot hold ONE max-length request fails at build time
    with pytest.raises(ValueError, match="max-length request"):
        DecoderStepModel(model, max_len=64, kv_layout="paged",
                         paged=PagedConfig(page_size=4, num_pages=8))
    with pytest.raises(ValueError, match="kv_layout"):
        DecoderStepModel(model, max_len=64, kv_layout="chunked")
    # pure O(1)-state stacks have nothing to page
    mcfg = get_config("minimalist-lm-360m-smoke")
    mmodel = build_model(mcfg)
    with pytest.raises(ValueError, match="attention-bearing"):
        DecoderStepModel(mmodel, max_len=64, kv_layout="paged")


def test_pure_window_stack_pages_bounded_by_ring():
    """A stack with ONLY sliding-window attention needs at most
    ceil(ring/page_size) pages per request no matter how long it runs —
    the bounded page chain the window guarantees."""
    cfg = get_config("gemma3-4b-smoke")     # window=8, but has global too
    base = build_model(cfg)
    assert DecoderStepModel(base, max_len=64, kv_layout="paged",
                            paged=PagedConfig(page_size=4)
                            ).pages_for(64) == 16
    pure = dataclasses.replace(
        cfg, pattern=(cfg.pattern[0],) * len(cfg.pattern),
        tail_layers=(cfg.pattern[0],) * len(cfg.tail_layers))
    assert all(s.kind == "attn_local" for s in pure.layer_specs())
    sm = DecoderStepModel(build_model(pure), max_len=64,
                          kv_layout="paged", paged=PagedConfig(page_size=4))
    assert sm.pages_for(64) == 2            # ring = window 8 -> 2 pages
    assert sm.max_pages == 2


def test_submit_validation_errors(gqa):
    """Satellite: submit() rejects malformed requests with clear
    ValueErrors — empty prompt, non-positive budget, cache overflow —
    instead of asserting or silently scattering out of bounds."""
    cfg, model, params = gqa
    sm = DecoderStepModel(model, max_len=16, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=1)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros(0, np.int64), max_new_tokens=2)
    # 0-d prompt: np.asarray(scalar) has ndim 0 — used to reach the
    # prefill as a shapeless array and die with a TypeError mid-admit
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.int64(7), max_new_tokens=2)
    with pytest.raises(ValueError, match="max_new_tokens >= 1"):
        eng.submit(np.arange(3), max_new_tokens=0)
    with pytest.raises(ValueError, match="1-D token prompt"):
        eng.submit(np.zeros((2, 3), np.int64), max_new_tokens=2)
    with pytest.raises(ValueError,
                       match=r"\(10\) \+ max_new_tokens \(7\) = 17"):
        eng.submit(np.arange(10), max_new_tokens=7)
    # boundary: exactly max_len fits
    r = eng.submit(np.arange(10) % cfg.vocab, max_new_tokens=6)
    eng.run()
    assert len(r.outputs) == 6
    # paged: a request that can NEVER fit the pool is an OOM at submit,
    # not an eternal defer (num_pages >= one max-length request, but a
    # smaller max_len engine can still build pools below that)
    psm = DecoderStepModel(model, max_len=16, prefill_chunk=8,
                           kv_layout="paged",
                           paged=PagedConfig(page_size=4, num_pages=4))
    peng = ServeEngine(psm, params, slots=1)
    assert psm.max_pages == 4
    r = peng.submit(np.arange(8) % cfg.vocab, max_new_tokens=8)
    peng.run()
    assert len(r.outputs) == 8


def test_cancel_unknown_request_rejected(gqa):
    cfg, model, params = gqa
    sm = DecoderStepModel(model, max_len=16, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=1)
    other = ServeEngine(sm, params, slots=1)
    req = other.submit(np.arange(3) % cfg.vocab, max_new_tokens=2)
    with pytest.raises(ValueError, match="not known"):
        eng.cancel(req)


# ---------------------------------------------------------------------------
# sharded paged serving (nightly: 8 forced host devices, TP=2 x DP=2)
# ---------------------------------------------------------------------------

SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "SRC")
import json
import jax, numpy as np
from repro.configs import get_config
from repro.models import build_model
from repro.serve import DecoderStepModel, PagedConfig, ServeEngine
from repro.launch.mesh import make_local_mesh

LENS = [(5, 4), (9, 3), (3, 5), (7, 2), (11, 4), (4, 3)]


def serve(model, cfg, params, mesh, sm=None):
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=p) for p, _ in LENS]
    if sm is None:
        sm = DecoderStepModel(model, max_len=64, prefill_chunk=8,
                              kv_layout="paged",
                              paged=PagedConfig(page_size=4))
    eng = ServeEngine(sm, params, slots=4, mesh=mesh)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, (_pl, g) in zip(prompts, LENS)]
    eng.run()
    return [list(map(int, r.tokens)) for r in reqs], sm, eng


cfg = get_config("smollm-360m-smoke")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
assert len(jax.devices()) == 8
ref, _, _ = serve(model, cfg, params, None)
got, sm, eng = serve(model, cfg, params, make_local_mesh(model=2, data=2))
leaf = [a for a in jax.tree_util.tree_leaves(eng.state) if a.ndim >= 3][0]
out = {
    "greedy_bitwise": got == ref,
    "step_compiles": sm._jit_step._cache_size(),
    "pool_drained": eng.pool.pages_in_use == 0,
    "state_on_mesh": leaf.sharding.num_devices == 4,
}
print("RESULT" + json.dumps(out))
"""


@pytest.mark.slow
def test_paged_sharded_tp2_dp2_bitwise():
    """Nightly: the paged engine under TP=2 x DP=2 (8 forced host
    devices) produces greedy streams bitwise-identical to single-device
    paged serving, with one compiled step and a drained pool — pages
    TP-shard their kv_heads, block tables ride the DP slot placement."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    prog = SUBPROCESS_PROG.replace("SRC", src.replace("\\", "/"))
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-4000:]
    line = [ln for ln in res.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["greedy_bitwise"], out
    assert out["step_compiles"] == 1, out
    assert out["pool_drained"], out
    assert out["state_on_mesh"], out
