"""Preemption: evict-running / resume-later is invisible in the bytes.

The contract under test: a preempted-then-resumed stream is BITWISE
equal to one that was never disturbed — greedy and sampled, across the
three attention families (global GQA / sliding window / MLA latents),
pinned against the ``paged_impl="gather"`` oracle (dense decode math
through the block table).  Two mechanisms make it hold, and both are
exercised here:

  * the snapshot swaps the request's page BYTES to host memory and
    re-seeds them into FRESH physical pages on resume (the LIFO free
    list typically hands the chain back in a different order) — reads
    go through the block table, so the mapping change is invisible;
  * the sampling PRNG is counter-based on (seed, uid, pos) — when a
    token is drawn cannot change what is drawn.

The snapshot/restore path is eager host transfers, so the decode step's
compile count stays at 1 throughout.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SamplingParams, get_config
from repro.models import build_model
from repro.serve import DecoderStepModel, PagedConfig, ServeEngine

LENS = [(5, 8), (9, 6), (3, 7)]
SPS = [None, dict(temperature=0.9, top_k=12, seed=3),
       dict(temperature=1.2, top_p=0.8, seed=5)]


def _build(cfg, params, *, policy="fifo", slots=2, max_len=32,
           num_pages=0, lens=LENS, sps=SPS, submit_all=True):
    model = build_model(dataclasses.replace(cfg, paged_impl="gather"))
    sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=8,
                          kv_layout="paged",
                          paged=PagedConfig(page_size=4,
                                            num_pages=num_pages))
    eng = ServeEngine(sm, params, slots=slots, policy=policy)
    reqs = []
    if submit_all:
        reqs = _submit(eng, cfg, lens, sps)
    return eng, sm, reqs


def _submit(eng, cfg, lens, sps, **kw):
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=p) for p, _g in lens]
    return [eng.submit(p, max_new_tokens=g,
                       sampling=SamplingParams(**sp) if sp else None,
                       **kw)
            for p, (_pl, g), sp in zip(prompts, lens, sps)]


def _drain(eng, sm, reqs):
    eng.run()
    assert sm._jit_step._cache_size() == 1
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0
    return [list(r.tokens) for r in reqs]


@pytest.mark.parametrize("arch", ["smollm-360m-smoke",      # global GQA
                                  "gemma3-4b-smoke",        # sliding window
                                  "deepseek-v3-671b-smoke"  # MLA latents
                                  ])
def test_preempt_resume_bitwise(arch):
    """Force-evict EVERY active slot mid-stream, let the engine resume
    them (into different slots and differently-ordered physical pages),
    and require the exact undisturbed streams — greedy + sampled."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng0, sm0, reqs0 = _build(cfg, params)
    ref = _drain(eng0, sm0, reqs0)

    eng, sm, reqs = _build(cfg, params)
    eng.step()
    eng.step()
    victims = [int(s) for s in np.flatnonzero(eng.active)]
    assert victims                          # mid-stream, nothing finished
    chains = {s: list(eng.pool.block_tables[s,
                                            :eng.pool.chain_len[s]])
              for s in victims}
    for s in victims:
        eng._preempt(s)
    assert not eng.active.any()
    assert eng.pool.pages_in_use == 0       # pages really went back
    assert all(r.snapshot is not None
               for r in eng.waiting if r.n_preemptions)
    got = _drain(eng, sm, reqs)
    assert got == ref
    assert eng.n_preemptions == len(victims)
    assert sum(r.n_preemptions for r in reqs) == len(victims)
    assert all(r.snapshot is None for r in reqs)   # host bytes dropped
    del chains                              # (mapping change is internal)


def test_priority_policy_preempts_for_high_priority():
    """End-to-end policy-driven preemption, blocked on SLOTS: two
    low-priority requests occupy both slots; a later high-priority
    arrival evicts the youngest low one, runs, and the victim resumes —
    every stream bitwise equal to the same traffic under fifo (which
    never preempts: the arrival just waits)."""
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [(6, 18), (6, 16), (4, 4)]
    sps = [None, dict(temperature=0.8, top_k=10, seed=7), None]
    prios = [0, 0, 5]

    def drive(policy):
        eng, sm, _ = _build(cfg, params, policy=policy,
                            submit_all=False)
        low = _submit(eng, cfg, lens[:2], sps[:2])
        for i, r in enumerate(low):
            r.priority = prios[i]           # (already 0; explicit)
        eng.step()
        eng.step()
        high = _submit(eng, cfg, lens[2:], sps[2:], priority=prios[2])
        reqs = low + high
        toks = _drain(eng, sm, reqs)
        order = [eng.finished.index(r) for r in reqs]
        return toks, order, eng, reqs

    ref_toks, _ref_order, ref_eng, _ = drive("fifo")
    assert ref_eng.n_preemptions == 0
    toks, order, eng, reqs = drive("priority")
    assert toks == ref_toks                 # preemption moved no bytes
    assert eng.n_preemptions == 1
    victim = reqs[1]                        # youngest of the low class
    assert victim.n_preemptions == 1
    assert order[2] < order[1]              # high finished before victim
    assert eng.stats().n_preemptions == 1


def test_priority_policy_preempts_for_pages():
    """Same, blocked on PAGES: a slot is free but the pool is fully
    reserved by the low-priority pair — the eviction is what returns
    pages.  The victim's reservation comes back to it on resume from
    its snapshot, so the drain still empties the pool."""
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [(6, 18), (6, 16), (4, 4)]
    sps = [None, None, None]

    eng, sm, _ = _build(cfg, params, policy="priority", slots=3,
                        num_pages=12, submit_all=False)
    low = _submit(eng, cfg, lens[:2], sps[:2])
    eng.step()
    assert int(eng.active.sum()) == 2
    assert eng.pool.available == 0          # 6 + 6 pages reserved
    high = _submit(eng, cfg, lens[2:], sps[2:], priority=5)
    eng.step()
    assert eng.n_preemptions == 1
    assert high[0] in [eng.slot_req[s]
                       for s in np.flatnonzero(eng.active)]
    toks = _drain(eng, sm, low + high)
    # bitwise vs an unconstrained fifo run of the same submissions
    # (same two-batch submit pattern -> same prompt bytes and uids)
    eng0, sm0, _ = _build(cfg, params, slots=3, submit_all=False)
    ref_low = _submit(eng0, cfg, lens[:2], sps[:2])
    ref_high = _submit(eng0, cfg, lens[2:], sps[2:])
    ref = _drain(eng0, sm0, ref_low + ref_high)
    assert toks == ref


def test_fork_child_preempt_resume_bitwise():
    """Regression: a fork child's ``max_new_tokens`` counts from the
    FORK POINT, so the prompt+budget reservation formula under-sizes
    its chain (which covers every position up to the fork).  Re-
    admission must reserve what the slot held at eviction (recorded in
    the snapshot) — with the naive formula, ``pool.grow`` raised
    'exceeds its reservation' mid-resume or at the next page-boundary
    decode append, after the slot was already allocated.  Greedy parent
    + sampled child, streams pinned bitwise against an undisturbed run."""
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def drive(preempt):
        eng, sm, _ = _build(cfg, params, slots=3, submit_all=False)
        rng = np.random.default_rng(4)
        parent = eng.submit(rng.integers(0, cfg.vocab, 6),
                            max_new_tokens=18)
        for _ in range(7):
            eng.step()                  # parent decodes well past its
        [child] = eng.fork(             # prompt before the fork
            parent, max_new_tokens=8,
            sampling=SamplingParams(temperature=0.9, top_k=12, seed=3))
        for _ in range(2):
            eng.step()
        if preempt:
            slot = next(s for s, r in enumerate(eng.slot_req)
                        if r is child)
            # the gap under test: the chain must eventually cover
            # pos+remaining positions, more than prompt+budget covers
            assert (sm.pages_for(int(eng.pos[slot])
                                 + int(eng.remaining[slot]))
                    > sm.pages_for(len(child.prompt)
                                   + child.max_new_tokens))
            eng._preempt(slot)
            assert child.snapshot["reserve"] == sm.pages_for(
                int(child.snapshot["pos"])
                + int(child.snapshot["remaining"]))
        return _drain(eng, sm, [parent, child]), eng, child

    ref, _, _ = drive(preempt=False)
    got, eng, child = drive(preempt=True)
    assert got == ref                   # resume moved no bytes
    assert child.n_preemptions == 1 and child.snapshot is None


def test_cancel_preempted_request_drops_snapshot():
    """A preempted request sits in the queue holding only host bytes —
    cancelling it drops them, touches no pool state (its pages were
    released at eviction), and the rest of the traffic drains."""
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng, sm, reqs = _build(cfg, params)
    eng.step()
    victim = int(np.flatnonzero(eng.active)[0])
    vreq = eng.slot_req[victim]
    eng._preempt(victim)
    assert vreq.snapshot is not None and vreq in eng.waiting
    fp = (eng.pool.refcount.copy(), list(eng.pool._free),
          eng.pool.reserved_total)
    eng.cancel(vreq)
    assert vreq.cancelled and vreq.snapshot is None
    assert vreq not in eng.waiting
    assert (eng.pool.refcount == fp[0]).all()
    assert eng.pool._free == fp[1] and eng.pool.reserved_total == fp[2]
    eng.run()
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0
    assert sm._jit_step._cache_size() == 1


def test_preempt_misuse_raises():
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng, _sm, _reqs = _build(cfg, params)
    with pytest.raises(ValueError, match="not running"):
        eng._preempt(0)                     # nothing admitted yet
    # dense engines have no pages to swap
    dense_sm = DecoderStepModel(build_model(cfg), max_len=32,
                                prefill_chunk=8)
    dense = ServeEngine(dense_sm, params, slots=2)
    rng = np.random.default_rng(0)
    dense.submit(rng.integers(0, cfg.vocab, 4), max_new_tokens=4)
    dense.step()
    with pytest.raises(ValueError, match="paged"):
        dense._preempt(0)
