"""Pallas flash-attention kernel vs naive oracle: shape/dtype sweeps,
GQA index-map correctness, causal + sliding-window masks, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops, ref

KEY = jax.random.PRNGKey(3)


def _inputs(B, H, KV, S, D, dtype=jnp.float32, k=0):
    kk = jax.random.fold_in(KEY, k)
    q = (jax.random.normal(jax.random.fold_in(kk, 1), (B, H, S, D)) * 0.5
         ).astype(dtype)
    kx = (jax.random.normal(jax.random.fold_in(kk, 2), (B, KV, S, D)) * 0.5
          ).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(kk, 3), (B, KV, S, D)
                          ).astype(dtype)
    return q, kx, v


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 1, 1, 8, 8), (1, 2, 2, 64, 16), (2, 4, 2, 128, 32),
    (1, 6, 2, 96, 64), (1, 8, 1, 256, 16),
])
def test_matches_reference(B, H, KV, S, D):
    q, k, v = _inputs(B, H, KV, S, D, k=S + H)
    G = H // KV
    want = ref.mha_ref(q, jnp.repeat(k, G, 1), jnp.repeat(v, G, 1))
    got = ops.flash_attention(q, k, v, True, None, "pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_dtypes(dtype, tol):
    q, k, v = _inputs(1, 2, 2, 64, 32, dtype=dtype, k=7)
    want = ref.mha_ref(q, k, v)
    got = ops.flash_attention(q, k, v, True, None, "pallas")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [8, 16, 64])
def test_sliding_window(window):
    q, k, v = _inputs(1, 2, 1, 128, 16, k=window)
    want = ref.mha_ref(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                       window=window)
    got = ops.flash_attention(q, k, v, True, window, "pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_non_causal():
    q, k, v = _inputs(1, 2, 2, 64, 16, k=11)
    want = ref.mha_ref(q, k, v, causal=False)
    got = ops.flash_attention(q, k, v, False, None, "pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("B,H,KV,S,D", [(1, 4, 2, 64, 16), (2, 2, 1, 96, 32)])
def test_gradients_match_reference(B, H, KV, S, D):
    q, k, v = _inputs(B, H, KV, S, D, k=S)

    def loss(q, k, v, backend):
        out = ops.flash_attention(q, k, v, True, None, backend)
        return jnp.sum(jnp.sin(out) * jnp.cos(jnp.arange(D)))

    want = jax.grad(loss, (0, 1, 2))(q, k, v, "xla")
    got = jax.grad(loss, (0, 1, 2))(q, k, v, "pallas")
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-5, rtol=5e-4)


def test_cost_model_sane():
    f_tr, b_tr = ops.cost_model(8, 16, 4, 4096, 128, train=True)
    f_inf, b_inf = ops.cost_model(8, 16, 4, 4096, 128, train=False)
    assert f_tr > f_inf and b_tr > b_inf
    # memory is O(S·D), not O(S²)
    assert b_inf < 8 * 16 * 4096 * 4096
    fw, _ = ops.cost_model(8, 16, 4, 4096, 128, train=False, window=512)
    assert fw < f_inf  # windowing cuts flops
