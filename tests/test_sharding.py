"""Sharding rules engine + a real multi-device SPMD train step / elastic
re-mesh in a subprocess with 8 host devices."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as shd


class FakeMesh:
    """Duck-typed mesh exposing .shape only (spec_for needs nothing else)."""

    def __init__(self, **axes):
        self.shape = axes


def test_spec_divisibility_gate():
    mesh = FakeMesh(data=16, model=16)
    rules = shd.make_rules()
    # heads=96 divisible -> sharded; head_dim untouched
    assert shd.spec_for(("embed", "heads", "head_dim"), (12288, 96, 128),
                        rules, mesh) == P(None, "model", None)
    # heads=8 NOT divisible by 16 -> replicated
    assert shd.spec_for(("embed", "heads", "head_dim"), (2560, 8, 256),
                        rules, mesh) == P(None, None, None)
    # vocab padded divisible
    assert shd.spec_for(("vocab", "embed"), (152064, 2048), rules, mesh) \
        == P("model", None)


def test_spec_no_duplicate_mesh_axes():
    mesh = FakeMesh(data=4, model=4)
    rules = shd.make_rules({"head_dim": "model"})
    spec = shd.spec_for(("embed", "heads", "head_dim"), (64, 8, 16),
                        rules, mesh)
    axes = [a for a in spec if a is not None]
    assert len(axes) == len(set(axes)) == 1  # heads wins, head_dim skipped


def test_cache_rules_batch_vs_seqlen():
    mesh = FakeMesh(pod=2, data=16, model=16)
    # decode_32k: batch 128 divisible by 32 -> DP on batch
    s = shd.spec_for(("batch", "kv_len", "kv_heads", "head_dim"),
                     (128, 32768, 8, 128), shd.CACHE_RULES, mesh)
    assert s[0] == ("pod", "data") and s[1] is None
    # long_500k: batch 1 -> sequence-parallel cache
    s = shd.spec_for(("batch", "kv_len", "kv_heads", "head_dim"),
                     (1, 524288, 8, 128), shd.CACHE_RULES, mesh)
    assert s[0] is None and s[1] == "data"


def test_missing_mesh_axis_is_dropped():
    mesh = FakeMesh(data=16, model=16)  # no "pod"
    s = shd.spec_for(("batch", "kv_len"), (128, 1024), shd.CACHE_RULES, mesh)
    assert s[0] == "data"


def test_serve_cache_rules_never_shard_kv_len():
    """Serving caches: slot batch -> DP, kv_heads -> TP, but the cache
    LENGTH always replicates — a length-sharded cache would split every
    decode-step softmax reduction across devices and break the engine's
    placement-invariance contract.  (The dry-run's long-context batch-1
    SP regime keeps CACHE_RULES.)"""
    mesh = FakeMesh(data=2, model=2)
    axes = ("batch", "kv_len", "kv_heads", "head_dim")
    # slot batch divisible -> DP; length replicated even though 'data'
    # would be free under CACHE_RULES' SP fallback
    s = shd.spec_for(axes, (4, 1024, 2, 16), shd.SERVE_CACHE_RULES, mesh)
    assert s == P("data", None, "model", None)
    # batch 1 (a solo admission wave): length STILL replicated
    s = shd.spec_for(axes, (1, 1024, 2, 16), shd.SERVE_CACHE_RULES, mesh)
    assert s == P(None, None, "model", None)
    # the stacked positional layout (slots, 1, L, KV, hd): outer slot
    # axis takes DP, the unit's singleton batch dim loses and replicates
    s = shd.spec_for(("batch",) + axes, (4, 1, 1024, 2, 16),
                     shd.SERVE_CACHE_RULES, mesh)
    assert s == P("data", None, None, "model", None)


def test_slot_specs_divisibility_and_trailing_dims():
    """Per-slot decode arrays: dim0 (slot axis) -> DP when divisible,
    trailing dims and scalars replicate, odd slot counts replicate."""
    import jax.numpy as jnp
    mesh = FakeMesh(pod=2, data=2, model=2)
    sds = jax.ShapeDtypeStruct
    specs = shd.slot_specs(
        {"tok": sds((8,), jnp.int32), "cur": sds((8, 3), jnp.float32),
         "pos": sds((), jnp.int32), "odd": sds((3,), jnp.int32)}, mesh)
    assert specs["tok"] == P(("pod", "data"))
    assert specs["cur"] == P(("pod", "data"), None)
    assert specs["pos"] == P()
    assert specs["odd"] == P(None)     # rank kept, just replicated


def test_mesh_info_dp_tp_without_pod_axis():
    from repro.launch.mesh import mesh_info
    assert mesh_info(FakeMesh(data=4, model=2)) == {
        "axes": {"data": 4, "model": 2}, "n_devices": 8, "dp": 4, "tp": 2}
    assert mesh_info(FakeMesh(pod=2, data=16, model=16))["dp"] == 32


SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "SRC")
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamW
from repro.parallel import sharding as shd
from repro.train.elastic import elastic_restart

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen3-moe-30b-a3b-smoke")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
p_shapes = jax.eval_shape(lambda: params)
spec = shd.param_specs(model, p_shapes, mesh)
sh = shd.named_sharding_tree(spec, mesh)
params = jax.tree_util.tree_map(jax.device_put, params, sh)
opt = AdamW(lr=1e-3)
opt_state = opt.init(params)

toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
batch = {"tokens": toks, "labels": toks}
bsh = shd.named_sharding_tree(shd.batch_specs(
    jax.eval_shape(lambda: batch), mesh), mesh)
batch = jax.tree_util.tree_map(jax.device_put, batch, bsh)

@jax.jit
def step(params, opt_state, batch):
    def loss_fn(p):
        return model.loss(p, batch)
    (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt_state, _ = opt.update(g, opt_state, params)
    return params, opt_state, loss

with mesh:
    params, opt_state, loss1 = step(params, opt_state, batch)
    params, opt_state, loss2 = step(params, opt_state, batch)

# elastic: lose 4 devices -> remesh (data=1, model=4), reshard, step again
new_mesh, params2, opt2, plan = elastic_restart(
    model, params, opt_state, lost_devices=4, mesh=mesh)
# the input pipeline re-shards onto the new mesh as well
batch2 = jax.tree_util.tree_map(
    jax.device_put, batch,
    shd.named_sharding_tree(shd.batch_specs(
        jax.eval_shape(lambda: batch), new_mesh), new_mesh))
with new_mesh:
    params2, opt2, loss3 = step(params2, opt2, batch2)

print(json.dumps({
    "loss1": float(loss1), "loss2": float(loss2), "loss3": float(loss3),
    "plan": {"new_data": plan.new_data, "accum": plan.accum_multiplier},
    "any_sharded": any(
        len(getattr(l.sharding, "spec", ())) and
        any(a is not None for a in l.sharding.spec)
        for l in jax.tree_util.tree_leaves(params)),
}))
"""


@pytest.mark.slow
def test_spmd_train_step_and_elastic_remesh_8_devices():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    prog = SUBPROCESS_PROG.replace("SRC", src)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert np.isfinite([res["loss1"], res["loss2"], res["loss3"]]).all()
    assert res["loss2"] < res["loss1"]          # it actually trains
    assert res["plan"] == {"new_data": 1, "accum": 2}
    assert res["any_sharded"]                   # params really distributed
