"""Speculative decoding through the paged engine (serve/spec.py).

The load-bearing contract: a GREEDY speculative stream is BITWISE the
target-only greedy stream — the drafter can only change how many target
calls it took to produce the bytes, never the bytes.  Sampled streams
draw from the target's distribution via counter-keyed rejection/residual
sampling (tests/test_serve_sampling.py pins the sampler in isolation);
at the engine level greedy rows of a mixed batch must stay bitwise while
sampled rows may legitimately re-draw (the per-position salts differ
from the sequential path once a rejection occurs).

Fast half (tier-1): GQA target + minGRU drafter — bitwise identity at
k=4, heterogeneous per-slot widths, mixed greedy/sampled traffic, ONE
compiled verify and ONE compiled propose, pool drained; plus the
submit()/ServeConfig/engine-compat validation satellites.  Slow half:
the same identity sweep over sliding-window (gemma3) and MLA
(deepseek) targets and k in {2, 4}.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SamplingParams, ServeConfig, get_config
from repro.models import build_model
from repro.serve import (DecoderStepModel, DraftStepModel, PagedConfig,
                         ServeEngine)
from repro.serve.spec import heterogeneous_k

LENS = [(7, 9), (13, 6), (5, 12), (9, 5), (11, 8), (6, 10)]


@pytest.fixture(scope="module")
def drafter_model():
    cfg = get_config("minimalist-lm-360m-smoke")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(1))


def _streams(arch, spec_k, drafter_model, *, het=False, sampled=False,
             slots=3, force_drafter=False):
    """Run the LENS workload; returns per-request streams + the engine."""
    cfg = dataclasses.replace(get_config(arch), paged_impl="gather")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sm = DecoderStepModel(model, max_len=64, kv_layout="paged",
                          paged=PagedConfig(page_size=4))
    kw = {}
    if spec_k > 1 or force_drafter:
        _dcfg, dmodel, dparams = drafter_model
        kw = dict(drafter=DraftStepModel(dmodel, spec_k=spec_k),
                  drafter_params=dparams, spec_k=spec_k)
    eng = ServeEngine(sm, params, slots=slots, **kw)
    rng = np.random.default_rng(0)
    reqs = []
    for i, (p, g) in enumerate(LENS):
        samp = (SamplingParams(temperature=0.8, top_k=7, top_p=0.9,
                               seed=123) if sampled and i % 2 else None)
        sk = 1 + (i % spec_k) if het and spec_k > 1 else None
        reqs.append(eng.submit(rng.integers(0, cfg.vocab, p),
                               max_new_tokens=g, sampling=samp,
                               spec_k=sk))
    eng.run()
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0
    if eng.drafter is not None:
        # compile discipline: per-slot widths ride as int32 DATA through
        # ONE compiled verify and ONE compiled propose program
        assert sm._jit_verify._cache_size() == 1
        assert eng.drafter._jit_propose._cache_size() == 1
    return [list(map(int, r.tokens)) for r in reqs], eng


# ---------------------------------------------------------------------------
# fast: GQA identity + widths + mixed traffic (tier-1)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gqa_base(drafter_model):
    """Target-only greedy streams — the oracle every spec run must hit."""
    base, _ = _streams("smollm-360m-smoke", 1, drafter_model)
    return base


def test_greedy_spec_bitwise_identity(gqa_base, drafter_model):
    spec, eng = _streams("smollm-360m-smoke", 4, drafter_model)
    assert spec == gqa_base
    assert eng.n_drafts_proposed > 0
    # every wave decided at least the correction token; with a working
    # accept path the engine must have taken FEWER waves than tokens
    assert eng.n_steps < eng._n_decoded


def test_heterogeneous_per_slot_widths(gqa_base, drafter_model):
    """Requests at spec_k 1/2/3/4 co-batched in one engine: per-slot
    widths are data, and every stream still matches target-only."""
    het, eng = _streams("smollm-360m-smoke", 4, drafter_model, het=True)
    assert het == gqa_base
    assert eng._req_k.max() <= 4


def test_mixed_greedy_sampled_traffic(drafter_model):
    """Greedy rows of a mixed batch are bitwise the target-only rows
    even when sampled rows share every wave (sampled rows draw from the
    target's distribution but not the same sample path)."""
    base, _ = _streams("smollm-360m-smoke", 1, drafter_model,
                       sampled=True)
    spec, _ = _streams("smollm-360m-smoke", 4, drafter_model,
                       sampled=True)
    for i in range(0, len(base), 2):       # even rows are greedy
        assert spec[i] == base[i]


def test_spec_k1_engine_is_plain_decode(drafter_model):
    """A drafter-carrying engine at spec_k=1 degenerates to plain decode
    bitwise — INCLUDING the sampled rows: a width-1 wave has no drafts
    to test, so the verifier's only draw is the unsalted sequential
    sample at pos+1, the exact token plain decode draws."""
    base, _ = _streams("smollm-360m-smoke", 1, drafter_model,
                       sampled=True)
    one, eng = _streams("smollm-360m-smoke", 1, drafter_model,
                        sampled=True, force_drafter=True)
    assert eng.drafter is not None
    assert one == base


# ---------------------------------------------------------------------------
# validation satellites: clear errors, nothing burned
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gqa_engine(drafter_model):
    cfg = dataclasses.replace(get_config("smollm-360m-smoke"),
                              paged_impl="gather")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _dcfg, dmodel, dparams = drafter_model
    sm = DecoderStepModel(model, max_len=64, kv_layout="paged",
                          paged=PagedConfig(page_size=4))
    eng = ServeEngine(sm, params, slots=2,
                      drafter=DraftStepModel(dmodel, spec_k=4),
                      drafter_params=dparams, spec_k=4)
    return cfg, model, params, eng


def test_submit_validates_spec_k(gqa_engine):
    cfg, _model, _params, eng = gqa_engine
    prompt = np.arange(4)
    for bad in [0, -1, 5, 1.5, "wide", True]:
        with pytest.raises(ValueError, match="spec_k"):
            eng.submit(prompt, max_new_tokens=2, spec_k=bad)
    assert not eng.waiting                    # nothing enqueued
    ok = eng.submit(prompt, max_new_tokens=2, spec_k=3)
    assert ok.uid == 0                        # failed submits burned no uid
    assert ok.spec_k == 3
    eng.run()


def test_serve_config_validates_spec_fields():
    with pytest.raises(ValueError, match="spec_k"):
        ServeConfig(spec_k=0)
    with pytest.raises(ValueError, match="drafter"):
        ServeConfig(spec_k=2)                 # width without a drafter
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(drafter="minimalist-lm-360m-smoke", spec_k=2)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeConfig(drafter="minimalist-lm-360m-smoke", spec_k=2,
                    kv_layout="paged", prefix_cache=True)
    ServeConfig(drafter="minimalist-lm-360m-smoke", spec_k=2,
                kv_layout="paged")            # the valid shape


def test_draft_model_rejects_attention_and_bad_k(drafter_model):
    _dcfg, dmodel, _dparams = drafter_model
    with pytest.raises(ValueError, match="spec_k"):
        DraftStepModel(dmodel, spec_k=0)
    attn = build_model(get_config("smollm-360m-smoke"))
    with pytest.raises(ValueError, match="attention"):
        DraftStepModel(attn, spec_k=2)


def test_engine_rejects_incompatible_spec_setups(drafter_model):
    _dcfg, dmodel, dparams = drafter_model
    cfg = dataclasses.replace(get_config("smollm-360m-smoke"),
                              paged_impl="gather")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def paged_sm(m, **kw):
        return DecoderStepModel(m, max_len=64, kv_layout="paged",
                                paged=PagedConfig(page_size=4), **kw)

    drafter = DraftStepModel(dmodel, spec_k=4)
    # drafter without a width / width without a drafter
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(paged_sm(model), params, slots=2, spec_k=4)
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(paged_sm(model), params, slots=2, drafter=drafter,
                    drafter_params=dparams, spec_k=2)  # k mismatch
    # dense target: no paged commit path to verify through
    dense = DecoderStepModel(model, max_len=64)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(dense, params, slots=2, drafter=drafter,
                    drafter_params=dparams, spec_k=4)
    # prefix cache attaches mid-stream state the drafter cannot replay
    with pytest.raises(ValueError, match="prefix"):
        ServeEngine(paged_sm(model), params, slots=2,
                    prefix_cache=True, drafter=drafter,
                    drafter_params=dparams, spec_k=4)
    # vocab mismatch between drafter and target
    vcfg = dataclasses.replace(get_config("minimalist-lm-360m-smoke"),
                               vocab=300)
    vdrafter = DraftStepModel(build_model(vcfg), spec_k=4)
    with pytest.raises(ValueError, match="vocab"):
        ServeEngine(paged_sm(model), params, slots=2, drafter=vdrafter,
                    drafter_params=dparams, spec_k=4)
    # int8 pool: the verify overlay reads raw bf16 page rows
    qmodel = build_model(dataclasses.replace(cfg, kv_dtype="int8"))
    with pytest.raises(ValueError, match="int8"):
        ServeEngine(paged_sm(qmodel), params, slots=2, drafter=drafter,
                    drafter_params=dparams, spec_k=4)
    # sliding-window ring: a wave must fit the shortest ring
    wcfg = dataclasses.replace(get_config("gemma3-4b-smoke"),
                               paged_impl="gather")
    wmodel = build_model(wcfg)
    wparams = wmodel.init(jax.random.PRNGKey(0))
    wide = DraftStepModel(dmodel, spec_k=9)   # window is 8
    with pytest.raises(ValueError, match="window"):
        ServeEngine(paged_sm(wmodel), wparams, slots=2, drafter=wide,
                    drafter_params=dparams, spec_k=9)


def test_heterogeneous_k_clamps():
    """Width = request's k, clamped to [1, k_max] and to the remaining
    generation budget (never commit K/V past pos + remaining)."""
    req = np.array([0, 1, 4, 9, 3], np.int32)
    rem = np.array([5, 5, 2, 5, 1], np.int32)
    out = heterogeneous_k(req, rem, 4)
    assert out.dtype == np.int32
    assert list(out) == [1, 1, 2, 4, 1]


# ---------------------------------------------------------------------------
# slow: sliding-window + MLA targets, k sweep
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-4b-smoke",
                                  "deepseek-v3-671b-smoke"])
def test_greedy_spec_bitwise_identity_window_mla(arch, drafter_model):
    base, _ = _streams(arch, 1, drafter_model)
    for k in (2, 4):
        spec, _ = _streams(arch, k, drafter_model)
        assert spec == base, f"{arch} k={k} diverged from target-only"
    het, _ = _streams(arch, 4, drafter_model, het=True)
    assert het == base


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-4b-smoke",
                                  "deepseek-v3-671b-smoke"])
def test_mixed_traffic_window_mla(arch, drafter_model):
    base, _ = _streams(arch, 1, drafter_model, sampled=True)
    spec, _ = _streams(arch, 4, drafter_model, sampled=True)
    for i in range(0, len(base), 2):
        assert spec[i] == base[i]
