# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the single real CPU device; only the dry-run
# (repro.launch.dryrun) and explicit subprocess tests use 512/8 devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
