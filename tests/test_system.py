"""End-to-end behaviour tests for the paper's system: train the
hardware-constrained MINIMALIST network on the sequential task, export to
the switched-capacitor circuit model, verify the circuit reproduces the
trained network's predictions (the paper's Fig. 4 verification flow)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.analog import AnalogConfig, analog_forward, export_layer
from repro.core.mingru import MinimalistNetwork
from repro.data.smnist import load_smnist
from repro.train.qat import QATConfig, accuracy, train_qat


@pytest.fixture(scope="module")
def tiny_task():
    # short-sequence variant of the surrogate task for CPU runtime;
    # inputs stay analog for training (the paper's Fig.-5 constraints are
    # weights/biases/σ_h/σ_z — the circuit-side input binarization is
    # applied at the circuit-mapping tests below)
    (xtr, ytr), (xte, yte) = load_smnist(seed=0, n_train=1024, n_test=256,
                                         binarize=False)
    # subsample time 784 -> 98 for speed
    return (xtr[:, ::8], ytr), (xte[:, ::8], yte)


@pytest.fixture(scope="module")
def trained(tiny_task):
    train, test = tiny_task
    cfg = QATConfig(dims=(1, 48, 48, 10), phase_epochs=(12, 8, 8, 8),
                    batch=64, lr=5e-3)
    params, results = train_qat(train, test, cfg, verbose=False)
    return params, results, cfg


def test_qat_ladder_learns(trained, tiny_task):
    params, results, cfg = trained
    accs = [r["test_acc"] for r in results]
    assert accs[0] > 0.55, f"fp32 phase failed to learn: {accs}"
    # hardware-compatible phase keeps the bulk of the accuracy (the paper's
    # full-size/full-data version loses only 1.2 pp; this CPU-scale test
    # allows a wider but still meaningful envelope)
    assert accs[-1] > 0.4, accs
    assert results[-1]["quant"]["quantize_gate_6b"]


def test_trained_network_maps_to_circuit(trained, tiny_task):
    """The trained hardware-phase network, exported to capacitor codes and
    replayed through the analog simulator, reproduces the classification."""
    params, results, cfg = trained
    _, (xte, yte) = tiny_task
    net = MinimalistNetwork(cfg.dims, qcfg=quant.QuantConfig.hardware())
    acfg = AnalogConfig()
    images = [export_layer(params[b.name], acfg) for b in net.blocks]
    n = 32
    # the circuit's row drivers are binary: binarize at the hardware boundary
    x = jnp.asarray((xte[:n] > 0.5).astype(np.float32))
    sw_logits = net(params, x)
    readout, _ = analog_forward(images, x, acfg, collect_traces=False)
    sw_pred = np.argmax(np.asarray(sw_logits), -1)
    an_pred = np.argmax(np.asarray(readout), -1)
    assert (sw_pred == an_pred).mean() > 0.9


def test_circuit_robust_to_small_mismatch(trained, tiny_task):
    """1% capacitor mismatch must not destroy accuracy (the paper's claim
    that metal-capacitor matching supports state-of-the-art accuracy)."""
    from repro.core.analog import make_mismatch
    params, results, cfg = trained
    _, (xte, yte) = tiny_task
    net = MinimalistNetwork(cfg.dims, qcfg=quant.QuantConfig.hardware())
    acfg = AnalogConfig(mismatch_sigma=0.01)
    images = [export_layer(params[b.name], acfg) for b in net.blocks]
    mm = make_mismatch(jax.random.PRNGKey(0), images, acfg)
    n = 32
    x = jnp.asarray((xte[:n] > 0.5).astype(np.float32))
    ideal, _ = analog_forward(images, x, AnalogConfig(),
                              collect_traces=False)
    noisy, _ = analog_forward(images, x, acfg, mismatch=mm,
                              collect_traces=False)
    ideal_pred = np.argmax(np.asarray(ideal), -1)
    noisy_pred = np.argmax(np.asarray(noisy), -1)
    assert (ideal_pred == noisy_pred).mean() > 0.8
