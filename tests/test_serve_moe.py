"""Engine-level MoE serving determinism (the contract that replaced the
old DecoderStepModel warning): with the default ``dispatch="auto"``, a
request served on an MoE stack produces BITWISE-identical tokens no
matter which other requests share the slot batch and no matter how its
prompt was chunked at admission — plus dispatch-path equivalence checks
at the module level (gather-GEMM == pooled when nothing is dropped) and
a sensitivity probe showing the pooled path really does vary with
chunking (what the suite would catch if routing regressed).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import build_model
from repro.models.moe import MoEMLP
from repro.serve import DecoderStepModel, ServeEngine


@pytest.fixture(scope="module")
def qwen_moe():
    cfg = get_config("qwen3-moe-30b-a3b-smoke")   # ATTN + MoE every layer
    assert cfg.moe.dispatch == "auto"
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve_target(model, params, target_prompt, gen, *, neighbors=(),
                  chunk=8, slots=3):
    sm = DecoderStepModel(model, max_len=64, prefill_chunk=chunk)
    eng = ServeEngine(sm, params, slots=slots)
    tgt = eng.submit(target_prompt, max_new_tokens=gen)
    for prompt, g in neighbors:
        eng.submit(prompt, max_new_tokens=g)
    eng.run()
    return list(tgt.tokens)


def test_moe_serving_batch_invariant(qwen_moe):
    """Same request alone, co-batched with two different traffic mixes,
    and prefilled at different chunk sizes: identical token streams —
    exactly the failure mode the deleted warning used to describe."""
    cfg, model, params = qwen_moe
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=11)
    alone = _serve_target(model, params, prompt, 6)
    mixed = _serve_target(model, params, prompt, 6, neighbors=[
        (rng.integers(0, cfg.vocab, size=5), 4),
        (rng.integers(0, cfg.vocab, size=7), 3)])
    assert alone == mixed
    mixed2 = _serve_target(model, params, prompt, 6, neighbors=[
        (rng.integers(0, cfg.vocab, size=13), 8)])
    assert alone == mixed2
    # cross-chunk-size runs are DIFFERENT compiled programs: routing is
    # exactly invariant (per-request drop-free dispatch), while the
    # logits behind the greedy argmax match only up to cross-program
    # rounding — like test_chunked_prefill_carry_equivalence, the fixed
    # seeds here sit clear of one-ULP argmax ties
    for chunk in (4, 16):
        assert alone == _serve_target(model, params, prompt, 6,
                                      chunk=chunk)


def test_moe_step_model_no_longer_warns(qwen_moe):
    """Constructing a DecoderStepModel over an MoE stack is warning-free
    (dispatch='auto' serves batch-invariantly) and records the mode."""
    cfg, model, params = qwen_moe
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    assert sm.moe_dispatch == "auto"
    dense = build_model(get_config("smollm-360m-smoke"))
    assert DecoderStepModel(dense, max_len=32).moe_dispatch is None


def test_explicit_pooled_dispatch_still_warns(qwen_moe):
    """dispatch='pooled' opts back into batch-DEPENDENT serving — there
    the old caveat remains true, so the adapter still says so."""
    cfg, _model, _params = qwen_moe
    pooled_cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="pooled"))
    model = build_model(pooled_cfg)
    with pytest.warns(UserWarning, match="pooled"):
        sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    assert sm.moe_dispatch == "pooled"


@pytest.mark.slow
def test_jamba_moe_serving_batch_invariant():
    """The hybrid mamba/attention MoE stack (jamba) gets the same
    guarantee: bitwise-identical streams under co-batching and across
    prefill chunk sizes."""
    cfg = get_config("jamba-1.5-large-398b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab, size=9)
    alone = _serve_target(model, params, prompt, 5)
    mixed = _serve_target(model, params, prompt, 5, neighbors=[
        (rng.integers(0, cfg.vocab, size=6), 4),
        (rng.integers(0, cfg.vocab, size=12), 6)])
    assert alone == mixed
    assert alone == _serve_target(model, params, prompt, 5, chunk=4)


# ---------------------------------------------------------------------------
# module-level dispatch equivalence / sensitivity
# ---------------------------------------------------------------------------

def _mk(dispatch="auto", capacity_factor=1e9, **kw):
    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                    capacity_factor=capacity_factor, dispatch=dispatch,
                    **kw)
    m = MoEMLP(8, moe)
    return m, m.init(jax.random.PRNGKey(0))


def test_gather_matches_pooled_when_no_drops():
    """The capacity-free gather-GEMM decode path computes the same MoE
    output as the pooled capacity dispatch whenever the pool drops
    nothing — they only diverge when pooled capacity bites."""
    m, p = _mk()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 8))
    pooled, aux_p = m(p, x, route="train")        # auto+train -> pooled
    gathered, aux_g = m(p, x, route="decode")     # auto+decode -> gather
    np.testing.assert_allclose(np.asarray(gathered), np.asarray(pooled),
                               atol=1e-5, rtol=1e-4)
    assert float(aux_p["dropped_frac"]) == 0.0
    assert float(aux_g["dropped_frac"]) == 0.0


def test_per_request_matches_pooled_when_no_drops():
    m, p = _mk()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 8))
    pooled, _ = m(p, x, route="train")
    per_req, aux = m(p, x, route="prefill")       # auto+prefill
    np.testing.assert_allclose(np.asarray(per_req), np.asarray(pooled),
                               atol=1e-5, rtol=1e-4)
    assert float(aux["dropped_frac"]) == 0.0


def test_per_request_routing_is_chunk_and_row_invariant():
    """Per-request dispatch is pure per-token top-k: splitting the
    sequence into chunks or changing a NEIGHBOR row leaves a row's
    output bitwise unchanged (grid padding inert for MoE too)."""
    m, p = _mk()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 8))
    full, _ = m(p, x, route="prefill")
    c1, _ = m(p, x[:, :5], route="prefill")
    c2, _ = m(p, x[:, 5:], route="prefill")
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([c1, c2], 1)),
                               atol=1e-6)
    # bitwise row isolation under a different neighbor
    x2 = x.at[1].set(jax.random.normal(jax.random.PRNGKey(9), (12, 8)))
    other, _ = m(p, x2, route="prefill")
    np.testing.assert_array_equal(np.asarray(full[0]),
                                  np.asarray(other[0]))


def test_pooled_dispatch_varies_with_chunking():
    """Sensitivity probe: under tight capacity the POOLED path routes
    differently when the same tokens arrive in smaller chunks — the
    batch-dependence the serving modes remove.  If this ever stops
    failing for pooled, the determinism suite above has lost its
    teeth."""
    m, p = _mk(dispatch="pooled", capacity_factor=0.5)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 8))
    full, aux = m(p, x, route="prefill")
    c1, _ = m(p, x[:, :4], route="prefill")
    c2, _ = m(p, x[:, 4:], route="prefill")
    chunked = jnp.concatenate([c1, c2], 1)
    assert float(aux["dropped_frac"]) > 0.0
    assert float(jnp.abs(full - chunked).max()) > 1e-6


def test_explicit_per_request_dispatch_applies_everywhere():
    """dispatch='per_request' uses per-request grouping on every route,
    including training — outputs match auto's prefill path exactly."""
    m_auto, p = _mk("auto")
    m_pr = MoEMLP(8, dataclasses.replace(m_auto.moe,
                                         dispatch="per_request"))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, 8))
    want, _ = m_auto(p, x, route="prefill")
    for route in ("train", "prefill"):
        got, _ = m_pr(p, x, route=route)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
