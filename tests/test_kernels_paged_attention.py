"""Paged-attention decode kernels: Pallas (interpret) vs dense-gather ref,
page-indirection semantics (chain permutation / stale-page immunity),
equivalence against the dense decode attention they emulate, the int8
per-page-scale kernel variants, and the traffic cost models.

The TOLERANCE CONTRACT lives here: ``paged_impl="pallas"`` is the
serving default, and its per-family max-abs deviation from the
``gather`` oracle is pinned below (both paths are fp32; the kernel's
online softmax reassociates the reduction, the oracle subtracts one
global max — measured worst case is ~4e-7 across page sizes and ragged
chains, pinned at 5x headroom).  The int8 variants are pinned against
the DEQUANTIZED oracle with the same bound: kernel and oracle dequantize
the identical codes with the identical scales, so quantization error
cancels and only the softmax reassociation remains."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import ops, quant, ref

# pallas-vs-gather max |err| bound, per attention family (see module
# docstring; README "Paged KV cache" documents the same numbers)
PALLAS_TOL = {"gqa_global": 2e-6, "gqa_window": 2e-6, "mla": 2e-6}


def _chains(rng, B, n_chain, num_pages):
    """Disjoint random page chains (one per request), like the pool's."""
    ids = rng.permutation(num_pages)[:B * n_chain]
    return ids.reshape(B, n_chain).astype(np.int32)


def _scatter_dense(pool, bt, dense):
    """Write each request's dense cache rows into its page chain."""
    P, ps = pool.shape[:2]
    out = np.array(pool)
    B, L = dense.shape[:2]
    for b in range(B):
        for j in range(L):
            out[bt[b, j // ps], j % ps] = dense[b, j]
    return out


def _setup_gqa(rng, *, B=3, H=4, KV=2, hd=16, L=24, ps=8, num_pages=32):
    n_chain = -(-L // ps)
    q = rng.standard_normal((B, H, hd)).astype(np.float32)
    dense_k = rng.standard_normal((B, L, KV, hd)).astype(np.float32)
    dense_v = rng.standard_normal((B, L, KV, hd)).astype(np.float32)
    bt = _chains(rng, B, n_chain, num_pages)
    # unowned pages hold garbage — they must never matter
    pool_k = _scatter_dense(
        rng.standard_normal((num_pages, ps, KV, hd)).astype(np.float32) * 50,
        bt, dense_k)
    pool_v = _scatter_dense(
        rng.standard_normal((num_pages, ps, KV, hd)).astype(np.float32) * 50,
        bt, dense_v)
    pos = rng.integers(0, L, size=B).astype(np.int32)
    return q, dense_k, dense_v, pool_k, pool_v, bt, pos


def _dense_gqa(q, dense_k, dense_v, pos, *, window=None):
    """Masked softmax attention over the dense cache (fp32), the oracle."""
    B, H, hd = q.shape
    KV = dense_k.shape[2]
    L = dense_k.shape[1]
    idx = np.arange(L)
    if window is None:
        k_pos = np.broadcast_to(idx, (B, L))
    else:
        k_pos = pos[:, None] - ((pos[:, None] - idx[None, :]) % L)
    valid = (k_pos >= 0) & (k_pos <= pos[:, None])
    if window is not None:
        valid &= (pos[:, None] - k_pos) < window
    qg = q.reshape(B, KV, H // KV, hd)
    s = np.einsum("bkgd,blkd->bkgl", qg, dense_k) / math.sqrt(hd)
    s = np.where(valid[:, None, None, :], s, -1e30)
    s = s - s.max(-1, keepdims=True)
    w = np.exp(s)
    w /= w.sum(-1, keepdims=True)
    return np.einsum("bkgl,blkd->bkgd", w, dense_v).reshape(B, H, hd)


@pytest.mark.parametrize("window", [None, 5])
def test_ref_matches_dense_oracle(window):
    rng = np.random.default_rng(0)
    L = 24 if window is None else 5        # ring length = min(window, L)
    q, dk, dv, pk, pv, bt, pos = _setup_gqa(rng, L=L, ps=4)
    got = ops.paged_gqa_attention(
        jnp.asarray(q), jnp.asarray(pk), jnp.asarray(pv), jnp.asarray(bt),
        jnp.asarray(pos), length=L, window=window, backend="xla")
    np.testing.assert_allclose(np.asarray(got),
                               _dense_gqa(q, dk, dv, pos, window=window),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("ps", [4, 8])
def test_pallas_matches_ref_gqa(window, ps):
    rng = np.random.default_rng(1)
    L = 24 if window is None else 7
    q, _dk, _dv, pk, pv, bt, pos = _setup_gqa(rng, L=L, ps=ps)
    args = (jnp.asarray(q), jnp.asarray(pk), jnp.asarray(pv),
            jnp.asarray(bt), jnp.asarray(pos))
    want = ops.paged_gqa_attention(*args, length=L, window=window,
                                   backend="xla")
    got = ops.paged_gqa_attention(*args, length=L, window=window,
                                  backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_chain_permutation_invariance():
    """WHERE a chain's pages live in the pool is irrelevant: permuting
    the page ids (and moving the contents along) leaves the output
    bitwise unchanged."""
    rng = np.random.default_rng(2)
    q, dk, dv, _pk, _pv, bt, pos = _setup_gqa(rng, L=16, ps=4,
                                              num_pages=32)
    perm = rng.permutation(32)
    bt2 = perm[bt].astype(np.int32)
    outs = []
    for table in (bt, bt2):
        pool_k = _scatter_dense(np.zeros((32, 4, 2, 16), np.float32),
                                table, dk)
        pool_v = _scatter_dense(np.zeros((32, 4, 2, 16), np.float32),
                                table, dv)
        for backend in ("xla", "pallas"):
            outs.append(np.asarray(ops.paged_gqa_attention(
                jnp.asarray(q), jnp.asarray(pool_k), jnp.asarray(pool_v),
                jnp.asarray(table), jnp.asarray(pos), length=16,
                backend=backend)))
    np.testing.assert_array_equal(outs[0], outs[2])   # xla: bt == bt2
    np.testing.assert_array_equal(outs[1], outs[3])   # pallas: bt == bt2


def test_stale_pages_and_unallocated_entries_ignored():
    """Garbage in unowned pages and in block-table entries beyond the
    live position must contribute exactly nothing (the engine's page
    recycling correctness property)."""
    rng = np.random.default_rng(3)
    q, dk, dv, pk, pv, bt, pos = _setup_gqa(rng, L=24, ps=8)
    pos = np.minimum(pos, 7)               # only chain entry 0 is live
    clean_k = _scatter_dense(np.zeros_like(pk), bt, dk)
    clean_v = _scatter_dense(np.zeros_like(pv), bt, dv)
    # poison every unallocated block-table entry with a foreign page id
    bt_poison = np.array(bt)
    bt_poison[:, 1:] = (bt[:, 1:] + 1) % pk.shape[0]
    for backend in ("xla", "pallas"):
        a = np.asarray(ops.paged_gqa_attention(
            jnp.asarray(q), jnp.asarray(pk), jnp.asarray(pv),
            jnp.asarray(bt), jnp.asarray(pos), length=24, backend=backend))
        b = np.asarray(ops.paged_gqa_attention(
            jnp.asarray(q), jnp.asarray(clean_k), jnp.asarray(clean_v),
            jnp.asarray(bt_poison), jnp.asarray(pos), length=24,
            backend=backend))
        np.testing.assert_array_equal(a, b)


def test_pallas_matches_ref_mla():
    rng = np.random.default_rng(4)
    B, H, r, dr, L, ps, num_pages = 3, 4, 16, 8, 20, 4, 16
    n_chain = -(-L // ps)
    q_abs = rng.standard_normal((B, H, r)).astype(np.float32)
    q_rope = rng.standard_normal((B, H, dr)).astype(np.float32)
    dense_c = rng.standard_normal((B, L, r)).astype(np.float32)
    dense_r = rng.standard_normal((B, L, dr)).astype(np.float32)
    bt = _chains(rng, B, n_chain, num_pages)
    pool_c = _scatter_dense(
        rng.standard_normal((num_pages, ps, r)).astype(np.float32) * 50,
        bt, dense_c)
    pool_r = _scatter_dense(
        rng.standard_normal((num_pages, ps, dr)).astype(np.float32) * 50,
        bt, dense_r)
    pos = rng.integers(0, L, size=B).astype(np.int32)
    scale = 1.0 / math.sqrt(r + dr)
    args = (jnp.asarray(q_abs), jnp.asarray(q_rope), jnp.asarray(pool_c),
            jnp.asarray(pool_r), jnp.asarray(bt), jnp.asarray(pos))
    want = ops.paged_mla_attention(*args, length=L, scale=scale,
                                   backend="xla")
    got = ops.paged_mla_attention(*args, length=L, scale=scale,
                                  backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    # the ref itself against a straight dense MLA softmax
    s = (np.einsum("bhr,blr->bhl", q_abs, dense_c)
         + np.einsum("bhk,blk->bhl", q_rope, dense_r)) * scale
    s = np.where(np.arange(L)[None, None] <= pos[:, None, None], s, -1e30)
    s = s - s.max(-1, keepdims=True)
    w = np.exp(s)
    w /= w.sum(-1, keepdims=True)
    oracle = np.einsum("bhl,blr->bhr", w, dense_c)
    np.testing.assert_allclose(np.asarray(want), oracle, atol=1e-5,
                               rtol=1e-5)


def test_bad_backend_and_ring_length_rejected():
    z = jnp.zeros((1, 2, 4))
    pool = jnp.zeros((2, 2, 1, 4))
    bt = jnp.zeros((1, 1), jnp.int32)
    pos = jnp.zeros(1, jnp.int32)
    with pytest.raises(ValueError, match="backend"):
        ops.paged_gqa_attention(z, pool, pool, bt, pos, length=2,
                                backend="cuda")
    with pytest.raises(ValueError, match="ring length"):
        ops.paged_gqa_attention(z, pool, pool, bt, pos, length=4, window=2)


def test_page_gather_helper():
    """gather_pages reconstructs the dense view exactly."""
    rng = np.random.default_rng(5)
    pool = rng.standard_normal((8, 4, 3)).astype(np.float32)
    bt = np.array([[6, 1, 3], [0, 7, 2]], np.int32)
    got = np.asarray(ref.gather_pages(jnp.asarray(pool), jnp.asarray(bt),
                                      10))
    for b in range(2):
        for j in range(10):
            np.testing.assert_array_equal(got[b, j],
                                          pool[bt[b, j // 4], j % 4])


def test_gather_dequant_helper():
    """gather_dequant == dequantize-whole-pool + gather_pages: each
    gathered row carries ITS page's scale."""
    rng = np.random.default_rng(6)
    pool = rng.integers(-127, 128, size=(8, 4, 2, 3)).astype(np.int8)
    sc = (rng.random((8, 2)) + 0.1).astype(np.float32)
    bt = np.array([[6, 1, 3], [0, 7, 2]], np.int32)
    got = np.asarray(ref.gather_dequant(jnp.asarray(pool), jnp.asarray(sc),
                                        jnp.asarray(bt), 10))
    dense_pool = pool.astype(np.float32) * sc[:, None, :, None]
    want = np.asarray(ref.gather_pages(jnp.asarray(dense_pool),
                                       jnp.asarray(bt), 10))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# tolerance contract: the default pallas path vs the gather oracle,
# swept over page sizes (incl. ps that doesn't divide the length — ragged
# page ends) and ragged per-request positions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("ps", [2, 4, 5, 8, 16])
def test_tolerance_contract_gqa(window, ps):
    fam = "gqa_global" if window is None else "gqa_window"
    tol = PALLAS_TOL[fam]
    rng = np.random.default_rng(7)
    L = 24 if window is None else 7
    q, _dk, _dv, pk, pv, bt, pos = _setup_gqa(rng, L=L, ps=ps,
                                              num_pages=64)
    args = (jnp.asarray(q), jnp.asarray(pk), jnp.asarray(pv),
            jnp.asarray(bt), jnp.asarray(pos))
    want = ops.paged_gqa_attention(*args, length=L, window=window,
                                   backend="xla")
    got = ops.paged_gqa_attention(*args, length=L, window=window,
                                  backend="pallas")
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    assert err <= tol, f"{fam} ps={ps}: |err|={err:.3e} > pinned {tol:.0e}"


def _setup_mla(rng, *, B=3, H=4, r=16, dr=8, L=20, ps=4, num_pages=48):
    n_chain = -(-L // ps)
    q_abs = rng.standard_normal((B, H, r)).astype(np.float32)
    q_rope = rng.standard_normal((B, H, dr)).astype(np.float32)
    dense_c = rng.standard_normal((B, L, r)).astype(np.float32)
    dense_r = rng.standard_normal((B, L, dr)).astype(np.float32)
    bt = _chains(rng, B, n_chain, num_pages)
    pool_c = _scatter_dense(
        rng.standard_normal((num_pages, ps, r)).astype(np.float32) * 50,
        bt, dense_c)
    pool_r = _scatter_dense(
        rng.standard_normal((num_pages, ps, dr)).astype(np.float32) * 50,
        bt, dense_r)
    pos = rng.integers(0, L, size=B).astype(np.int32)
    return q_abs, q_rope, pool_c, pool_r, bt, pos


@pytest.mark.parametrize("ps", [2, 4, 5, 8])
def test_tolerance_contract_mla(ps):
    tol = PALLAS_TOL["mla"]
    rng = np.random.default_rng(8)
    L, r, dr = 20, 16, 8
    qa, qr, pc, pr, bt, pos = _setup_mla(rng, L=L, ps=ps)
    scale = 1.0 / math.sqrt(r + dr)
    args = (jnp.asarray(qa), jnp.asarray(qr), jnp.asarray(pc),
            jnp.asarray(pr), jnp.asarray(bt), jnp.asarray(pos))
    want = ops.paged_mla_attention(*args, length=L, scale=scale,
                                   backend="xla")
    got = ops.paged_mla_attention(*args, length=L, scale=scale,
                                  backend="pallas")
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    assert err <= tol, f"mla ps={ps}: |err|={err:.3e} > pinned {tol:.0e}"


# ---------------------------------------------------------------------------
# int8 per-page-scale kernel variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 7])
def test_pallas_q8_matches_dequant_oracle_gqa(window):
    """The q8 kernel (in-register dequant) vs the gather oracle over the
    SAME codes+scales: quantization error cancels, only the softmax
    reassociation remains — same pinned bound as the bf16 contract."""
    fam = "gqa_global" if window is None else "gqa_window"
    rng = np.random.default_rng(9)
    L = 24 if window is None else 7
    q, _dk, _dv, pk, pv, bt, pos = _setup_gqa(rng, L=L, ps=4)
    ks = quant.page_abs_scale(jnp.asarray(pk))
    kc = quant.quantize(jnp.asarray(pk), ks)
    vs = quant.page_abs_scale(jnp.asarray(pv))
    vc = quant.quantize(jnp.asarray(pv), vs)
    kw = dict(length=L, window=window, k_scale=ks, v_scale=vs)
    want = ops.paged_gqa_attention(jnp.asarray(q), kc, vc, jnp.asarray(bt),
                                   jnp.asarray(pos), backend="xla", **kw)
    got = ops.paged_gqa_attention(jnp.asarray(q), kc, vc, jnp.asarray(bt),
                                  jnp.asarray(pos), backend="pallas", **kw)
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    assert err <= PALLAS_TOL[fam], err
    # and the dequantized attention tracks the full-precision one at the
    # coarse level 8-bit storage allows (sanity, not the contract)
    full = ops.paged_gqa_attention(
        jnp.asarray(q), jnp.asarray(pk), jnp.asarray(pv), jnp.asarray(bt),
        jnp.asarray(pos), length=L, window=window, backend="xla")
    np.testing.assert_allclose(np.asarray(want), np.asarray(full),
                               atol=0.15, rtol=0.15)


def test_pallas_q8_matches_dequant_oracle_mla():
    rng = np.random.default_rng(10)
    L, r, dr = 20, 16, 8
    qa, qr, pc, pr, bt, pos = _setup_mla(rng, L=L)
    scale = 1.0 / math.sqrt(r + dr)
    cs = quant.page_abs_scale(jnp.asarray(pc))
    cc = quant.quantize(jnp.asarray(pc), cs)
    rs = quant.page_abs_scale(jnp.asarray(pr))
    rc = quant.quantize(jnp.asarray(pr), rs)
    kw = dict(length=L, scale=scale, ckv_scale=cs, krope_scale=rs)
    want = ops.paged_mla_attention(jnp.asarray(qa), jnp.asarray(qr), cc,
                                   rc, jnp.asarray(bt), jnp.asarray(pos),
                                   backend="xla", **kw)
    got = ops.paged_mla_attention(jnp.asarray(qa), jnp.asarray(qr), cc,
                                  rc, jnp.asarray(bt), jnp.asarray(pos),
                                  backend="pallas", **kw)
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    assert err <= PALLAS_TOL["mla"], err


def test_scales_must_come_in_pairs():
    rng = np.random.default_rng(11)
    q, _dk, _dv, pk, pv, bt, pos = _setup_gqa(rng, L=8, ps=4)
    ks = quant.page_abs_scale(jnp.asarray(pk))
    with pytest.raises(ValueError, match="k_scale/v_scale"):
        ops.paged_gqa_attention(jnp.asarray(q), jnp.asarray(pk),
                                jnp.asarray(pv), jnp.asarray(bt),
                                jnp.asarray(pos), length=8, k_scale=ks)


# ---------------------------------------------------------------------------
# cost models (the roofline / benchmark bytes accounting)
# ---------------------------------------------------------------------------

def test_cost_model_window_caps_live_tokens():
    """Satellite fix: a sliding-window layer streams at most
    ceil(min(live, window)/ps) pages — the model used to bill the full
    chain, overstating window-layer bytes by live/window."""
    base = ops.cost_model(4, 8, 2, 64, live_tokens=4096, page_size=16,
                          window=128)
    capped = ops.cost_model(4, 8, 2, 64, live_tokens=128, page_size=16)
    assert base == capped                  # (flops, bytes) both capped
    # window larger than the live chain: no cap kicks in
    short = ops.cost_model(4, 8, 2, 64, live_tokens=64, page_size=16,
                           window=128)
    assert short[1] < base[1]


def test_cost_model_int8_and_scale_bytes():
    """int8 pools stream half the KV bytes plus the per-page scale rows;
    q/o stay priced at bf16 (activations are never quantized)."""
    B, H, KV, hd, T, ps = 4, 8, 2, 64, 4096, 16
    bf_f, bf_b = ops.cost_model(B, H, KV, hd, live_tokens=T, page_size=ps)
    q8_f, q8_b = ops.cost_model(B, H, KV, hd, live_tokens=T, page_size=ps,
                                dtype_bytes=1, scale_bytes=4)
    pages = -(-T // ps)
    assert (bf_b - q8_b
            == 2 * B * pages * ps * KV * hd            # kv bytes halved
            - 2 * B * pages * KV * 4)                  # minus scale rows
    assert q8_f == bf_f                    # math is fp32 either way


def test_cost_model_mla_variant():
    """Satellite fix: MLA latent pages stream r+dr rows per token ONCE
    (keys and values share the ckv latents), not the 2x KV-head shape
    the GQA model assumes."""
    B, H, r, dr, T, ps = 4, 16, 512, 64, 4096, 16
    flops, nbytes = ops.cost_model_mla(B, H, r, dr, live_tokens=T,
                                       page_size=ps)
    pages = -(-T // ps)
    kv_bytes = B * pages * ps * (r + dr) * 2
    assert nbytes == (kv_bytes + B * pages * 4
                      + B * H * (r + dr) * 2 + B * H * r * 2)
    assert flops == 2 * B * H * T * (r + dr) + 2 * B * H * T * r
    # int8 + scales
    _q8_f, q8_b = ops.cost_model_mla(B, H, r, dr, live_tokens=T,
                                     page_size=ps, dtype_bytes=1,
                                     scale_bytes=4)
    assert q8_b < nbytes
