"""Mesh-sharded serving under 8 forced host devices (subprocess, like the
SPMD train-step test in test_sharding.py).

The contract pinned here, for minGRU (minimalist-lm), GQA (smollm) and
MoE-auto (qwen3-moe) stacks:

  * greedy decode on a TP=2 x DP=2 mesh produces BITWISE-identical token
    streams to the single-device engine (TP perturbs logits by a couple
    of bf16 ULPs — reduction order — but never the argmax tokens);
  * the decode step stays ONE compiled program across traffic mixes;
  * sampled decode on a DP-only mesh is bitwise identical to the
    single-device engine (pure placement: row-wise math is untouched);
  * sampled decode under TP keeps the engine's reproducibility contract
    (same request, different co-batched traffic, SAME mesh -> same
    stream) even though its draws may differ from the single-device ones
    (the Gumbel comparisons see those ULP-level logit deltas — this is
    the honest boundary of the bitwise claim, documented in the README).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "SRC")
import json
import jax, numpy as np
from repro.configs import SamplingParams, get_config
from repro.models import build_model
from repro.serve import DecoderStepModel, ServeEngine
from repro.launch.mesh import make_local_mesh

LENS = [(5, 4), (9, 3), (3, 5), (7, 2), (11, 4), (4, 3)]
SPS = [None, dict(temperature=0.9, top_k=12, seed=3), None,
       dict(temperature=1.2, top_p=0.8, seed=5),
       dict(temperature=0.7, seed=8),
       dict(temperature=1.0, top_k=5, top_p=0.9, seed=13)]


def serve(model, cfg, params, mesh, *, sampled=False, slots=4, sm=None,
          lens=LENS, rng_seed=1):
    rng = np.random.default_rng(rng_seed)
    prompts = [rng.integers(0, cfg.vocab, size=p) for p, _ in lens]
    if sm is None:
        sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=slots, mesh=mesh)
    reqs = []
    for i, (p, (_pl, g)) in enumerate(zip(prompts, lens)):
        sp = SamplingParams(**SPS[i % len(SPS)]) \
            if sampled and SPS[i % len(SPS)] else None
        reqs.append(eng.submit(p, max_new_tokens=g, sampling=sp))
    eng.run()
    return [list(map(int, r.tokens)) for r in reqs], sm


out = {}
mesh22 = make_local_mesh(model=2, data=2)    # device prefix of the 8
mesh_dp = make_local_mesh(model=1, data=4)
assert len(jax.devices()) == 8

for arch in ("minimalist-lm-360m-smoke", "smollm-360m-smoke",
             "qwen3-moe-30b-a3b-smoke"):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ref, _ = serve(model, cfg, params, None)
    got, sm = serve(model, cfg, params, mesh22)
    # a different traffic mix through a second engine on the SAME bound
    # StepModel: compile count must not move
    serve(model, cfg, params, mesh22, sm=sm,
          lens=[(6, 3), (13, 2), (2, 4)], rng_seed=9)
    res = {"greedy_bitwise": got == ref,
           "step_compiles": sm._jit_step._cache_size()}
    if arch == "minimalist-lm-360m-smoke":
        sref, _ = serve(model, cfg, params, None, sampled=True)
        sdp, _ = serve(model, cfg, params, mesh_dp, sampled=True)
        res["sampled_dp_bitwise"] = sdp == sref
        # TP reproducibility: request 0 (same uid/seed/prompt) must emit
        # the same stream no matter the co-batched traffic, on one mesh
        stp_a, _ = serve(model, cfg, params, mesh22, sampled=True)
        stp_b, _ = serve(model, cfg, params, mesh22, sampled=True,
                         lens=[LENS[0], (13, 2), (2, 6), (6, 3)])
        res["sampled_tp_reproducible"] = stp_a[0] == stp_b[0]
    out[arch] = res

# params really are distributed: at least one TP-sharded leaf
cfg = get_config("smollm-360m-smoke")
model = build_model(cfg)
sm = DecoderStepModel(model, max_len=32)
sh = sm.shardings(mesh22, 4)
out["any_param_tp_sharded"] = any(
    any(a == "model" or (isinstance(a, tuple) and "model" in a)
        for a in s.spec)
    for s in jax.tree_util.tree_leaves(sh.params))
out["state_slot_dp_sharded"] = any(
    s.spec and s.spec[0] == "data"
    for s in jax.tree_util.tree_leaves(sh.state))

print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_serving_8_devices():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    prog = SUBPROCESS_PROG.replace("SRC", src)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for arch in ("minimalist-lm-360m-smoke", "smollm-360m-smoke",
                 "qwen3-moe-30b-a3b-smoke"):
        assert res[arch]["greedy_bitwise"], (arch, res)
        assert res[arch]["step_compiles"] == 1, (arch, res)
    mg = res["minimalist-lm-360m-smoke"]
    assert mg["sampled_dp_bitwise"], res
    assert mg["sampled_tp_reproducible"], res
    assert res["any_param_tp_sharded"] and res["state_slot_dp_sharded"], res
