"""Property-based tests for ``mingru_scan`` (repro.kernels.linear_scan):
backend equivalence across ragged shapes and custom-VJP gradients against
``jax.grad`` of the definitional scan."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra; skip on minimal installs
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels.linear_scan import ops, ref

SETTINGS = settings(max_examples=12, deadline=None,
                    suppress_health_check=list(hypothesis.HealthCheck))

# ragged T/D on purpose: primes and off-by-ones exercise the padding path
# in linear_scan.ops._dispatch (pallas pads T, D up to block multiples)
shapes = st.tuples(st.integers(1, 3),              # B
                   st.sampled_from([1, 2, 3, 5, 7, 13, 17, 31, 33]),  # T
                   st.sampled_from([1, 2, 3, 5, 8, 13, 129]))         # D


def _inputs(key, B, T, D):
    kz, kh, k0 = jax.random.split(jax.random.PRNGKey(key), 3)
    z = jax.nn.sigmoid(jax.random.normal(kz, (B, T, D)))
    htilde = jax.random.normal(kh, (B, T, D))
    h0 = jax.random.normal(k0, (B, D))
    return z, htilde, h0


def _def_scan(z, htilde, h0):
    """Definitional minGRU recurrence via lax.scan (ground truth)."""
    return ref.linear_scan_sequential(1.0 - z, z * htilde, h0)


@SETTINGS
@given(shapes, st.integers(0, 2**16))
def test_backend_equivalence(shape, key):
    """seq == xla == pallas(interpret) on arbitrary ragged shapes."""
    B, T, D = shape
    z, htilde, h0 = _inputs(key, B, T, D)
    h_seq = ops.mingru_scan(z, htilde, h0, backend="seq")
    h_xla = ops.mingru_scan(z, htilde, h0, backend="xla")
    np.testing.assert_allclose(np.asarray(h_xla), np.asarray(h_seq),
                               atol=1e-5, rtol=1e-5)
    h_pl = ops.mingru_scan(z, htilde, h0, backend="pallas",
                           tblk=8, dblk=128)
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_seq),
                               atol=1e-5, rtol=1e-5)


@SETTINGS
@given(shapes, st.integers(0, 2**16))
def test_custom_vjp_matches_definitional_grad(shape, key):
    """The reverse-scan custom VJP == jax.grad of the definitional scan,
    for gradients wrt z, h̃ and h0 through an arbitrary linear readout."""
    B, T, D = shape
    z, htilde, h0 = _inputs(key, B, T, D)
    w = jax.random.normal(jax.random.PRNGKey(key + 1), (B, T, D))

    def loss_ops(z, htilde, h0):
        return jnp.sum(w * ops.mingru_scan(z, htilde, h0, backend="xla"))

    def loss_def(z, htilde, h0):
        return jnp.sum(w * _def_scan(z, htilde, h0))

    g_ops = jax.grad(loss_ops, argnums=(0, 1, 2))(z, htilde, h0)
    g_def = jax.grad(loss_def, argnums=(0, 1, 2))(z, htilde, h0)
    for a, b, name in zip(g_ops, g_def, ("dz", "dhtilde", "dh0")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


@SETTINGS
@given(st.integers(1, 3), st.integers(1, 9), st.integers(0, 2**16))
def test_gate_interpolation_bounds(B, T, key):
    """h_t always lies in the convex hull of {h_{t-1}, h̃_t} per channel —
    the capacitor-swap interpretation (paper §3) requires it."""
    D = 4
    z, htilde, h0 = _inputs(key, B, T, D)
    h = np.asarray(ops.mingru_scan(z, htilde, h0, backend="seq"))
    h_prev = np.concatenate([np.asarray(h0)[:, None], h[:, :-1]], axis=1)
    lo = np.minimum(h_prev, np.asarray(htilde)) - 1e-5
    hi = np.maximum(h_prev, np.asarray(htilde)) + 1e-5
    assert ((h >= lo) & (h <= hi)).all()
