"""Pallas linear_scan kernel vs pure-jnp oracle: shape/dtype sweeps,
gradients, and hypothesis property tests on the recurrence algebra."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra; skip on minimal installs
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels.linear_scan import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, k, lo=-1.0, hi=1.0, dtype=jnp.float32):
    return jax.random.uniform(jax.random.fold_in(KEY, k), shape,
                              jnp.float32, lo, hi).astype(dtype)


# ---------------------------------------------------------------------------
# shape / dtype sweep: pallas (interpret) vs sequential oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,D", [
    (1, 1, 1), (2, 7, 3), (1, 128, 128), (3, 33, 257),
    (2, 300, 64), (4, 16, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_oracle(B, T, D, dtype):
    a = _rand((B, T, D), 1, 0.0, 1.0, dtype)
    b = _rand((B, T, D), 2, dtype=dtype)
    h0 = _rand((B, D), 3, dtype=dtype)
    want = ref.linear_scan_sequential(a, b, h0)
    got = ops.linear_scan(a, b, h0, "pallas", 16, 128)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("tblk,dblk", [(8, 128), (64, 128), (256, 256)])
def test_pallas_blocking_invariance(tblk, dblk):
    B, T, D = 2, 100, 200
    a = _rand((B, T, D), 4, 0.0, 1.0)
    b = _rand((B, T, D), 5)
    h0 = _rand((B, D), 6)
    want = ref.linear_scan_sequential(a, b, h0)
    got = ops.linear_scan(a, b, h0, "pallas", tblk, dblk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "pallas", "seq"])
def test_gradients_match_reference(backend):
    B, T, D = 2, 23, 17
    a = _rand((B, T, D), 7, 0.1, 0.9)
    b = _rand((B, T, D), 8)
    h0 = _rand((B, D), 9)

    def loss(a, b, h0, impl):
        if impl == "ref":
            h = ref.linear_scan_sequential(a, b, h0)
        else:
            h = ops.linear_scan(a, b, h0, impl, 8, 128)
        return jnp.sum(jnp.tanh(h) * jnp.arange(T)[None, :, None])

    want = jax.grad(loss, (0, 1, 2))(a, b, h0, "ref")
    got = jax.grad(loss, (0, 1, 2))(a, b, h0, backend)
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 40), st.integers(1, 20),
       st.integers(0, 2 ** 31 - 1))
def test_prop_associative_equals_sequential(B, T, D, seed):
    k = jax.random.PRNGKey(seed)
    a = jax.random.uniform(jax.random.fold_in(k, 0), (B, T, D))
    b = jax.random.normal(jax.random.fold_in(k, 1), (B, T, D))
    h0 = jax.random.normal(jax.random.fold_in(k, 2), (B, D))
    hs = ref.linear_scan_sequential(a, b, h0)
    ha = ref.linear_scan_associative(a, b, h0)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hs),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 30), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_prop_scan_composition(T, D, seed):
    """Scanning [0,T) equals scanning [0,s) then [s,T) from the carry —
    the chunking invariant the Pallas kernel's VMEM carry relies on."""
    k = jax.random.PRNGKey(seed)
    s = T // 2
    a = jax.random.uniform(jax.random.fold_in(k, 0), (1, T, D))
    b = jax.random.normal(jax.random.fold_in(k, 1), (1, T, D))
    h0 = jax.random.normal(jax.random.fold_in(k, 2), (1, D))
    full = ref.linear_scan_sequential(a, b, h0)
    h1 = ref.linear_scan_sequential(a[:, :s], b[:, :s], h0)
    carry = h1[:, -1] if s > 0 else h0
    h2 = ref.linear_scan_sequential(a[:, s:], b[:, s:], carry)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 30), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_prop_mingru_convexity(T, D, seed):
    """minGRU state is a convex combination: with h̃, h0 in [lo, hi], every
    h_t stays in [lo, hi] (the capacitor bank cannot leave the rails)."""
    k = jax.random.PRNGKey(seed)
    z = jax.random.uniform(jax.random.fold_in(k, 0), (1, T, D))
    htilde = jax.random.uniform(jax.random.fold_in(k, 1), (1, T, D),
                                minval=-2.0, maxval=3.0)
    h0 = jax.random.uniform(jax.random.fold_in(k, 2), (1, D),
                            minval=-2.0, maxval=3.0)
    h = ops.mingru_scan(z, htilde, h0, backend="seq")
    assert float(h.max()) <= 3.0 + 1e-5
    assert float(h.min()) >= -2.0 - 1e-5
