"""Fused MINIMALIST block kernel vs the hardware-mode MinGRUBlock — the
kernel must be bit-exact with both the STE software model and (hence) the
switched-capacitor circuit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.mingru import MinGRUBlock
from repro.kernels.minimalist_block import ops, ref


def _block(K, N, seed=0):
    blk = MinGRUBlock(K, N, qcfg=quant.QuantConfig.hardware())
    params = blk.init(jax.random.PRNGKey(seed))
    return blk, params


@pytest.mark.parametrize("B,T,K,N", [
    (1, 8, 4, 8), (2, 33, 16, 24), (1, 128, 64, 64), (3, 60, 8, 130),
])
def test_kernel_matches_hardware_block(B, T, K, N):
    blk, params = _block(K, N, seed=B + T)
    x = (jax.random.uniform(jax.random.PRNGKey(1), (B, T, K)) > 0.5
         ).astype(jnp.float32)
    out_sw, h_sw = blk(params, x)

    exported = ops.from_block_params(params)
    y, h = ops.minimalist_block(x, *exported, backend="pallas")
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_sw), atol=2e-5,
                               rtol=1e-5)
    # binary outputs may flip only at |h| ≈ 0 threshold ties
    flips = (np.asarray(y) != np.asarray(out_sw))
    assert not (flips & (np.abs(np.asarray(h_sw)) > 1e-4)).any()


def test_pallas_matches_ref_oracle():
    B, T, K, N = 2, 64, 32, 40
    key = jax.random.PRNGKey(7)
    x = (jax.random.uniform(key, (B, T, K)) > 0.5).astype(jnp.float32)
    ch = jax.random.randint(jax.random.fold_in(key, 1), (K, N), 0, 4
                            ).astype(jnp.int8)
    cz = jax.random.randint(jax.random.fold_in(key, 2), (K, N), 0, 4
                            ).astype(jnp.int8)
    bh = jax.random.normal(jax.random.fold_in(key, 3), (N,)) * 0.5
    bz = jax.random.normal(jax.random.fold_in(key, 4), (N,)) * 0.5
    args = (x, ch, cz, 0.11, bh, bz)
    y1, h1 = ops.minimalist_block(*args, backend="xla")
    y2, h2 = ops.minimalist_block(*args, backend="pallas")
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1), atol=2e-5)
    flips = (np.asarray(y1) != np.asarray(y2))
    assert not (flips & (np.abs(np.asarray(h1)) > 1e-4)).any()


def test_gate_grid_is_capacitor_exact():
    """z values realized inside the kernel live on the k/63 grid — verified
    through the state update: with h0=0 and constant h̃, h_1 = z·h̃."""
    B, T, K, N = 1, 1, 8, 16
    key = jax.random.PRNGKey(3)
    x = jnp.ones((B, T, K))
    ch = jnp.zeros((K, N), jnp.int8) + 3      # all max level
    cz = jax.random.randint(key, (K, N), 0, 4).astype(jnp.int8)
    bh = jnp.zeros((N,))
    bz = jnp.linspace(-4, 4, N)
    y, h = ops.minimalist_block(x, ch, cz, 0.2, bh, bz, backend="pallas")
    htilde = float(K * 1.5 * 0.2)             # all-ones x, all-3 codes
    z = np.asarray(h[0, 0]) / htilde
    np.testing.assert_allclose(z * 63, np.round(z * 63), atol=1e-4)


def test_cost_model():
    f, b = ops.cost_model(4, 784, 64, 64)
    # weight traffic is int8 codes: 2·K·N bytes — 4× less than bf16
    assert 2 * 64 * 64 <= b
    assert f > 0


@pytest.mark.parametrize("B,K,N", [(1, 4, 8), (3, 16, 24), (2, 64, 130)])
def test_fused_step_matches_block_step(B, K, N):
    """The single-step serving kernel == the software hardware-mode step
    == slicing one step out of the full fused block."""
    blk, params = _block(K, N, seed=B + K)
    exported = ops.from_block_params(params)
    x = (jax.random.uniform(jax.random.PRNGKey(2), (B, K)) > 0.5
         ).astype(jnp.float32)
    h_prev = jax.random.normal(jax.random.PRNGKey(3), (B, N))

    y_pl, h_pl = ops.minimalist_step(x, *exported, h_prev, backend="pallas")
    y_ref, h_ref = ops.minimalist_step(x, *exported, h_prev, backend="xla")
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_ref),
                               atol=2e-5)

    _y_sw, h_sw = blk.step(params, x, h_prev)
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_sw),
                               atol=2e-5, rtol=1e-5)

    _yb, hb = ops.minimalist_block(x[:, None, :], *exported, h0=h_prev,
                                   backend="pallas")
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(hb[:, 0]),
                               atol=2e-5)
