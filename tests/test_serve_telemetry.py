"""Observability: tracing, metrics, stats sink (README §Observability).

Two families of guarantees under test:

  * the PRIMITIVES work — bounded rate/percentile windows (eviction,
    empty-window, clock-misbehavior semantics), the metrics registry,
    the Chrome trace_event recorder and its validator, the injectable
    stats sink;
  * the ENGINE contracts hold with telemetry ON — a traced engine run
    (paged + speculative + forced preemption, the worst case) emits
    BITWISE the streams of an untraced run, keeps every jitted program
    at compile count 1, and its saved trace round-trips the Chrome JSON
    schema with a well-formed span tree (every B closed by a matching
    E, per-track monotonic timestamps).

Telemetry never touches jitted programs — every hook is host-side
around device calls — which is WHY the second family can hold.
"""
import dataclasses
import io
import json

import jax
import numpy as np
import pytest

from repro.common.trace import TraceRecorder, validate_chrome_trace
from repro.configs import SamplingParams, get_config
from repro.models import build_model
from repro.serve import (DecoderStepModel, DraftStepModel, PagedConfig,
                         ServeEngine, Telemetry)
from repro.serve.telemetry import (MetricsRegistry, PercentileWindow,
                                   RateWindow, StatsSink)


# -- bounded windows (the EngineStats rate-stream primitives) ------------
def test_rate_window_basic_rate():
    w = RateWindow(maxlen=8)
    # 3 events, 2s span, 5 units AFTER the anchor event -> 2.5/s (the
    # first event's units predate the window: excluded)
    w.push(10.0, 100)
    w.push(11.0, 2)
    w.push(12.0, 3)
    assert w.per_s() == pytest.approx(2.5)
    assert len(w) == 3


def test_rate_window_eviction_slides_the_anchor():
    w = RateWindow(maxlen=3)
    for i in range(10):                   # only the last 3 survive
        w.push(float(i), 1)
    assert len(w) == 3
    # window is [(7,1),(8,1),(9,1)]: 2 units over 2s
    assert w.per_s() == pytest.approx(1.0)


def test_rate_window_degenerate_is_zero():
    w = RateWindow()
    assert w.per_s() == 0.0               # empty
    w.push(5.0, 3)
    assert w.per_s() == 0.0               # single event: no span
    w.push(5.0, 4)
    assert w.per_s() == 0.0               # zero span
    w2 = RateWindow()
    w2.push(9.0, 1)
    w2.push(3.0, 7)                       # clock went BACKWARDS
    assert w2.per_s() == 0.0              # never inf / negative


def test_percentile_window_eviction_and_totals():
    w = PercentileWindow(maxlen=4)
    for v in range(10):
        w.push(float(v))
    assert len(w) == 4                    # window: 6,7,8,9
    assert w.n_total == 10                # lifetime count survives
    assert w.percentile(0) == pytest.approx(6.0)
    assert w.percentile(100) == pytest.approx(9.0)
    s = w.summary()
    assert s["count"] == 10 and s["max"] == pytest.approx(9.0)


def test_percentile_window_empty_is_zero():
    w = PercentileWindow()
    assert w.percentile(99) == 0.0
    assert w.percentiles((50, 99)) == (0.0, 0.0)
    assert w.summary() == {"count": 0, "p50": 0.0, "p99": 0.0,
                           "max": 0.0}


def test_metrics_registry():
    r = MetricsRegistry(reservoir=4)
    r.inc("a")
    r.inc("a", 4)
    r.gauge("g", 2.5)
    for v in range(10):
        r.observe("h", float(v))
    d = r.as_dict()
    assert d["counters"] == {"a": 5}
    assert d["gauges"] == {"g": 2.5}
    assert d["histograms"]["h"]["count"] == 10   # reservoir bounded at 4
    assert len(r.histograms["h"]) == 4


class _FakeStats:
    def __init__(self, n):
        self.n = n

    def line(self):
        return f"line {self.n}"


def test_stats_sink_stream_and_cadence():
    buf = io.StringIO()
    sink = StatsSink(stream=buf, every=3)
    for i in range(7):
        sink.emit(_FakeStats(i))
    out = buf.getvalue().splitlines()
    assert out == ["line 2", "line 5"]    # every 3rd call
    sink.emit(_FakeStats(99), force=True)
    assert buf.getvalue().splitlines()[-1] == "line 99"
    assert sink.n_lines == 3


# -- trace recorder + validator ------------------------------------------
def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 0.001
        return t[0]

    return clock


def test_trace_recorder_roundtrips_chrome_schema(tmp_path):
    tr = TraceRecorder(clock=_fake_clock())
    tr.thread_name(0, "engine")
    tr.begin("wave", 0, n=2)
    tr.instant("fork", 0, child=3)
    tr.counter("slots", 0, active=2, queue=1)
    tr.end(0, name="wave", tokens=2)
    tr.begin("queued", 5)
    tr.end(5)                             # unnamed E closes the top
    path = tmp_path / "t.json"
    tr.save(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    info = validate_chrome_trace(doc)
    assert info["spans"] == 2
    assert info["tracks"] == 2
    # span args land on both ends: B carries n, E carries tokens
    evs = {(e["ph"], e.get("name")): e for e in doc["traceEvents"]
           if e["ph"] in "BE"}
    assert evs[("B", "wave")]["args"] == {"n": 2}
    assert evs[("E", "wave")]["args"] == {"tokens": 2}


@pytest.mark.parametrize("events,err", [
    # unclosed span at end of trace
    ([{"ph": "B", "name": "x", "ts": 1, "pid": 0, "tid": 0}],
     "unclosed"),
    # E with no open span on the track
    ([{"ph": "E", "ts": 1, "pid": 0, "tid": 0}], "no open span"),
    # named E not matching the innermost open B
    ([{"ph": "B", "name": "a", "ts": 1, "pid": 0, "tid": 0},
      {"ph": "B", "name": "b", "ts": 2, "pid": 0, "tid": 0},
      {"ph": "E", "name": "a", "ts": 3, "pid": 0, "tid": 0}],
     "improper nesting"),
    # timestamps must be monotonic per track
    ([{"ph": "i", "name": "x", "ts": 5, "pid": 0, "tid": 0},
      {"ph": "i", "name": "y", "ts": 4, "pid": 0, "tid": 0}],
     "backwards"),
    # unknown phase letter
    ([{"ph": "Z", "name": "x", "ts": 1, "pid": 0, "tid": 0}],
     "phase"),
    # missing pid/tid
    ([{"ph": "i", "name": "x", "ts": 1}], "pid"),
])
def test_trace_validator_rejects_malformed(events, err):
    with pytest.raises(ValueError, match=err):
        validate_chrome_trace({"traceEvents": events})


def test_trace_validator_rejects_non_trace():
    with pytest.raises(ValueError):
        validate_chrome_trace([])
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})


# -- engine integration ---------------------------------------------------
@pytest.fixture(scope="module")
def lm():
    cfg = get_config("minimalist-lm-360m-smoke")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _submit_mixed(eng, cfg, n=4):
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(n):
        sp = (SamplingParams(temperature=0.9, top_k=8, seed=i)
              if i % 2 else None)
        reqs.append(eng.submit(rng.integers(0, cfg.vocab, size=3 + 2 * i),
                               max_new_tokens=3 + i, sampling=sp))
    return reqs


def test_engine_trace_smoke(lm, tmp_path):
    """Tier-1 smoke: a traced engine run saves valid Chrome JSON with a
    well-formed span tree and the expected span taxonomy."""
    cfg, model, params = lm
    tel = Telemetry(trace=True)
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=2, telemetry=tel)
    reqs = _submit_mixed(eng, cfg)
    done = eng.run()
    assert len(done) == len(reqs)

    path = tmp_path / "trace.json"
    tel.save_trace(str(path))
    doc = json.loads(path.read_text())
    info = validate_chrome_trace(doc)     # raises on a malformed tree
    assert info["spans"] > 0
    # engine track + one track per request
    assert info["tracks"] == 1 + len(reqs)
    names = {e["name"] for e in doc["traceEvents"]
             if e["ph"] in ("B", "i")}
    assert {"admit", "prefill", "decode_wave",
            "queued", "running", "submit", "finish"} <= names
    # every request's lifecycle chain is closed: span count on a request
    # track == E count (validate_chrome_trace already checked pairing)
    m = eng.metrics()
    assert m["counters"]["requests_finished"] == len(reqs)
    assert m["jit"]["step_compiles"] == 1
    assert m["telemetry"]["counters"]["requests_submitted"] == len(reqs)
    assert m["telemetry"]["histograms"]["ttft_ms"]["count"] == len(reqs)


def test_metrics_without_telemetry(lm):
    """engine.metrics() is always available — counters/gauges/rates/jit
    need no Telemetry handle; the registry section appears only with
    one attached."""
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=2)
    _submit_mixed(eng, cfg, n=2)
    eng.run()
    m = eng.metrics()
    assert set(m) == {"counters", "gauges", "rates", "jit"}
    assert m["counters"]["requests_finished"] == 2
    assert m["jit"]["step_compiles"] == 1
    assert 0.0 <= m["gauges"]["utilization"] <= 1.0


def test_stats_sink_drives_run(lm):
    """Telemetry(stats_stream=..., stats_every=N) replaces the old
    hardwired verbose print: same rendering, injectable stream and
    cadence."""
    cfg, model, params = lm
    buf = io.StringIO()
    tel = Telemetry(stats_stream=buf, stats_every=2)
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=2, telemetry=tel)
    _submit_mixed(eng, cfg, n=3)
    eng.run()                             # no verbose flag needed
    lines = buf.getvalue().splitlines()
    assert lines and all(ln.startswith("[fifo") for ln in lines)
    assert tel.stats_sink.n_lines == len(lines)
    # every=2: one line per two steps driven by run()
    assert tel.stats_sink.n_calls > len(lines)


def test_deadline_miss_counter(lm):
    cfg, model, params = lm
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    eng = ServeEngine(sm, params, slots=1)
    rng = np.random.default_rng(5)
    eng.submit(rng.integers(0, cfg.vocab, size=4), max_new_tokens=8,
               deadline=1)                # impossible: 8 tokens by step 1
    eng.submit(rng.integers(0, cfg.vocab, size=4), max_new_tokens=2)
    eng.run()
    assert eng.n_deadline_misses == 1
    assert eng.stats().deadline_misses == 1
    assert eng.metrics()["counters"]["deadline_misses"] == 1


# -- bitwise invariance + compile counts under tracing -------------------
@pytest.fixture(scope="module")
def spec_models():
    cfg = dataclasses.replace(get_config("smollm-360m-smoke"),
                              paged_impl="gather")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = get_config("minimalist-lm-360m-smoke")
    dmodel = build_model(dcfg)
    dparams = dmodel.init(jax.random.PRNGKey(1))
    return cfg, model, params, dmodel, dparams


LENS = [(7, 9), (13, 6), (5, 12)]
SPS = [None, dict(temperature=0.9, top_k=12, seed=3), None]


def _spec_engine(spec_models, telemetry):
    cfg, model, params, dmodel, dparams = spec_models
    sm = DecoderStepModel(model, max_len=64, prefill_chunk=8,
                          kv_layout="paged",
                          paged=PagedConfig(page_size=4))
    eng = ServeEngine(sm, params, slots=2, spec_k=3,
                      drafter=DraftStepModel(dmodel, spec_k=3),
                      drafter_params=dparams, telemetry=telemetry)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=p),
                       max_new_tokens=g,
                       sampling=SamplingParams(**sp) if sp else None)
            for (p, g), sp in zip(LENS, SPS)]
    return eng, sm, reqs


def _drive_with_preempt(eng, sm, reqs):
    """Two steps, force-evict every active slot, then drain."""
    eng.step()
    eng.step()
    victims = [int(s) for s in np.flatnonzero(eng.active)]
    assert victims
    for s in victims:
        eng._preempt(s)
    eng.run()
    assert eng.pool.pages_in_use == 0
    return [list(r.tokens) for r in reqs]


def test_traced_spec_preempt_bitwise_and_single_compile(spec_models,
                                                        tmp_path):
    """The acceptance worst case: paged + speculative + forced
    preemption with FULL tracing on emits bitwise the untraced streams,
    every jitted program compiles once, and the trace round-trips the
    Chrome schema with preempt/resume/spec spans present."""
    eng0, sm0, reqs0 = _spec_engine(spec_models, telemetry=None)
    ref = _drive_with_preempt(eng0, sm0, reqs0)

    tel = Telemetry(trace=True)
    eng, sm, reqs = _spec_engine(spec_models, telemetry=tel)
    got = _drive_with_preempt(eng, sm, reqs)
    assert got == ref                     # tracing changed NOTHING

    m = eng.metrics()
    assert m["jit"]["verify_compiles"] == 1
    assert m["jit"]["draft_propose_compiles"] == 1
    assert eng.n_preemptions == eng0.n_preemptions > 0
    assert m["counters"]["preemptions"] == eng.n_preemptions
    assert m["counters"]["drafts_accepted"] == eng0.n_drafts_accepted

    path = tmp_path / "spec_preempt_trace.json"
    tel.save_trace(str(path))
    doc = json.loads(path.read_text())
    info = validate_chrome_trace(doc)     # well-formed span tree
    assert info["tracks"] == 1 + len(reqs)
    names = {e["name"] for e in doc["traceEvents"]
             if e["ph"] in ("B", "i")}
    assert {"spec_wave", "propose", "verify", "preempt", "resume",
            "preempted", "running", "queued", "finish"} <= names
    # the preempted request's track carries the full lifecycle chain:
    # queued -> running -> preempted -> running (validator guarantees
    # every B on the track was closed)
    uid = next(r for r in reqs if r.n_preemptions).uid
    chain = [e["name"] for e in doc["traceEvents"]
             if e["tid"] == uid + 1 and e["ph"] == "B"]
    assert chain[:2] == ["queued", "running"]
    assert "preempted" in chain
    assert chain.index("preempted") < len(chain) - 1  # resumed after


def test_traced_plain_engine_bitwise(lm):
    """Dense / non-spec path: telemetry on vs off, identical streams
    and one compiled step."""
    cfg, model, params = lm

    def go(telemetry):
        sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
        eng = ServeEngine(sm, params, slots=2, telemetry=telemetry)
        reqs = _submit_mixed(eng, cfg)
        eng.run()
        assert sm._jit_step._cache_size() == 1
        return [list(r.tokens) for r in reqs]

    assert go(Telemetry(trace=True)) == go(None)
