"""Checkpointer: roundtrip, atomicity, retention, async, resume semantics."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((4, 8)) * 0.5},
                    "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    from repro.checkpoint.checkpointer import _flatten
    ck = Checkpointer(str(tmp_path), keep_n=2)
    t = _tree()
    ck.save(10, t, blocking=True)
    got = ck.restore()
    fa, fb = _flatten(t), _flatten(got)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]))


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=True)
    assert ck.latest_step() == 4
    assert ck.steps() == [3, 4]  # keep_n=2 garbage-collected the rest


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_partial_write_is_invisible(tmp_path):
    """A .tmp directory (simulated crash mid-write) is never restored."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    os.makedirs(str(tmp_path / "step_00000009.tmp"))
    with open(str(tmp_path / "step_00000009.tmp" / "x.npy"), "w") as f:
        f.write("garbage")
    assert ck.latest_step() == 1


def test_restore_none_when_empty(tmp_path):
    ck = Checkpointer(str(tmp_path))
    assert ck.restore() is None
    assert ck.latest_step() is None
