"""Property suite for the refcounted PagePool.

Random interleavings of reserve / grow / share (fork) / cow / pin
(prefix-cache hold) / unpin / release must preserve the allocator
invariants the engine's bitwise claim rests on:

  * refcounts == (# chains holding the page) + (# external pins) — no
    double-free, no page both free and live, no free-list duplicates;
  * single-writer: a page with refcount 1 sits in exactly one chain;
  * every chain stays within its reservation, and reserved_total is the
    sum of live reservations (``available`` stays conservative under
    sharing);
  * a full drain (release every slot, drop every pin) returns every
    page to the free list: pages_in_use == 0, reserved_total == 0.

Runs under hypothesis when available (shrinks failing op sequences);
the container always runs the seeded fallback over many interleavings.
"""
import numpy as np
import pytest

from repro.serve import PagePool

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

NUM_PAGES, SLOTS, MAX_PAGES = 24, 4, 8
N_OPS = 7  # op codes 0..6


class Shadow:
    """Reference model: chains and pins as plain python sets/lists."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.reserved = {}        # slot -> n_pages
        self.pins = []            # list of page-id tuples


def _chains(pool):
    return {s: [int(p) for p in pool.block_tables[s, :pool.chain_len[s]]]
            for s in range(pool.slots)}


def check_invariants(sh: Shadow):
    pool = sh.pool
    chains = _chains(pool)
    expect = np.zeros(pool.num_pages, np.int64)
    for chain in chains.values():
        assert len(set(chain)) == len(chain), "duplicate page in a chain"
        for p in chain:
            expect[p] += 1
    for pin in sh.pins:
        for p in pin:
            expect[p] += 1
    assert (pool.refcount == expect).all(), \
        f"refcount drift: {pool.refcount.tolist()} != {expect.tolist()}"
    free = pool._free
    assert len(set(free)) == len(free), "free-list duplicate"
    assert all(pool.refcount[p] == 0 for p in free), "free page is live"
    assert pool.pages_in_use == int((pool.refcount > 0).sum())
    assert pool.pages_in_use == pool.num_pages - len(free)
    for s in range(pool.slots):
        assert pool.chain_len[s] <= pool._reserved[s]
    assert pool.reserved_total == sum(sh.reserved.values())
    assert pool.reserved_total == int(pool._reserved.sum())


def apply_op(sh: Shadow, code: int, r: int):
    """One precondition-guarded operation; no-op when nothing applies."""
    pool = sh.pool
    reserved = sorted(sh.reserved)
    with_chain = [s for s in reserved if pool.chain_len[s] > 0]
    if code == 0:    # reserve a fresh slot
        slots = [s for s in range(pool.slots) if s not in sh.reserved]
        if slots:
            n = 1 + r % MAX_PAGES
            if pool.can_admit(n):
                slot = slots[r % len(slots)]
                pool.reserve(slot, n)
                sh.reserved[slot] = n
    elif code == 1:  # grow within the reservation
        if reserved:
            slot = reserved[r % len(reserved)]
            hi = sh.reserved[slot]
            lo = int(pool.chain_len[slot])
            pool.grow(slot, lo + r % (hi - lo + 1))
    elif code == 2:  # share: fork a parent chain prefix into an empty slot
        empty = [s for s in reserved if pool.chain_len[s] == 0]
        if empty and with_chain:
            child = empty[r % len(empty)]
            parent = with_chain[r % len(with_chain)]
            n = min(int(pool.chain_len[parent]), sh.reserved[child])
            pool.share(child, pool.block_tables[parent, :n])
    elif code == 3:  # cow a random chain entry
        if with_chain:
            slot = with_chain[r % len(with_chain)]
            pool.cow(slot, r % int(pool.chain_len[slot]))
    elif code == 4:  # pin a chain prefix (prefix-cache hold)
        if with_chain:
            slot = with_chain[r % len(with_chain)]
            n = 1 + r % int(pool.chain_len[slot])
            pages = tuple(int(p) for p in pool.block_tables[slot, :n])
            pool.incref(pages)
            sh.pins.append(pages)
    elif code == 5:  # drop a pin
        if sh.pins:
            pool.decref(sh.pins.pop(r % len(sh.pins)))
    elif code == 6:  # release (finish/cancel)
        if reserved:
            slot = reserved[r % len(reserved)]
            pool.release(slot)
            del sh.reserved[slot]


def run_ops(ops):
    sh = Shadow(PagePool(NUM_PAGES, SLOTS, MAX_PAGES))
    for code, r in ops:
        apply_op(sh, code % N_OPS, r)
        check_invariants(sh)
    # drain: everything released + unpinned -> the pool is empty
    for slot in list(sh.reserved):
        sh.pool.release(slot)
        del sh.reserved[slot]
    while sh.pins:
        sh.pool.decref(sh.pins.pop())
    check_invariants(sh)
    assert sh.pool.pages_in_use == 0
    assert sh.pool.reserved_total == 0
    assert sorted(sh.pool._free) == list(range(NUM_PAGES))


def test_pool_random_interleavings_seeded():
    """Always-on fallback: 40 seeded interleavings x 120 ops."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        ops = [(int(rng.integers(N_OPS)), int(rng.integers(1 << 16)))
               for _ in range(120)]
        run_ops(ops)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_pool_properties_hypothesis():
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, N_OPS - 1),
                              st.integers(0, 1 << 16)), max_size=150))
    def prop(ops):
        run_ops(ops)
    prop()


def _fingerprint(pool):
    """Every observable allocator field, copied."""
    return (pool.block_tables.copy(), pool.chain_len.copy(),
            pool.refcount.copy(), list(pool._free),
            pool._reserved.copy(), int(pool.reserved_total),
            int(pool.n_cow))


def _assert_unchanged(pool, fp):
    bt, cl, rc, free, res, rt, ncow = fp
    assert (pool.block_tables == bt).all()
    assert (pool.chain_len == cl).all()
    assert (pool.refcount == rc).all()
    assert pool._free == free
    assert (pool._reserved == res).all()
    assert pool.reserved_total == rt and pool.n_cow == ncow


def _misuse_leaves_pool_unchanged(ops):
    """Drive the pool through a valid op sequence, then prove that every
    flavor of refcount underflow / double release raises ValueError and
    leaves the allocator EXACTLY as it was — the failed call must not
    half-apply (the old code pushed pages to the free list as it walked
    the batch, so an underflow mid-batch corrupted the free list)."""
    sh = Shadow(PagePool(NUM_PAGES, SLOTS, MAX_PAGES))
    for code, r in ops:
        apply_op(sh, code % N_OPS, r)
    pool = sh.pool
    fp = _fingerprint(pool)
    dead = [p for p in range(pool.num_pages) if pool.refcount[p] == 0]
    live = [p for p in range(pool.num_pages) if pool.refcount[p] >= 1]
    if dead:  # underflow on a dead page
        with pytest.raises(ValueError, match="double-free"):
            pool.decref([dead[0]])
        _assert_unchanged(pool, fp)
    if live and dead:  # live prefix, dead tail: nothing may half-apply
        with pytest.raises(ValueError, match="double-free"):
            pool.decref([live[0], dead[0]])
        _assert_unchanged(pool, fp)
    singles = [p for p in live if pool.refcount[p] == 1]
    if singles:  # duplicate ids in ONE call must count with multiplicity
        with pytest.raises(ValueError, match="double-free"):
            pool.decref([singles[0], singles[0]])
        _assert_unchanged(pool, fp)
    empty = [s for s in range(pool.slots)
             if pool.chain_len[s] == 0 and pool._reserved[s] == 0]
    if empty:  # double release of a slot holding nothing
        with pytest.raises(ValueError, match="double-release"):
            pool.release(empty[0])
        _assert_unchanged(pool, fp)
    with pytest.raises(ValueError, match="not a page id"):
        pool.decref([pool.num_pages])
    _assert_unchanged(pool, fp)
    check_invariants(sh)


def test_pool_misuse_unchanged_seeded():
    """Always-on fallback for the underflow/double-release property."""
    for seed in range(25):
        rng = np.random.default_rng(1000 + seed)
        ops = [(int(rng.integers(N_OPS)), int(rng.integers(1 << 16)))
               for _ in range(60)]
        _misuse_leaves_pool_unchanged(ops)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_pool_misuse_unchanged_hypothesis():
    @settings(max_examples=150, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, N_OPS - 1),
                              st.integers(0, 1 << 16)), max_size=80))
    def prop(ops):
        _misuse_leaves_pool_unchanged(ops)
    prop()


def test_pool_misuse_raises():
    """The guard rails: double reserve, over-reservation growth, sharing
    dead pages, double-free, cow past the chain."""
    pool = PagePool(8, 2, 4)
    pool.reserve(0, 3)
    with pytest.raises(RuntimeError, match="already holds"):
        pool.reserve(0, 1)
    with pytest.raises(RuntimeError, match="exceeds available"):
        pool.reserve(1, 6)
    with pytest.raises(RuntimeError, match="exceeds its reservation"):
        pool.grow(0, 4)
    pool.grow(0, 2)
    with pytest.raises(RuntimeError, match="cow\\(3\\) beyond"):
        pool.cow(0, 3)
    with pytest.raises(RuntimeError, match="not live"):
        pool.incref([7])
    with pytest.raises(ValueError, match="double-free"):
        pool.decref([7])
    pool.reserve(1, 2)
    with pytest.raises(RuntimeError, match="not live"):
        pool.share(1, [7])
    pool.release(0)
    pool.release(1)
    assert pool.pages_in_use == 0 and pool.reserved_total == 0
    # double release: the slot gave back its chain AND reservation above,
    # so a second release means two owners think they freed it
    with pytest.raises(ValueError, match="double-release"):
        pool.release(1)
