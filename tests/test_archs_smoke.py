"""Per-architecture smoke tests (assignment requirement): reduced configs of
the same family run one forward/loss + one decode step on CPU, asserting
output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model

GRAD_ARCHS = {"qwen3-moe-30b-a3b", "falcon-mamba-7b",
              "jamba-1.5-large-398b", "deepseek-v3-671b"}


def _batch(cfg, B=2, S=16, key=jax.random.PRNGKey(0)):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.arch_type in ("vlm", "audio"):
        batch["embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ASSIGNED + ["minimalist-lm-360m",
                                             "minimalist-lm-360m-hw"])
def test_arch_smoke(name):
    cfg = get_config(name + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    B = batch["tokens"].shape[0]

    # train loss
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"

    # forward logits shape
    logits = model(params, batch["tokens"],
                   embeds=batch.get("embeds"))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_padded
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # decode step against a cache
    kw = {}
    if cfg.arch_type == "audio":
        kw = dict(params=params, frame_embeds=batch["embeds"])
    cache = model.init_cache(B, 32, **kw)
    lg, cache2 = model.decode_step(params, batch["tokens"][:, :1], cache,
                                   jnp.int32(0))
    assert lg.shape == (B, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(lg, np.float32)).all()

    # gradients for a representative subset (runtime budget)
    if name in GRAD_ARCHS:
        g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        leaves = jax.tree_util.tree_leaves(g)
        assert all(np.isfinite(np.asarray(l, np.float32)).all()
                   for l in leaves), f"{name}: NaN grads"


def test_decode_matches_forward_causal():
    """Step-by-step decode logits == full-sequence forward logits (teacher
    forcing) for a dense GQA arch — validates cache/mask bookkeeping."""
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = np.asarray(model(params, toks), np.float32)

    cache = model.init_cache(B, S + 1)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full, atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_sliding_window():
    """Same check through gemma's local:global ring-buffer caches."""
    cfg = get_config("gemma3-4b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 14  # > window (8) to exercise the ring buffer
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = np.asarray(model(params, toks), np.float32)
    cache = model.init_cache(B, S + 1)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full, atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_mamba():
    """O(1)-state decode == parallel scan for the SSM family."""
    cfg = get_config("falcon-mamba-7b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 9
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = np.asarray(model(params, toks), np.float32)
    cache = model.init_cache(B, S + 1, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full, atol=2e-2, rtol=2e-2)


def test_param_count_analytical_close_to_actual():
    """config.param_count() (used for MODEL_FLOPS) tracks real init sizes."""
    for name in ["smollm-360m", "qwen3-moe-30b-a3b", "falcon-mamba-7b"]:
        cfg = get_config(name + "-smoke")
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(s.shape))
                     for s in jax.tree_util.tree_leaves(shapes))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.15, (name, est, actual)
