"""Property-based tests for ``repro.serve.sampling``: greedy convergence,
top-k support, minimal-nucleus top-p, and counter-based reproducibility
under arbitrary co-batching."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra; skip on minimal installs
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.serve.sampling import sample_tokens

SETTINGS = settings(max_examples=20, deadline=None,
                    suppress_health_check=list(hypothesis.HealthCheck))

VOCABS = st.sampled_from([2, 3, 7, 16, 33, 128])


def _logits(key, V, spread=4.0):
    """Random but well-separated logits (no one-ULP ties)."""
    lg = jax.random.normal(jax.random.PRNGKey(key), (V,)) * spread
    return lg + jnp.arange(V) * 1e-3      # strict total order


def _draw(logits, *, seed=0, uid=0, uid_hi=0, pos=0, temperature=1.0,
          top_k=0, top_p=1.0):
    return int(sample_tokens(
        logits[None],
        jnp.asarray([seed], jnp.uint32), jnp.asarray([uid], jnp.uint32),
        jnp.asarray([uid_hi], jnp.uint32),
        jnp.asarray([pos], jnp.int32),
        jnp.asarray([temperature], jnp.float32),
        jnp.asarray([top_k], jnp.int32),
        jnp.asarray([top_p], jnp.float32))[0])


@SETTINGS
@given(VOCABS, st.integers(0, 2**16), st.integers(0, 2**16))
def test_temperature_zero_and_small_converge_to_greedy(V, key, seed):
    """temperature == 0 is exactly greedy; a tiny temperature with a
    clearly separated argmax also samples the argmax (the Gumbel noise
    is O(1) against a logit gap scaled by 1/T)."""
    lg = _logits(key, V)
    greedy = int(jnp.argmax(lg))
    assert _draw(lg, seed=seed, temperature=0.0) == greedy
    gapped = lg.at[greedy].add(1.0)       # >= 1.0 gap, /1e-3 = 1000 sigma
    for pos in range(4):
        assert _draw(gapped, seed=seed, pos=pos,
                     temperature=1e-3) == greedy


@SETTINGS
@given(VOCABS, st.integers(0, 2**16), st.integers(0, 2**16),
       st.integers(1, 512), st.integers(0, 31))
def test_top_k_support(V, key, seed, k, pos):
    """A top-k draw never emits a token whose logit is below the k-th
    largest (k >= V disables the filter — any token is fair game)."""
    lg = _logits(key, V)
    tok = _draw(lg, seed=seed, pos=pos, temperature=1.0, top_k=k)
    if k < V:
        kth = float(jnp.sort(lg)[::-1][k - 1])
        assert float(lg[tok]) >= kth
    else:
        assert 0 <= tok < V


@SETTINGS
@given(VOCABS, st.integers(0, 2**16), st.integers(0, 2**16),
       st.floats(0.05, 0.999), st.integers(0, 31))
def test_top_p_minimal_nucleus(V, key, seed, p, pos):
    """The emitted token always lies inside the MINIMAL nucleus: the
    smallest probability-ranked prefix whose mass reaches top_p."""
    lg = _logits(key, V)
    tok = _draw(lg, seed=seed, pos=pos, temperature=1.0, top_p=p)
    probs = np.asarray(jax.nn.softmax(lg), np.float64)
    order = np.argsort(-probs, kind="stable")
    csum = np.cumsum(probs[order])
    # minimal prefix reaching p (+eps: the kernel cumsums in f32)
    n = int(np.searchsorted(csum, min(p + 1e-5, 1.0)) + 1)
    nucleus = set(order[:n].tolist())
    assert tok in nucleus
    # and top_p=1.0 disables the filter entirely (any token possible)
    assert 0 <= _draw(lg, seed=seed, pos=pos, top_p=1.0) < V


@SETTINGS
@given(st.integers(0, 2**16), st.integers(0, 2**16), st.integers(1, 6))
def test_counter_key_reproducible_across_cobatch(key, seed, nbatch):
    """Row 0's draw depends only on (seed, uid, pos) and its own logits:
    bitwise identical no matter what fills the other slots."""
    V = 32
    lg0 = _logits(key, V)
    rng = np.random.default_rng(key)

    def batch_draw(neighbors):
        B = 1 + len(neighbors)
        lg = jnp.stack([lg0] + neighbors)
        out = sample_tokens(
            lg,
            jnp.asarray([seed] + [rng.integers(2**31)
                                  for _ in neighbors], jnp.uint32),
            jnp.asarray(range(B), jnp.uint32),
            jnp.asarray([0] * B, jnp.uint32),
            jnp.asarray([3] * B, jnp.int32),
            jnp.asarray([0.9] + [float(rng.uniform(0, 2))
                                 for _ in neighbors], jnp.float32),
            jnp.asarray([7] + [int(rng.integers(0, V))
                               for _ in neighbors], jnp.int32),
            jnp.asarray([0.8] + [float(rng.uniform(0.1, 1))
                                 for _ in neighbors], jnp.float32))
        return int(out[0])

    neigh = [jnp.asarray(rng.standard_normal(V), jnp.float32)
             for _ in range(nbatch - 1)]
    alone = batch_draw([jnp.zeros(V, jnp.float32)] * (nbatch - 1))
    mixed = batch_draw(neigh)
    assert alone == mixed


def test_different_seed_uid_or_pos_changes_the_stream():
    """The counter key really folds in all three of (seed, uid, pos):
    over a flat distribution, varying any one of them produces a
    different draw sequence."""
    V = 1024
    lg = jnp.zeros((V,))                  # uniform: draws expose the key
    base = [_draw(lg, seed=1, uid=2, pos=p) for p in range(16)]
    assert len(set(base)) > 1             # pos is folded in
    assert base != [_draw(lg, seed=3, uid=2, pos=p) for p in range(16)]
    assert base != [_draw(lg, seed=1, uid=4, pos=p) for p in range(16)]
    # and fixed (seed, uid, pos) is bitwise stable across processes/calls
    assert base == [_draw(lg, seed=1, uid=2, pos=p) for p in range(16)]
