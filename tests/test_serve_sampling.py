"""Property-based tests for ``repro.serve.sampling``: greedy convergence,
top-k support, minimal-nucleus top-p, and counter-based reproducibility
under arbitrary co-batching."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra; skip on minimal installs
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.serve.sampling import (rejection_sample_row, sample_tokens,
                                  verify_tokens)

SETTINGS = settings(max_examples=20, deadline=None,
                    suppress_health_check=list(hypothesis.HealthCheck))

VOCABS = st.sampled_from([2, 3, 7, 16, 33, 128])


def _logits(key, V, spread=4.0):
    """Random but well-separated logits (no one-ULP ties)."""
    lg = jax.random.normal(jax.random.PRNGKey(key), (V,)) * spread
    return lg + jnp.arange(V) * 1e-3      # strict total order


def _draw(logits, *, seed=0, uid=0, uid_hi=0, pos=0, temperature=1.0,
          top_k=0, top_p=1.0):
    return int(sample_tokens(
        logits[None],
        jnp.asarray([seed], jnp.uint32), jnp.asarray([uid], jnp.uint32),
        jnp.asarray([uid_hi], jnp.uint32),
        jnp.asarray([pos], jnp.int32),
        jnp.asarray([temperature], jnp.float32),
        jnp.asarray([top_k], jnp.int32),
        jnp.asarray([top_p], jnp.float32))[0])


@SETTINGS
@given(VOCABS, st.integers(0, 2**16), st.integers(0, 2**16))
def test_temperature_zero_and_small_converge_to_greedy(V, key, seed):
    """temperature == 0 is exactly greedy; a tiny temperature with a
    clearly separated argmax also samples the argmax (the Gumbel noise
    is O(1) against a logit gap scaled by 1/T)."""
    lg = _logits(key, V)
    greedy = int(jnp.argmax(lg))
    assert _draw(lg, seed=seed, temperature=0.0) == greedy
    gapped = lg.at[greedy].add(1.0)       # >= 1.0 gap, /1e-3 = 1000 sigma
    for pos in range(4):
        assert _draw(gapped, seed=seed, pos=pos,
                     temperature=1e-3) == greedy


@SETTINGS
@given(VOCABS, st.integers(0, 2**16), st.integers(0, 2**16),
       st.integers(1, 512), st.integers(0, 31))
def test_top_k_support(V, key, seed, k, pos):
    """A top-k draw never emits a token whose logit is below the k-th
    largest (k >= V disables the filter — any token is fair game)."""
    lg = _logits(key, V)
    tok = _draw(lg, seed=seed, pos=pos, temperature=1.0, top_k=k)
    if k < V:
        kth = float(jnp.sort(lg)[::-1][k - 1])
        assert float(lg[tok]) >= kth
    else:
        assert 0 <= tok < V


@SETTINGS
@given(VOCABS, st.integers(0, 2**16), st.integers(0, 2**16),
       st.floats(0.05, 0.999), st.integers(0, 31))
def test_top_p_minimal_nucleus(V, key, seed, p, pos):
    """The emitted token always lies inside the MINIMAL nucleus: the
    smallest probability-ranked prefix whose mass reaches top_p."""
    lg = _logits(key, V)
    tok = _draw(lg, seed=seed, pos=pos, temperature=1.0, top_p=p)
    probs = np.asarray(jax.nn.softmax(lg), np.float64)
    order = np.argsort(-probs, kind="stable")
    csum = np.cumsum(probs[order])
    # minimal prefix reaching p (+eps: the kernel cumsums in f32)
    n = int(np.searchsorted(csum, min(p + 1e-5, 1.0)) + 1)
    nucleus = set(order[:n].tolist())
    assert tok in nucleus
    # and top_p=1.0 disables the filter entirely (any token possible)
    assert 0 <= _draw(lg, seed=seed, pos=pos, top_p=1.0) < V


@SETTINGS
@given(st.integers(0, 2**16), st.integers(0, 2**16), st.integers(1, 6))
def test_counter_key_reproducible_across_cobatch(key, seed, nbatch):
    """Row 0's draw depends only on (seed, uid, pos) and its own logits:
    bitwise identical no matter what fills the other slots."""
    V = 32
    lg0 = _logits(key, V)
    rng = np.random.default_rng(key)

    def batch_draw(neighbors):
        B = 1 + len(neighbors)
        lg = jnp.stack([lg0] + neighbors)
        out = sample_tokens(
            lg,
            jnp.asarray([seed] + [rng.integers(2**31)
                                  for _ in neighbors], jnp.uint32),
            jnp.asarray(range(B), jnp.uint32),
            jnp.asarray([0] * B, jnp.uint32),
            jnp.asarray([3] * B, jnp.int32),
            jnp.asarray([0.9] + [float(rng.uniform(0, 2))
                                 for _ in neighbors], jnp.float32),
            jnp.asarray([7] + [int(rng.integers(0, V))
                               for _ in neighbors], jnp.int32),
            jnp.asarray([0.8] + [float(rng.uniform(0.1, 1))
                                 for _ in neighbors], jnp.float32))
        return int(out[0])

    neigh = [jnp.asarray(rng.standard_normal(V), jnp.float32)
             for _ in range(nbatch - 1)]
    alone = batch_draw([jnp.zeros(V, jnp.float32)] * (nbatch - 1))
    mixed = batch_draw(neigh)
    assert alone == mixed


# ---------------------------------------------------------------------------
# Speculative decoding: the rejection/residual sampler in isolation
# ---------------------------------------------------------------------------

def _pq(key, V, spread=2.0):
    kp, kq = jax.random.split(jax.random.PRNGKey(key))
    p_lg = jax.random.normal(kp, (V,)) * spread
    q_lg = jax.random.normal(kq, (V,)) * spread
    draft = int(jax.random.randint(kq, (), 0, V))
    return p_lg, q_lg, draft


def _reject_many(p_lg, q_lg, draft, seed, n):
    """n independent rejection steps (one per position counter)."""
    toks, acc = jax.vmap(
        rejection_sample_row,
        in_axes=(None, None, None, None, None, None, 0))(
        p_lg, q_lg, jnp.int32(draft), jnp.uint32(seed),
        jnp.uint32(1), jnp.uint32(0), jnp.arange(1, n + 1, dtype=jnp.int32))
    return np.asarray(toks), np.asarray(acc)


@SETTINGS
@given(st.sampled_from([2, 3, 7, 16]), st.integers(0, 2**16),
       st.integers(0, 2**16))
def test_rejection_accept_prob_is_min_ratio(V, key, seed):
    """The draft is accepted with probability min(1, p(draft)/q(draft))
    — the textbook rule, measured over independent position counters."""
    p_lg, q_lg, draft = _pq(key, V)
    n = 512
    _, acc = _reject_many(p_lg, q_lg, draft, seed, n)
    p = np.asarray(jax.nn.softmax(p_lg), np.float64)
    q = np.asarray(jax.nn.softmax(q_lg), np.float64)
    want = min(1.0, p[draft] / q[draft])
    sigma = np.sqrt(max(want * (1 - want), 1e-12) / n)
    assert abs(float(acc.mean()) - want) < 4.5 * sigma + 0.01


@SETTINGS
@given(st.sampled_from([2, 3, 7]), st.integers(0, 2**16),
       st.integers(0, 2**16))
def test_rejection_marginal_is_target_and_residual_normalizes(V, key,
                                                              seed):
    """With drafts DRAWN FROM q (the speculative setting), the composite
    accept-or-residual output is distributed exactly as the target p —
    the identity the whole scheme rests on.  And every rejected draw
    lands in the support of the normalized residual (p - q)+ — in
    particular, never on the rejected draft itself."""
    p_lg, q_lg, _ = _pq(key, V)
    n = 1024
    drafts = jax.random.categorical(
        jax.random.PRNGKey(key + 99), q_lg, shape=(n,)).astype(jnp.int32)
    toks, acc = jax.vmap(
        rejection_sample_row,
        in_axes=(None, None, 0, None, None, None, 0))(
        p_lg, q_lg, drafts, jnp.uint32(seed), jnp.uint32(1),
        jnp.uint32(0), jnp.arange(1, n + 1, dtype=jnp.int32))
    toks, acc = np.asarray(toks), np.asarray(acc)
    drafts = np.asarray(drafts)
    p = np.asarray(jax.nn.softmax(p_lg), np.float64)
    q = np.asarray(jax.nn.softmax(q_lg), np.float64)
    freq = np.bincount(toks, minlength=V) / n
    sigma = np.sqrt(0.25 / n)
    assert np.abs(freq - p).max() < 4.5 * sigma + 0.015
    resid = np.maximum(p - q, 0.0)
    rej = ~acc
    assert not np.any(toks[rej] == drafts[rej])
    assert np.all(resid[toks[rej]] > 0)


def _verify1(lg, toks, k_slot, *, seed=0, uid=1, pos=5, temperature=0.0,
             top_k=0, top_p=1.0):
    """One-slot wrapper over the batched verifier."""
    em, ne = verify_tokens(
        jnp.asarray(lg)[None], jnp.asarray(toks, jnp.int32)[None],
        jnp.asarray([k_slot], jnp.int32),
        jnp.asarray([seed], jnp.uint32), jnp.asarray([uid], jnp.uint32),
        jnp.asarray([0], jnp.uint32), jnp.asarray([pos], jnp.int32),
        jnp.asarray([temperature], jnp.float32),
        jnp.asarray([top_k], jnp.int32),
        jnp.asarray([top_p], jnp.float32))
    return np.asarray(em[0]), int(ne[0])


@SETTINGS
@given(st.sampled_from([4, 9, 33]), st.integers(0, 2**16),
       st.integers(1, 4))
def test_verify_greedy_is_exact_argmax(V, key, K):
    """Greedy verification is argmax-exact: perfect drafts fully accept
    and the emitted chain IS the per-row argmax chain; a poisoned draft
    stops acceptance at its row and the correction is that row's argmax
    — so a greedy spec stream can never diverge from plain decode."""
    lg = jax.random.normal(jax.random.PRNGKey(key), (K, V)) * 3.0
    lg = lg + jnp.arange(V) * 1e-3        # strict total order
    g = np.asarray(jnp.argmax(lg, -1), np.int32)
    toks = np.concatenate([[0], g[:K - 1]]).astype(np.int32)
    em, ne = _verify1(lg, toks, K)
    assert ne == K and (em[:K] == g).all()
    if K > 1:
        m = key % (K - 1)                 # poison the draft row m tests
        bad = toks.copy()
        bad[m + 1] = (g[m] + 1) % V
        em, ne = _verify1(lg, bad, K)
        assert ne == m + 1 and (em[:ne] == g[:ne]).all()


@SETTINGS
@given(st.sampled_from([8, 33]), st.integers(0, 2**16),
       st.integers(0, 2**16), st.integers(2, 4))
def test_verify_counter_keys_are_positional(V, key, seed, K):
    """Sampled verification is a pure function of the per-POSITION
    counter keys: repeated calls are bitwise identical, ``k_slot == 1``
    degenerates to exactly the sequential sampler's draw at ``pos+1``,
    the accepted prefix is the drafts verbatim, and a fully-accepted
    wave's bonus token equals the sequential draw at ``pos+K`` (so
    acceptance history never perturbs the stream's sample path)."""
    pos, temp = 11, 0.9
    lg = jax.random.normal(jax.random.PRNGKey(key), (K, V)) * 2.0
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(key + 1), (K,), 0, V), np.int32)

    def seq(row, at):
        return int(sample_tokens(
            lg[row][None], jnp.asarray([seed], jnp.uint32),
            jnp.asarray([1], jnp.uint32), jnp.asarray([0], jnp.uint32),
            jnp.asarray([at], jnp.int32),
            jnp.asarray([temp], jnp.float32),
            jnp.asarray([0], jnp.int32),
            jnp.asarray([1.0], jnp.float32))[0])

    em, ne = _verify1(lg, toks, 1, seed=seed, pos=pos, temperature=temp)
    assert ne == 1 and em[0] == seq(0, pos + 1)
    em1, ne1 = _verify1(lg, toks, K, seed=seed, pos=pos,
                        temperature=temp)
    em2, ne2 = _verify1(lg, toks, K, seed=seed, pos=pos,
                        temperature=temp)
    assert ne1 == ne2 and (em1 == em2).all()
    assert 1 <= ne1 <= K
    assert (em1[:ne1 - 1] == toks[1:ne1]).all()
    if ne1 == K:
        assert em1[K - 1] == seq(K - 1, pos + K)
    # force full acceptance: under top_k=1 the filtered distribution is
    # one-hot at the argmax, so argmax drafts are accepted with
    # probability exactly 1 — the full-accept bookkeeping (n_emit == K,
    # bonus row) is exercised on every example, not just by luck
    g = np.asarray(jnp.argmax(lg, -1), np.int32)
    perfect = np.concatenate([toks[:1], g[:K - 1]]).astype(np.int32)
    em3, ne3 = _verify1(lg, perfect, K, seed=seed, pos=pos,
                        temperature=temp, top_k=1)
    assert ne3 == K and (em3[:K] == g).all()


def test_different_seed_uid_or_pos_changes_the_stream():
    """The counter key really folds in all three of (seed, uid, pos):
    over a flat distribution, varying any one of them produces a
    different draw sequence."""
    V = 1024
    lg = jnp.zeros((V,))                  # uniform: draws expose the key
    base = [_draw(lg, seed=1, uid=2, pos=p) for p in range(16)]
    assert len(set(base)) > 1             # pos is folded in
    assert base != [_draw(lg, seed=3, uid=2, pos=p) for p in range(16)]
    assert base != [_draw(lg, seed=1, uid=4, pos=p) for p in range(16)]
    # and fixed (seed, uid, pos) is bitwise stable across processes/calls
    assert base == [_draw(lg, seed=1, uid=2, pos=p) for p in range(16)]
