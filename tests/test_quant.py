"""Quantizer properties (paper §2) — hypothesis-driven."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra; skip on minimal installs
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import quant

floats = st.floats(-10, 10, allow_nan=False, width=32)


@settings(max_examples=50, deadline=None)
@given(st.lists(floats, min_size=1, max_size=64))
def test_prop_weights_on_4_levels(ws):
    w = jnp.asarray(ws, jnp.float32)
    wq, codes = quant.quantize_weights_2b(w)
    scale = np.asarray(quant.weight_scale(w))
    lv = np.asarray(quant.W2B_LEVELS) * scale
    # every quantized weight is one of the 4 levels
    d = np.abs(np.asarray(wq)[:, None] - lv[None, :]).min(-1)
    assert d.max() < 1e-5
    assert int(np.asarray(codes).min()) >= 0
    assert int(np.asarray(codes).max()) <= 3
    # nearest-level projection (up to float ties at decision boundaries)
    best = np.abs(np.asarray(w)[:, None] - lv[None]).min(-1)
    got = np.abs(np.asarray(wq) - np.asarray(w))
    assert (got <= best + 1e-5 * (1 + np.abs(np.asarray(w)))).all()


def test_weight_ste_gradient_is_identity():
    w = jnp.asarray([-0.9, -0.2, 0.05, 0.4, 1.4])
    g = jax.grad(lambda w: jnp.sum(
        quant.quantize_weights_2b(w, 1.0)[0] * jnp.arange(5.0)))(w)
    np.testing.assert_allclose(np.asarray(g), np.arange(5.0), atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(floats)
def test_prop_hard_sigmoid(x):
    y = float(quant.hard_sigmoid(jnp.float32(x)))
    assert 0.0 <= y <= 1.0
    if x <= -3:
        assert y == 0.0
    if x >= 3:
        assert y == 1.0
    if -3 < x < 3:
        np.testing.assert_allclose(y, x / 6 + 0.5, atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.floats(0, 1, allow_nan=False, width=32))
def test_prop_z_quant_on_capacitor_grid(z):
    zq = float(quant.quantize_unit_6b(jnp.float32(z)))
    k = zq * quant.GATE_UNITS
    np.testing.assert_allclose(k, round(k), atol=1e-4)
    assert 0.0 <= zq <= 1.0
    assert abs(zq - z) <= 1.0 / quant.GATE_UNITS + 1e-6


def test_z_quant_endpoints():
    assert float(quant.quantize_unit_6b(jnp.float32(0.0))) == 0.0
    assert float(quant.quantize_unit_6b(jnp.float32(1.0))) == 1.0


@settings(max_examples=30, deadline=None)
@given(st.lists(floats, min_size=1, max_size=32))
def test_prop_bias_6b(bs):
    b = jnp.asarray(bs, jnp.float32)
    bq = np.asarray(quant.quantize_bias_6b(b))
    scale = max(np.abs(np.asarray(b)).max(), 1e-8) / 31.0
    codes = bq / scale
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)
    assert np.abs(codes).max() <= 31.01


def test_gate_bias_adc_grid():
    b = jnp.linspace(-5, 5, 101)
    bq = np.asarray(quant.quantize_gate_bias_adc(b))
    codes = bq / quant.ADC_GATE_BIAS_LSB
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert codes.min() >= -32 and codes.max() <= 31


def test_heaviside_forward_exact_and_surrogate_grad():
    x = jnp.asarray([-5.0, -0.5, 0.0, 0.5, 5.0])
    y = quant.heaviside_ste(x)
    np.testing.assert_array_equal(np.asarray(y), [0, 0, 0, 1, 1])
    g = jax.grad(lambda x: jnp.sum(quant.heaviside_ste(x)))(x)
    np.testing.assert_allclose(np.asarray(g),
                               [0, 1 / 6, 1 / 6, 1 / 6, 0], atol=1e-6)


def test_qat_phase_ladder_is_monotone_in_constraints():
    p = quant.QAT_PHASES
    assert not p[0].quantize_weights and not p[0].binary_output
    assert p[1].quantize_weights and not p[1].binary_output
    assert p[2].binary_output and not p[2].hard_sigmoid_gate
    assert p[3] == quant.QuantConfig.hardware()
