"""Fault-tolerant training loop: crash-restore, preemption checkpointing,
gradient compression invariants, data-pipeline resume determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import ShardedLoader, SyntheticLMDataset
from repro.models import build_model
from repro.optim import AdamW
from repro.optim.compress import compress_grads, init_error
from repro.train import TrainConfig, Trainer
from repro.train.fault_tolerance import FailureInjector, StragglerMonitor


def _trainer(tmp_path, steps=24, fail_at=(), **kw):
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32)
    loader = ShardedLoader(ds, global_batch=4)
    kw.setdefault("ckpt_every", 8)
    tcfg = TrainConfig(steps=steps, ckpt_dir=str(tmp_path),
                       log_every=1000, **kw)
    return Trainer(model, AdamW(lr=1e-3), tcfg, loader=loader,
                   failure_injector=FailureInjector(fail_at))


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, steps=25)
    tr.run()
    losses = [h["loss"] for h in tr.history]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_crash_restores_and_continues(tmp_path):
    tr = _trainer(tmp_path, steps=20, fail_at=(13,))
    params, step = tr.run()
    assert step == 20
    # the step re-ran after restore: history contains step 13 at least twice
    steps_seen = [h["step"] for h in tr.history]
    assert steps_seen.count(13) >= 1
    assert tr.ckpt.latest_step() == 20


def test_resume_from_checkpoint_is_deterministic(tmp_path):
    """Running 0..16 in one go == running 0..8, 'restarting', 8..16."""
    tr1 = _trainer(tmp_path / "a", steps=16)
    p1, _ = tr1.run()
    tr2a = _trainer(tmp_path / "b", steps=8, ckpt_every=8)
    tr2a.run()
    tr2b = _trainer(tmp_path / "b", steps=16)
    p2, _ = tr2b.run()
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_too_many_failures_raises(tmp_path):
    tr = _trainer(tmp_path, steps=20, fail_at=(3, 4, 5, 6, 7),
                  max_failures=2)
    with pytest.raises(RuntimeError):
        tr.run()


def test_grad_compress_training_works(tmp_path):
    tr = _trainer(tmp_path, steps=20, grad_compress=True)
    tr.run()
    losses = [h["loss"] for h in tr.history]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    """accum(k=2) over the same tokens ≈ one big batch (same grads up to
    loss-mean nonlinearity of metrics)."""
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    from repro.train.loop import build_train_step
    opt = AdamW(lr=1e-2, max_grad_norm=None)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16)
    batch = jax.tree_util.tree_map(jnp.asarray, ds.sample(4, 0))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    aux = {"ef_error": {}}

    full = build_train_step(model, opt)
    acc = build_train_step(model, opt, microbatch=2)
    p1, *_ = full(params, opt_state, aux, batch)
    p2, *_ = acc(params, opt.init(params), aux, batch)
    # Adam normalizes by sqrt(v): float reordering in the accumulation can
    # flip near-zero grads, moving a param by up to ~2·lr.  Require the bulk
    # to match tightly and all within the 2·lr envelope.
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        d = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
        assert (d < 5e-3).mean() > 0.995, d.max()
        assert d.max() < 2.5e-2


# ---------------------------------------------------------------------------
def test_error_feedback_invariant():
    """EF compression: cumulative dequantized == cumulative true grads + e_T
    (no gradient information is lost, only delayed)."""
    k = jax.random.PRNGKey(0)
    g_seq = [jax.random.normal(jax.random.fold_in(k, i), (32,)) * (0.1 + i)
             for i in range(10)]
    err = init_error({"w": g_seq[0]})
    sent_total = jnp.zeros((32,))
    for g in g_seq:
        sent, err = compress_grads({"w": g}, err)
        sent_total = sent_total + sent["w"]
    true_total = sum(g_seq)
    np.testing.assert_allclose(np.asarray(sent_total + err["w"]),
                               np.asarray(true_total), atol=1e-4)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=3.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 1.0)        # 10× median -> straggler
    assert not mon.record(11, 0.12)
    assert len(mon.flagged) == 1


def test_data_pipeline_determinism_and_host_sharding():
    ds = SyntheticLMDataset(vocab=100, seq_len=16)
    a = ShardedLoader(ds, global_batch=8, host_id=0, num_hosts=2)
    b = ShardedLoader(ds, global_batch=8, host_id=1, num_hosts=2)
    a1, a2 = a.batch_at(3), a.batch_at(3)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # deterministic
    assert a.host_batch == 4
    assert not np.array_equal(a1["tokens"], b.batch_at(3)["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a1["tokens"][:, 1:], a1["labels"][:, :-1])
