"""Property suite for the int8 per-page KV quantizer
(repro.kernels.paged_attention.quant) and the engine behaviours built on
it (decode-write scale monotonicity, COW fork bit-exactness).

Pinned properties:

  * round-trip: |x - deq(quant(x))| <= 0.5 * scale elementwise (the
    symmetric round-to-nearest grid's half-LSB bound), for every page
    and feature row independently;
  * scale positivity: page_abs_scale >= MIN_SCALE > 0 always, including
    all-zero pages (which round-trip to exact zeros);
  * symmetry: quant(-x) == -quant(x) (codes), so dequant is odd — the
    reason the KV grid follows the paper's symmetric DAC convention and
    not the ADC's two's-complement grid (see core.quant grid notes);
  * code range: codes in [-127, 127]; -128 never emitted;
  * rescale identity: rescale_codes(c, s, s) == c bitwise (steady-state
    decode writes never perturb stored pages), and growing the scale
    re-expresses codes within the same half-LSB bound;
  * requantize idempotence: quantizing the dequantized view of a
    quantized page reproduces the codes bit-exactly (a quantized page
    has max|code| == QMAX unless all-zero, so absmax/QMAX returns the
    same scale) — this is what makes prefix-cache attach rewrites safe;
  * COW fork: copying a page's codes and scale row preserves the
    dequantized view bit-exactly (pages are (codes, scale) units).

Runs under hypothesis when available (shrinks failing cases); the
container always runs the seeded fallback over many draws.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.paged_attention import quant

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

P, PS, KV, HD = 5, 4, 2, 8     # GQA-shaped pool (P, ps, KV, hd)


def _pool(rng, magnitude):
    x = rng.standard_normal((P, PS, KV, HD)).astype(np.float32)
    return x * magnitude


def check_roundtrip(x):
    xj = jnp.asarray(x)
    sc = quant.page_abs_scale(xj)
    codes = quant.quantize(xj, sc)
    deq = quant.dequantize(codes, sc)
    sc_np = np.asarray(sc)                         # (P, KV)
    assert (sc_np >= quant.MIN_SCALE).all()
    c = np.asarray(codes)
    assert c.min() >= -quant.QMAX and c.max() <= quant.QMAX
    # elementwise half-LSB bound, each (page, kv) row under ITS scale
    err = np.abs(np.asarray(deq) - x)
    bound = 0.5 * sc_np[:, None, :, None] * (1 + 1e-6)
    assert (err <= bound).all(), float((err - bound).max())
    # symmetry: quant(-x) == -quant(x)
    neg = np.asarray(quant.quantize(jnp.asarray(-x), sc))
    np.testing.assert_array_equal(neg, -c)
    # rescale identity at equal scales — bitwise
    same = np.asarray(quant.rescale_codes(codes, sc, sc))
    np.testing.assert_array_equal(same, c)
    # requantize idempotence: codes hit QMAX per row (or the row is all
    # zero), so absmax/QMAX of the dequantized view returns the scale
    sc2 = quant.page_abs_scale(deq)
    codes2 = np.asarray(quant.quantize(deq, sc2))
    np.testing.assert_array_equal(codes2, c)
    # growing the scale re-expresses codes within the new grid's LSB
    grown = sc * 1.7
    re = quant.rescale_codes(codes, sc, grown)
    err2 = np.abs(np.asarray(quant.dequantize(re, grown)) -
                  np.asarray(deq))
    bound2 = 0.5 * np.asarray(grown)[:, None, :, None] * (1 + 1e-6)
    assert (err2 <= bound2).all()


def test_roundtrip_seeded_sweep():
    rng = np.random.default_rng(0)
    for mag in (1e-6, 1e-2, 1.0, 37.0, 1e4):
        for _ in range(8):
            check_roundtrip(_pool(rng, mag))


def test_all_zero_page_is_invertible():
    x = np.zeros((P, PS, KV, HD), np.float32)
    sc = quant.page_abs_scale(jnp.asarray(x))
    assert (np.asarray(sc) == quant.MIN_SCALE).all()
    codes = quant.quantize(jnp.asarray(x), sc)
    np.testing.assert_array_equal(np.asarray(codes), 0)
    np.testing.assert_array_equal(
        np.asarray(quant.dequantize(codes, sc)), 0.0)


def test_fresh_page_rescale_zeroes_stale_tenant():
    """The decode write path passes old_scale=0 for a page's first
    token: every stale code rescales to 0 (ratio 0), so the previous
    tenant's data never leaks through a recycled page."""
    rng = np.random.default_rng(1)
    x = _pool(rng, 5.0)
    sc = quant.page_abs_scale(jnp.asarray(x))
    codes = quant.quantize(jnp.asarray(x), sc)
    zero = jnp.zeros_like(sc)
    wiped = quant.rescale_codes(codes, zero, sc)
    np.testing.assert_array_equal(np.asarray(wiped), 0)


def test_mla_page_axis_shapes():
    """MLA latent pools (P, ps, r): one scale per page, page_axis=1,
    same bound."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((P, PS, 16)).astype(np.float32) * 3
    sc = quant.page_abs_scale(jnp.asarray(x))
    assert sc.shape == (P,)
    deq = np.asarray(quant.dequantize(quant.quantize(jnp.asarray(x), sc),
                                      sc))
    assert (np.abs(deq - x)
            <= 0.5 * np.asarray(sc)[:, None, None] * (1 + 1e-6)).all()


def test_cow_fork_is_bit_exact():
    """A COW page copy moves (codes row, scale row) as one unit: the
    fork's dequantized view equals the parent's bitwise — mirrors
    DecoderStepModel.copy_pages, which copies every pool leaf (codes AND
    <key>_scale) page-for-page."""
    rng = np.random.default_rng(3)
    x = _pool(rng, 2.0)
    sc = quant.page_abs_scale(jnp.asarray(x))
    codes = quant.quantize(jnp.asarray(x), sc)
    src, dst = 1, 4
    codes2 = codes.at[dst].set(codes[src])
    sc2 = sc.at[dst].set(sc[src])
    a = np.asarray(quant.dequantize(codes, sc))[src]
    b = np.asarray(quant.dequantize(codes2, sc2))[dst]
    np.testing.assert_array_equal(a, b)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_roundtrip_hypothesis():
    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 2**32 - 1),
           st.floats(1e-6, 1e5, allow_nan=False, allow_infinity=False))
    def run(seed, mag):
        check_roundtrip(_pool(np.random.default_rng(seed), mag))

    run()
