"""Exact-value pins for the two DELIBERATELY different signed 6 b code
grids (paper §3.1.2, Fig. 3C) — the reconciliation the grid notes in
core.quant document:

  * quantize_bias_6b — SYMMETRIC [-31, +31] (63 live codes): the
    weight/bias DAC's segmented bank straddles zero symmetrically, so
    code -32 is never emitted and quantize(-x) == -quantize(x) exactly;
  * quantize_gate_bias_adc — full TWO'S-COMPLEMENT [-32, +31] on the
    fixed grid LSB = 6/63: the ADC preset is a plain signed 6 b
    register, so the asymmetric -32 code physically exists (one extra
    step of negative bias range) and symmetry breaks at that edge.

The serving int8 KV quantizer (kernels.paged_attention.quant) follows
the symmetric convention with QMAX = 127 mirroring the 31 here; its
half-LSB/symmetry properties are pinned in
tests/test_paged_quant_properties.py.  No hypothesis dependency: these
exact pins must run on minimal installs too.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels.paged_attention import quant as kvq


def test_bias_6b_grid_is_symmetric_63_codes():
    lsb = 1.0 / 31.0                        # absmax=1 -> scale = 1/31
    b = jnp.asarray(np.arange(-31, 32) * lsb, jnp.float32)
    bq = np.asarray(quant.quantize_bias_6b(b, scale=lsb))
    codes = np.round(bq / lsb).astype(int)
    np.testing.assert_array_equal(codes, np.arange(-31, 32))
    assert len(set(codes.tolist())) == 63   # 63 live codes out of 64
    # code -32 is never emitted: values past the negative edge clip to -31
    deep = jnp.asarray([-40.0 * lsb, -31.49 * lsb], jnp.float32)
    dq = np.asarray(quant.quantize_bias_6b(deep, scale=lsb))
    np.testing.assert_allclose(dq, [-31 * lsb, -31 * lsb], rtol=1e-6)
    # exact odd symmetry on the whole grid
    neg = np.asarray(quant.quantize_bias_6b(-b, scale=lsb))
    np.testing.assert_array_equal(neg, -bq)
    # default scale = absmax/31: the extreme values are reproduced exactly
    ends = jnp.asarray([1.0, -1.0], jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quant.quantize_bias_6b(ends)), [1.0, -1.0])


def test_gate_bias_adc_grid_is_twos_complement():
    lsb = quant.ADC_GATE_BIAS_LSB
    assert lsb == 6.0 / 63.0                # fixed by the ADC, not absmax
    # code -32 EXISTS: -32*LSB is representable exactly...
    v = jnp.asarray([-32.0 * lsb], jnp.float32)
    np.testing.assert_allclose(
        np.asarray(quant.quantize_gate_bias_adc(v)), [-32 * lsb],
        rtol=1e-6)
    # ...values below it clip to -32, values above +31 clip to +31
    edges = jnp.asarray([-40.0 * lsb, 40.0 * lsb], jnp.float32)
    eq = np.asarray(quant.quantize_gate_bias_adc(edges))
    np.testing.assert_allclose(eq, [-32 * lsb, 31 * lsb], rtol=1e-6)
    # symmetry therefore BREAKS exactly at the -32 edge (and only there)
    x = jnp.asarray([32.0 * lsb], jnp.float32)
    a = float(quant.quantize_gate_bias_adc(x)[0])      # clips to +31
    b = float(quant.quantize_gate_bias_adc(-x)[0])     # lands on -32
    np.testing.assert_allclose([a, b], [31 * lsb, -32 * lsb], rtol=1e-6)
    assert abs(a + b) > 0.5 * lsb           # |a| != |b|: one-code gap
    # full sweep stays on the 64-code grid
    sweep = jnp.asarray(np.linspace(-5, 5, 1001), jnp.float32)
    codes = np.round(np.asarray(quant.quantize_gate_bias_adc(sweep))
                     / lsb).astype(int)
    assert codes.min() == -32 and codes.max() == 31


def test_int8_kv_grid_mirrors_symmetric_convention():
    """QMAX=127 of the int8 range <-> 31 of the 6 b range: same
    symmetric grid family; -128 plays the role of the never-emitted
    -32."""
    x = jnp.asarray(np.linspace(-3, 3, 101, dtype=np.float32)
                    .reshape(1, 101, 1))
    sc = kvq.page_abs_scale(x)
    codes = np.asarray(kvq.quantize(x, sc))
    assert codes.min() == -kvq.QMAX and codes.max() == kvq.QMAX
    assert kvq.QMAX == 127                  # -128 never emitted
    neg = np.asarray(kvq.quantize(-x, sc))
    np.testing.assert_array_equal(neg, -codes)
