"""Scheduler/state layer: policy ordering, SlotTable lifecycle, the
submit() scheduling-field validation, cancel-of-queued, and stats().

Policy decisions are host-side list manipulation over the SlotTable —
deterministic (uid tie-breaks everywhere) and invisible to jit, so the
unit half of this suite runs with no model at all.  The engine-level
half pins the load-bearing contracts: ``policy="fifo"`` reproduces the
legacy admission byte for byte, and NO policy ever changes a request's
token stream (scheduling moves requests in time, the counter-based PRNG
keeps their bytes) — only completion ORDER moves.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SamplingParams, get_config
from repro.models import build_model
from repro.serve import (DecoderStepModel, EDFPolicy, FIFOPolicy,
                         PagedConfig, PagePool, PriorityPolicy, Request,
                         ServeEngine, SJFPolicy, SlotTable, make_policy)


def _req(uid, plen=4, gen=4, **kw):
    return Request(uid, np.zeros(plen, np.int32), gen, **kw)


# ---------------------------------------------------------------------------
# policy units (no model, no jit)
# ---------------------------------------------------------------------------

def test_fifo_admit_order_is_arrival_order():
    tab = SlotTable(4)
    reqs = [_req(u, priority=p) for u, p in
            [(0, 9), (1, 0), (2, 5), (3, 7)]]
    tab.waiting.extend(reqs)
    order = FIFOPolicy().admit_order(tab.waiting, tab)
    assert [r.uid for r in order] == [0, 1, 2, 3]   # priorities ignored
    assert FIFOPolicy().select_victim(tab) is None


def test_priority_order_deterministic_under_shuffle():
    """Same submitted set -> same order, whatever the arrival shuffle;
    higher priority first, uid breaks ties inside a class."""
    base = [(0, 1), (1, 3), (2, 3), (3, 0), (4, 1)]
    want = [1, 2, 0, 4, 3]
    pol = PriorityPolicy()
    rng = np.random.default_rng(0)
    for _ in range(5):
        tab = SlotTable(4)
        perm = rng.permutation(len(base))
        tab.waiting.extend(_req(u, priority=p)
                           for u, p in [base[i] for i in perm])
        assert [r.uid for r in pol.admit_order(tab.waiting, tab)] == want


def test_sjf_orders_by_prefill_cost_with_uid_tiebreak():
    tab = SlotTable(4)
    tab.waiting.extend([_req(0, plen=9), _req(1, plen=2), _req(2, plen=9),
                        _req(3, plen=5)])
    pol = SJFPolicy(aging=1.0)
    pol.begin_round(tab)
    assert [r.uid for r in pol.admit_order(tab.waiting, tab)] \
        == [1, 3, 0, 2]
    with pytest.raises(ValueError, match="aging"):
        SJFPolicy(aging=0.0)


def test_sjf_aging_bound():
    """A P-token prompt outranks ANY fresh newcomer after at most
    ceil((P - 1) / aging) rounds — the starvation bound.  Here P=10,
    aging=1: by round 9 the old prompt's effective cost has decayed to
    the newcomer's and its lower uid wins the tie."""
    P = 10
    pol = SJFPolicy(aging=1.0)
    tab = SlotTable(2)
    old = _req(0, plen=P)
    tab.waiting.append(old)
    uid, rounds = 1, None
    for rnd in range(P + 3):
        pol.begin_round(tab)
        tab.waiting.append(_req(uid, plen=1))   # fresh 1-token rival
        uid += 1
        head = pol.admit_order(tab.waiting, tab)[0]
        if head is old:
            rounds = rnd
            break
        tab.pop_waiting(head)                   # rival admits, old waits
    assert rounds is not None and rounds <= P - 1


def test_sjf_resumed_requests_have_zero_prefill_cost():
    """A preempted request's pages re-seed from host bytes — no prefill
    left — so SJF re-admits it ahead of fresh prompts."""
    tab = SlotTable(2)
    preempted = _req(5, plen=50)
    preempted.snapshot = {"n_pages": 1}         # any non-None marker
    tab.waiting.extend([_req(1, plen=2), preempted])
    pol = SJFPolicy()
    pol.begin_round(tab)
    assert pol.admit_order(tab.waiting, tab)[0] is preempted


def test_priority_select_victim_strict_gap_only():
    """Victim = the lowest-priority (then youngest) RUNNING slot, and
    only when the blocked head outranks it STRICTLY — equal-priority
    traffic never thrashes."""
    pool = PagePool(8, 2, 4)
    tab = SlotTable(2, pool=pool, pages_for_req=lambda r: 4)
    for uid, prio in [(0, 1), (1, 0)]:
        s = tab.alloc_slot()
        pool.reserve(s, 4)
        r = _req(uid, priority=prio)
        tab.slot_req[s] = r
        tab.active[s] = True
    pol = PriorityPolicy()
    assert pol.select_victim(tab) is None       # nothing waiting
    tab.waiting.append(_req(2, priority=5))
    assert pol.select_victim(tab) == 1          # slot 1: priority 0 < 5
    for s in (0, 1):                            # equal priority: no gap
        tab.slot_req[s].priority = 5
    assert pol.select_victim(tab) is None
    tab.slot_req[0].priority, tab.slot_req[1].priority = 1, 0
    assert pol.select_victim(tab) == 1          # gap is back
    assert PriorityPolicy(preempt=False).select_victim(tab) is None
    # unpaged state: eviction has no page swap to make it cheap -> None
    tab2 = SlotTable(2)
    tab2.waiting.append(_req(9, priority=5))
    assert pol.select_victim(tab2) is None


def test_priority_select_victim_only_when_eviction_can_unblock():
    """Naming a victim whose eviction cannot (even cumulatively) free
    enough pages for the blocked head would discard decode work and
    admit nothing — the policy must return None instead."""
    pool = PagePool(8, 2, 8)
    tab = SlotTable(2, pool=pool,
                    pages_for_req=lambda r: int(r.max_new_tokens))
    for uid, prio in [(0, 9), (1, 0)]:
        s = tab.alloc_slot()
        pool.reserve(s, 4)
        tab.slot_req[s] = _req(uid, gen=4, priority=prio)
        tab.active[s] = True
    pol = PriorityPolicy()
    head = _req(2, gen=8, priority=5)
    tab.waiting.append(head)
    # the only strictly-lower running slot (1) frees 4 pages; the head
    # needs 8 and nothing is unreserved -> eviction cannot unblock it
    assert pol.select_victim(tab) is None
    head.max_new_tokens = 4                     # slot 1's 4 pages suffice
    assert pol.select_victim(tab) == 1
    # cumulative progress: both running slots outranked -> their summed
    # reservations (4 + 4) cover the head's 8, one eviction at a time
    head.max_new_tokens = 8
    tab.slot_req[0].priority = 1
    assert pol.select_victim(tab) == 1


def test_edf_orders_by_deadline_none_last():
    """Earliest deadline first; no-deadline requests sort last (+inf);
    uid breaks ties inside a deadline class — deterministic under any
    arrival shuffle."""
    base = [(0, 9.0), (1, None), (2, 3.0), (3, 9.0), (4, None)]
    want = [2, 0, 3, 1, 4]
    pol = EDFPolicy()
    rng = np.random.default_rng(0)
    for _ in range(5):
        tab = SlotTable(4)
        perm = rng.permutation(len(base))
        tab.waiting.extend(_req(u, deadline=d)
                           for u, d in [base[i] for i in perm])
        assert [r.uid for r in pol.admit_order(tab.waiting, tab)] == want


def test_edf_select_victim_latest_deadline_strict_gap():
    """Victim = the latest-deadline running slot (no-deadline runners
    are +inf: first out), only on a STRICT gap — equal deadlines never
    thrash and a no-deadline head never preempts anyone."""
    pool = PagePool(8, 2, 4)
    tab = SlotTable(2, pool=pool, pages_for_req=lambda r: 4)
    for uid, dl in [(0, 5.0), (1, None)]:
        s = tab.alloc_slot()
        pool.reserve(s, 4)
        tab.slot_req[s] = _req(uid, deadline=dl)
        tab.active[s] = True
    pol = EDFPolicy()
    assert pol.select_victim(tab) is None       # nothing waiting
    tab.waiting.append(_req(2, deadline=2.0))
    assert pol.select_victim(tab) == 1          # best-effort slot first
    tab.slot_req[1].deadline = 2.0              # equal to head: no gap
    assert pol.select_victim(tab) == 0          # 5.0 is still later
    tab.slot_req[0].deadline = 2.0              # all equal: no victim
    assert pol.select_victim(tab) is None
    tab.waiting[0].deadline = None              # no-deadline head never
    tab.slot_req[0].deadline = 9.0              # preempts a dated runner
    assert pol.select_victim(tab) is None
    assert EDFPolicy(preempt=False).select_victim(tab) is None
    # unpaged state: eviction has no page swap to make it cheap -> None
    tab2 = SlotTable(2)
    tab2.waiting.append(_req(9, deadline=1.0))
    assert pol.select_victim(tab2) is None


def test_edf_select_victim_only_when_eviction_can_unblock():
    """Same cumulative-unblock guard as priority: no victim is named
    when even evicting every later-deadline runner cannot free enough
    pages for the blocked head."""
    pool = PagePool(8, 2, 8)
    tab = SlotTable(2, pool=pool,
                    pages_for_req=lambda r: int(r.max_new_tokens))
    for uid, dl in [(0, 1.0), (1, 50.0)]:
        s = tab.alloc_slot()
        pool.reserve(s, 4)
        tab.slot_req[s] = _req(uid, gen=4, deadline=dl)
        tab.active[s] = True
    pol = EDFPolicy()
    head = _req(2, gen=8, deadline=10.0)
    tab.waiting.append(head)
    # only slot 1 (deadline 50 > 10) is evictable; it frees 4 of the 8
    # the head needs and nothing is unreserved -> no victim
    assert pol.select_victim(tab) is None
    head.max_new_tokens = 4                     # slot 1's 4 pages suffice
    assert pol.select_victim(tab) == 1
    # cumulative progress: both runners outranked -> 4 + 4 cover the 8
    head.max_new_tokens = 8
    tab.slot_req[0].deadline = 20.0
    assert pol.select_victim(tab) == 1          # latest deadline first


def test_make_policy_names_and_instances():
    assert isinstance(make_policy("fifo"), FIFOPolicy)
    assert isinstance(make_policy("priority"), PriorityPolicy)
    assert isinstance(make_policy("sjf"), SJFPolicy)
    assert isinstance(make_policy("edf"), EDFPolicy)
    pol = SJFPolicy(aging=2.0)
    assert make_policy(pol) is pol
    with pytest.raises(ValueError, match="policy must be one of"):
        make_policy("lifo")


def test_slot_table_discard_waiting_identity_only():
    """Cancel path: only the SAME object leaves the queue — a lookalike
    (equal prompt bytes) must not be dequeued."""
    tab = SlotTable(2)
    a, b = _req(0), _req(1)
    lookalike = _req(0)
    tab.waiting.extend([a, b])
    assert not tab.discard_waiting(lookalike)
    assert tab.discard_waiting(a)
    assert list(tab.waiting) == [b]
    assert not tab.discard_waiting(a)           # already gone


# ---------------------------------------------------------------------------
# submit() scheduling-field validation + cancel-of-queued (satellites)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gqa():
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _engine(cfg, model, params, *, policy="fifo", slots=2, max_len=32,
            num_pages=0, page_size=4, impl="gather"):
    m = build_model(dataclasses.replace(cfg, paged_impl=impl)) \
        if impl else model
    sm = DecoderStepModel(m, max_len=max_len, prefill_chunk=8,
                          kv_layout="paged",
                          paged=PagedConfig(page_size=page_size,
                                            num_pages=num_pages))
    return ServeEngine(sm, params, slots=slots, policy=policy), sm


def test_submit_validates_priority_and_deadline(gqa):
    """Satellite: bad scheduling fields die at submit() with a clear
    ValueError — not deep inside a policy comparison or an int32 slot
    array — and a failed submit leaves the queue (and uid counter)
    untouched."""
    cfg, model, params = gqa
    eng, _ = _engine(cfg, model, params)
    prompt = np.arange(4)
    for bad in [1.5, "high", None, True, 2**31, -2**31 - 1]:
        with pytest.raises(ValueError, match="priority"):
            eng.submit(prompt, max_new_tokens=2, priority=bad)
    for bad in [0.0, -3.0, float("nan"), float("inf"), "soon", True]:
        with pytest.raises(ValueError, match="deadline"):
            eng.submit(prompt, max_new_tokens=2, deadline=bad)
    assert not eng.waiting
    ok = eng.submit(prompt, max_new_tokens=2, priority=3, deadline=1.5)
    assert ok.uid == 0                       # failed submits burned no uid
    assert ok.priority == 3 and ok.deadline == 1.5
    r2 = eng.submit(prompt, max_new_tokens=2,
                    priority=np.int32(2), deadline=np.float64(9.0))
    assert r2.priority == 2                  # numpy scalars accepted
    eng.run()


def test_cancel_queued_request_never_touches_pool(gqa):
    """Satellite: cancelling a never-admitted request removes it from
    the queue and provably leaves the page pool alone (a queued request
    holds no slot, pages or reservation)."""
    cfg, model, params = gqa
    eng, _ = _engine(cfg, model, params, slots=1, num_pages=8)
    rng = np.random.default_rng(0)
    a = eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=20)
    b = eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=4)
    eng.step()                               # a admits; b deferred (slots)
    assert b in eng.waiting
    fp = (eng.pool.block_tables.copy(), eng.pool.chain_len.copy(),
          eng.pool.refcount.copy(), list(eng.pool._free),
          eng.pool.reserved_total)
    eng.cancel(b)
    assert b.cancelled and b.finished and b not in eng.waiting
    assert (eng.pool.block_tables == fp[0]).all()
    assert (eng.pool.chain_len == fp[1]).all()
    assert (eng.pool.refcount == fp[2]).all()
    assert eng.pool._free == fp[3] and eng.pool.reserved_total == fp[4]
    eng.run()
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


# ---------------------------------------------------------------------------
# engine-level policy contracts
# ---------------------------------------------------------------------------

LENS = [(5, 4), (13, 6), (3, 3), (9, 5)]
SPS = [None, dict(temperature=0.9, top_k=12, seed=3), None,
       dict(temperature=1.2, top_p=0.8, seed=5)]
PRIOS = [0, 0, 5, 1]
DLS = [None, None, 1.0, 50.0]


def _run_policy(cfg, model, params, policy, *, slots=2):
    eng, sm = _engine(cfg, model, params, policy=policy, slots=slots)
    rng = np.random.default_rng(1)
    reqs = []
    for i, (p, g) in enumerate(LENS):
        sp = SamplingParams(**SPS[i]) if SPS[i] else None
        reqs.append(eng.submit(rng.integers(0, cfg.vocab, size=p),
                               max_new_tokens=g, sampling=sp,
                               priority=PRIOS[i], deadline=DLS[i]))
    done = eng.run()
    assert sm._jit_step._cache_size() == 1
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0
    return [list(r.tokens) for r in reqs], [r.uid for r in done], eng


def test_policies_move_requests_in_time_never_in_bytes(gqa):
    """The load-bearing contract: fifo/priority/sjf produce IDENTICAL
    per-request token streams (the counter-based PRNG keys on
    (seed, uid, pos), so when a request runs cannot change what it
    says); only completion order moves.  fifo == the legacy admission:
    under 2 slots the first two arrivals admit first, so uid 2 (the
    high-priority short request) finishes last of the first three under
    fifo but is boosted by both priority (class 5) and sjf (3-token
    prompt)."""
    cfg, model, params = gqa
    fifo_toks, fifo_order, _ = _run_policy(cfg, model, params, "fifo")
    prio_toks, prio_order, _ = _run_policy(cfg, model, params,
                                           "priority")
    sjf_toks, sjf_order, _ = _run_policy(cfg, model, params, "sjf")
    edf_toks, edf_order, _ = _run_policy(cfg, model, params, "edf")
    assert fifo_toks == prio_toks == sjf_toks == edf_toks
    assert fifo_order.index(2) > 0           # fifo: uid 2 waits its turn
    assert prio_order[0] == 2                # priority: class 5 first out
    assert sjf_order[0] == 2                 # sjf: shortest prompt first
    assert edf_order[0] == 2                 # edf: tightest deadline
    assert fifo_order != prio_order


def test_fifo_defer_at_head_no_bypass(gqa):
    """fifo reproduces the legacy head-of-line rule: when the head
    cannot reserve, smaller requests behind it do NOT bypass (that is
    sjf's job)."""
    cfg, model, params = gqa
    rng = np.random.default_rng(2)
    eng, _ = _engine(cfg, model, params, slots=3, max_len=24,
                     num_pages=7)
    a = eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=16)
    b = eng.submit(rng.integers(0, cfg.vocab, 10), max_new_tokens=14)
    c = eng.submit(rng.integers(0, cfg.vocab, 3), max_new_tokens=2)
    eng.step()
    # a holds 6 pages of 7; b (head, needs 6) defers; c (needs 2) must
    # NOT slip past it even though one page is free
    assert int(eng.active.sum()) == 1
    assert list(eng.waiting) == [b, c]
    eng.run()
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


def test_stall_diagnostic_names_policy_head(gqa):
    """run()'s deadlock error reports the POLICY-ordered head — under
    priority the blocked request is the highest waiting class, not
    waiting[0]."""
    cfg, model, params = gqa
    eng, _ = _engine(cfg, model, params, policy="priority")
    eng.pool.reserve(1, eng.pool.num_pages)     # simulate a leaked hold
    rng = np.random.default_rng(5)
    low = eng.submit(rng.integers(0, cfg.vocab, 6), max_new_tokens=4)
    high = eng.submit(rng.integers(0, cfg.vocab, 4), max_new_tokens=4,
                      priority=5)
    assert eng.waiting[0] is low                # arrival order differs
    with pytest.raises(RuntimeError, match=f"uid={high.uid} "):
        eng.run()


def test_stats_snapshot_and_verbose_run(gqa, capsys):
    """Satellite: stats() reports occupancy / queue / pool pages /
    preemptions, and run(verbose=True) emits one line per step."""
    cfg, model, params = gqa
    eng, _ = _engine(cfg, model, params, slots=2, num_pages=12)
    rng = np.random.default_rng(3)
    s0 = eng.stats()
    assert s0.active_slots == 0 and s0.queue_depth == 0
    assert s0.pages_in_use == 0 and s0.pages_free == 12
    assert s0.policy == "fifo" and s0.utilization == 0.0
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, 6), max_new_tokens=6)
    eng.step()
    s1 = eng.stats()
    assert s1.active_slots == 2 and s1.queue_depth == 1
    assert s1.pages_in_use == eng.pool.pages_in_use > 0
    assert s1.pages_reserved == eng.pool.reserved_total > 0
    assert s1.pages_free == len(eng.pool._free)
    assert s1.n_steps == 1 and s1.n_preemptions == 0
    assert 0.0 < s1.utilization <= 1.0
    assert eng.utilization == s1.utilization   # legacy readout survives
    eng.run(verbose=True)
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines() if ln.startswith("[fifo")]
    assert len(lines) == eng.n_steps - 1       # one line per driven step
    assert "queue" in lines[0] and "pages" in lines[0]
    s2 = eng.stats()
    assert s2.active_slots == 0 and s2.pages_in_use == 0
