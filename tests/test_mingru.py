"""minGRU / MINIMALIST network behaviour (paper §2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.mingru import MinGRUBlock, MinimalistNetwork


@pytest.mark.parametrize("mode", ["float", "quantized", "hardware"])
def test_parallel_scan_equals_stepwise(mode):
    """Training-time parallel evaluation == recurrent inference, for all
    three Fig.-5 model variants."""
    qcfg = getattr(quant.QuantConfig,
                   {"float": "float_baseline", "quantized": "quantized",
                    "hardware": "hardware"}[mode])()
    net = MinimalistNetwork((3, 6, 4), qcfg=qcfg)
    key = jax.random.PRNGKey(0)
    params = net.init(key)
    B, T = 2, 12
    x = (jax.random.uniform(jax.random.fold_in(key, 5), (B, T, 3)) > 0.5
         ).astype(jnp.float32)
    logits = net(params, x)

    states = net.initial_state(B)
    out = None
    for t in range(T):
        out, states = net.step(params, x[:, t, :], states)
    np.testing.assert_allclose(np.asarray(states[-1]), np.asarray(logits),
                               atol=1e-5)


def test_block_gate_zero_keeps_state():
    """z == 0 ⇒ h unchanged (the 'untouched capacitor bank' case)."""
    blk = MinGRUBlock(4, 4)
    params = blk.init(jax.random.PRNGKey(0))
    params = dict(params, bz=jnp.full((4,), -1e9))  # σ(−inf) = 0
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 9, 4))
    h0 = jnp.ones((1, 4))
    _, h = blk(params, x, h0=h0)
    np.testing.assert_allclose(np.asarray(h), 1.0, atol=1e-6)


def test_block_gate_one_overwrites_state():
    """z == 1 ⇒ h = h̃ (full capacitor swap)."""
    blk = MinGRUBlock(4, 4)
    params = blk.init(jax.random.PRNGKey(0))
    params = dict(params, bz=jnp.full((4,), 1e9))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 9, 4))
    _, h = blk(params, x, h0=jnp.zeros((1, 4)))
    htilde = x @ params["wh"] + params["bh"]
    np.testing.assert_allclose(np.asarray(h), np.asarray(htilde), atol=1e-5)


def test_hardware_mode_is_trainable():
    """Gradients flow through all STE quantizers (the QAT requirement)."""
    qcfg = quant.QuantConfig.hardware()
    net = MinimalistNetwork((2, 8, 3), qcfg=qcfg)
    key = jax.random.PRNGKey(0)
    params = net.init(key)
    x = (jax.random.uniform(key, (4, 20, 2)) > 0.5).astype(jnp.float32)
    y = jnp.array([0, 1, 2, 0])

    def loss(p):
        logits = net(p, x)
        return -jnp.take_along_axis(
            jax.nn.log_softmax(logits), y[:, None], -1).mean()

    g = jax.grad(loss)(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    total = sum(float(jnp.abs(l).sum()) for l in leaves)
    assert total > 0.0


def test_binary_outputs_are_binary():
    net = MinimalistNetwork((2, 5, 3), qcfg=quant.QuantConfig.hardware())
    params = net.init(jax.random.PRNGKey(0))
    x = (jax.random.uniform(jax.random.PRNGKey(1), (2, 7, 2)) > 0.5
         ).astype(jnp.float32)
    _, tr = net(params, x, collect_traces=True)
    out0 = np.asarray(tr["block0"]["out"])
    assert set(np.unique(out0)).issubset({0.0, 1.0})
    z = np.asarray(tr["block0"]["z"])
    codes = z * quant.GATE_UNITS
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)


def test_paper_network_shape():
    """The paper's sMNIST stack 1-64-64-64-64-10."""
    from repro.configs import MINIMALIST_SMNIST_DIMS
    net = MinimalistNetwork(MINIMALIST_SMNIST_DIMS,
                            qcfg=quant.QuantConfig.hardware())
    params = net.init(jax.random.PRNGKey(0))
    x = (jax.random.uniform(jax.random.PRNGKey(1), (2, 50, 1)) > 0.5
         ).astype(jnp.float32)
    logits = net(params, x)
    assert logits.shape == (2, 10)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    # 2·(in·out + out) per block
    want = sum(2 * (i * o + o) for i, o in
               zip(MINIMALIST_SMNIST_DIMS[:-1], MINIMALIST_SMNIST_DIMS[1:]))
    assert n_params == want
