"""Prefix caching + copy-on-write forks: the bitwise contract.

A request that attaches to cached prefix pages, and a child forked off a
running parent's page chain, must emit the EXACT stream an independently
prefilled-and-decoded request would — greedy and sampled, for all three
attention families (global GQA, sliding window, MLA), with exactly one
compiled decode step.  Sharing changes memory traffic and scheduling,
never numerics.

The whole suite pins paged_impl="gather" (the bitwise oracle): the
streams here are compared against independently *prefilled* requests,
and the default pallas decode path is only tolerance-equal to the dense
prefill numerics — a sampled near-tie can legitimately flip under it.
Sharing semantics (attach points, COW copies, refcounts) are identical
across impls; the oracle just makes the stream equality exact.

Also covers the engine-loop bugs the feature exposed: admission must
refill a slot freed mid-wave (a max_new_tokens=1 request retiring at
admission), and run() must raise instead of busy-spinning when a
deferred request can never be admitted.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SamplingParams, get_config
from repro.models import build_model
from repro.serve import (DecoderStepModel, PagedConfig, PagePool,
                         PrefixCache, ServeEngine)

SPS = dict(temperature=0.9, top_k=12, top_p=0.9, seed=3)


def _built(arch):
    cfg = dataclasses.replace(get_config(arch), paged_impl="gather")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def gqa():
    return _built("smollm-360m-smoke")


@pytest.fixture(scope="module")
def window():
    return _built("gemma3-4b-smoke")


@pytest.fixture(scope="module")
def mla():
    return _built("deepseek-v3-671b-smoke")


def _engine(model, params, *, prefix_cache=False, slots=3, max_len=64,
            chunk=8, page_size=4, num_pages=0):
    sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk,
                          kv_layout="paged",
                          paged=PagedConfig(page_size=page_size,
                                            num_pages=num_pages))
    return ServeEngine(sm, params, slots=slots,
                       prefix_cache=prefix_cache), sm


# ---------------------------------------------------------------------------
# prefix attach == from-scratch prefill, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam", ["gqa", "window", "mla"])
def test_prefix_attach_bitwise(fam, request):
    """Requests sharing a page- AND chunk-aligned 24-token prefix: the
    first admission inserts it, the next two attach and prefill only
    their tails.  Streams (greedy + sampled) match a cache-off engine
    submitted in the same order (same uids -> same PRNG keys), with one
    compiled decode step.  24 = 6 pages of 4 = 3 chunks of 8, so the
    window stacks' exact-attach rule (attach % chunk == 0) is satisfied
    too."""
    cfg, model, params = request.getfixturevalue(fam)
    rng = np.random.default_rng(7)
    p0 = rng.integers(0, cfg.vocab, size=24)
    prompts = [p0, np.concatenate([p0, rng.integers(0, cfg.vocab, size=9)]),
               np.concatenate([p0, rng.integers(0, cfg.vocab, size=3)])]
    sp = [None, SamplingParams(**SPS), SamplingParams(**SPS)]

    ref_eng, _ = _engine(model, params)
    ref = [ref_eng.submit(p, max_new_tokens=6, sampling=s)
           for p, s in zip(prompts, sp)]
    ref_eng.run()

    eng, sm = _engine(model, params, prefix_cache=True)
    got = [eng.submit(p, max_new_tokens=6, sampling=s)
           for p, s in zip(prompts, sp)]
    eng.run()

    assert [list(r.tokens) for r in got] == [list(r.tokens) for r in ref]
    assert eng.n_prefix_hits == 2
    assert eng.n_prefix_tokens >= 2 * 24 - 8  # window attaches skip >= 16
    assert sm._jit_step._cache_size() == 1
    assert eng.pool.reserved_total == 0
    # only the cache's pins remain; clearing it drains the pool
    eng.prefix_cache.clear()
    assert eng.pool.pages_in_use == 0


def test_prefix_attach_under_pool_pressure_evicts(gqa):
    """A small pool forces the reclaim hook: cached entries are evicted
    LRU to satisfy reserve-covered allocations, traffic still completes,
    and the pool drains after the cache clears."""
    cfg, model, params = gqa
    rng = np.random.default_rng(5)
    eng, _ = _engine(model, params, prefix_cache=True, slots=2,
                     max_len=32, num_pages=10)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=8),
                       max_new_tokens=3) for _ in range(6)]
    eng.run()
    assert all(r.finished for r in reqs)
    assert eng.prefix_cache.n_evicted > 0
    eng.prefix_cache.clear()
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


def test_prefix_cache_requires_paged_layout(gqa):
    cfg, model, params = gqa
    sm = DecoderStepModel(model, max_len=64, prefill_chunk=8)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(sm, params, slots=2, prefix_cache=True)


def test_prefix_cache_match_rules():
    """Host-side match semantics on a bare pool: longest-prefix wins,
    chunk-grid mismatch is skipped, and full-prompt-only (window) mode
    rejects full matches and off-chunk attach points."""
    pool = PagePool(num_pages=16, slots=2, max_pages=8)
    pc = PrefixCache(pool, page_size=4)
    toks = np.arange(16)
    pool.reserve(0, 4)
    pool.grow(0, 4)
    row = pool.block_tables[0, :4]
    pc.insert(toks, row, chunk_w=8)
    # longest prefix: all 4 pages, attach at 16
    pages, attach = pc.match(np.concatenate([toks, [99]]), 8)
    assert attach == 16 and len(pages) == 4
    # shorter overlap matches a shorter inserted prefix
    pages, attach = pc.match(np.concatenate([toks[:8], [99]]), 8)
    assert attach == 8 and len(pages) == 2
    # different chunk grid -> no hit (the grid is part of the contract)
    assert pc.match(np.concatenate([toks, [99]]), 4) == (None, 0)

    pool2 = PagePool(num_pages=16, slots=2, max_pages=8)
    pcw = PrefixCache(pool2, page_size=4, full_prompt_only=True)
    pool2.reserve(0, 4)
    pool2.grow(0, 4)
    pcw.insert(toks, pool2.block_tables[0, :4], chunk_w=8)
    assert len(pcw) == 1  # single full-prompt entry, no sub-prefixes
    # full match rejected (ring would be 'ahead' of pos0)
    assert pcw.match(toks, 8) == (None, 0)
    # attach off the chunk grid rejected: entry covers 16 tokens but a
    # 17-token prompt attaches at 16 which IS on-grid -> accepted...
    pages, attach = pcw.match(np.concatenate([toks, [99]]), 8)
    assert attach == 16 and len(pages) == 4
    # ...whereas a grid of 32 (pow2ceil of a longer prompt) is a miss
    assert pcw.match(np.concatenate([toks, [99]]), 32) == (None, 0)


# ---------------------------------------------------------------------------
# copy-on-write forks == independent decode, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam", ["gqa", "window", "mla"])
def test_fork_bitwise(fam, request):
    """Greedy children reproduce the parent's remaining stream bitwise;
    a sampled child matches an independently submitted request with
    prompt = parent prompt + tokens-at-fork and the same uid (fork
    assigns the next uid, so submission order aligns the PRNG keys)."""
    cfg, model, params = request.getfixturevalue(fam)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, size=10)
    sps = SamplingParams(**SPS)

    # greedy: child == parent, bit for bit
    A, sm = _engine(model, params)
    parent = A.submit(prompt, max_new_tokens=8)
    A.step()
    kids = A.fork(parent, 2)
    A.run()
    assert parent.finished
    for k in kids:
        assert list(k.tokens) == list(parent.tokens)
    assert sm._jit_step._cache_size() == 1
    assert A.pool.pages_in_use == 0 and A.pool.reserved_total == 0
    assert A.n_forks == 2

    # sampled: child (uid 1) == from-scratch request (uid 1) continuing
    # the same token history under the same counter-based PRNG
    B, _ = _engine(model, params)
    sparent = B.submit(prompt, max_new_tokens=8, sampling=sps)
    B.step()
    at_fork = list(sparent.tokens)
    skid = B.fork(sparent, 1, sampling=sps)[0]
    B.run()

    C, _ = _engine(model, params)
    c1 = C.submit(prompt, max_new_tokens=8, sampling=sps)
    c2 = C.submit(np.concatenate([prompt, at_fork]),
                  max_new_tokens=8 - len(at_fork), sampling=sps)
    C.run()
    assert list(sparent.tokens) == list(c1.tokens)
    assert list(skid.tokens) == at_fork + list(c2.tokens)
    assert B.pool.pages_in_use == 0 and B.pool.reserved_total == 0


def test_fork_requires_running_parent_and_capacity(gqa):
    cfg, model, params = gqa
    rng = np.random.default_rng(2)
    eng, _ = _engine(model, params, slots=2)
    req = eng.submit(rng.integers(0, cfg.vocab, size=6),
                     max_new_tokens=4)
    with pytest.raises(ValueError, match="RUNNING"):
        eng.fork(req, 1)  # still waiting, no slot yet
    eng.step()
    eng.fork(req, 1)
    with pytest.raises(RuntimeError):  # slots exhausted
        eng.fork(req, 1)
    eng.run()
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


# ---------------------------------------------------------------------------
# engine-loop fixes the feature exposed
# ---------------------------------------------------------------------------

def test_admit_refills_slot_freed_mid_wave(gqa):
    """A max_new_tokens=1 request retires AT admission (its single token
    is the prefill's tok0); the slot it frees must be refilled in the
    SAME admit() call instead of idling a decode step."""
    cfg, model, params = gqa
    rng = np.random.default_rng(4)
    eng, _ = _engine(model, params, slots=2)
    a = eng.submit(rng.integers(0, cfg.vocab, size=5), max_new_tokens=1)
    b = eng.submit(rng.integers(0, cfg.vocab, size=7), max_new_tokens=4)
    c = eng.submit(rng.integers(0, cfg.vocab, size=6), max_new_tokens=4)
    eng.admit()
    assert a.finished                      # retired inside the wave
    assert not eng.waiting                 # c admitted by the refill loop
    assert int(eng.active.sum()) == 2
    eng.run()
    assert b.finished and c.finished
    assert eng.pool.pages_in_use == 0 and eng.pool.reserved_total == 0


def test_run_raises_on_permanent_stall(gqa):
    """With the pool's capacity promised away and nothing active to ever
    free it, run() must raise a descriptive error naming the blocked
    request instead of spinning forever."""
    cfg, model, params = gqa
    rng = np.random.default_rng(3)
    eng, _ = _engine(model, params, slots=2, max_len=32)
    eng.pool.reserve(1, eng.pool.num_pages)  # simulate a leaked hold
    req = eng.submit(rng.integers(0, cfg.vocab, size=6),
                     max_new_tokens=4)
    with pytest.raises(RuntimeError, match=f"uid={req.uid}"):
        eng.run()


def test_submit_rejects_0d_prompt(gqa):
    cfg, model, params = gqa
    eng, _ = _engine(model, params, slots=2)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.int64(7), max_new_tokens=2)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.zeros(0, np.int64), max_new_tokens=2)
