"""MoE dispatch correctness: the sort-based gather/scatter path must equal
a dense "every expert sees every token" reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.moe import MoEMLP


def dense_reference(params, x, moe: MoEConfig):
    """O(N·E) oracle: run every token through every expert, combine top-k."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, idx = jax.lax.top_k(probs, moe.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    # all experts on all tokens
    g = jnp.einsum("nd,edf->nef", xt, params["w_gate"])
    u = jnp.einsum("nd,edf->nef", xt, params["w_up"])
    y_all = jnp.einsum("nef,efd->ned", jax.nn.silu(g) * u, params["w_down"])
    out = jnp.zeros_like(xt)
    for k in range(moe.top_k):
        sel = jnp.take_along_axis(y_all, idx[:, k][:, None, None], 1)[:, 0]
        out = out + gate[:, k][:, None] * sel
    return out.reshape(B, S, D)


@pytest.mark.parametrize("n_experts,top_k", [(4, 2), (8, 2), (8, 4)])
def test_dispatch_matches_dense_reference(n_experts, top_k):
    moe = MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                    capacity_factor=1e9)  # no dropping -> exact match
    m = MoEMLP(8, moe)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 8))
    got, aux = m(params, x)
    want = dense_reference(params, x, moe)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
    assert float(aux["dropped_frac"]) == 0.0


def test_capacity_dropping_bounds_work():
    moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8,
                    capacity_factor=0.5)
    m = MoEMLP(8, moe)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 8))
    out, aux = m(params, x)
    assert 0.0 < float(aux["dropped_frac"]) < 1.0
    assert np.isfinite(np.asarray(out)).all()


def test_shared_expert_added():
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, n_shared=1,
                    capacity_factor=2.0)
    m = MoEMLP(8, moe)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 8))
    out, _ = m(params, x)
    # zeroing the shared expert changes the output
    p2 = jax.tree_util.tree_map(jnp.zeros_like, params["shared"])
    out2, _ = m(dict(params, shared=p2), x)
    assert float(jnp.abs(out - out2).max()) > 1e-6


def test_aux_loss_prefers_balance():
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8)
    m = MoEMLP(8, moe)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 8))
    _, aux = m(params, x)
    balanced = float(aux["aux_loss"])
    # force collapse onto expert 0
    p_bad = dict(params, router=params["router"]
                 + jnp.array([100.0, 0, 0, 0]))
    _, aux_bad = m(p_bad, x)
    assert float(aux_bad["aux_loss"]) > balanced


def test_moe_config_validates_groups_and_dispatch():
    """groups=0 used to slip past the divisibility guard and divide by
    zero at trace time; it is now rejected at construction, along with
    unknown dispatch modes and out-of-range top_k."""
    with pytest.raises(ValueError, match="groups"):
        MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, groups=0)
    with pytest.raises(ValueError, match="groups"):
        MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, groups=-1)
    with pytest.raises(ValueError, match="dispatch"):
        MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, dispatch="magic")
    with pytest.raises(ValueError, match="top_k"):
        MoEConfig(n_experts=4, top_k=5, d_ff_expert=8)
    with pytest.raises(ValueError, match="top_k"):
        MoEConfig(n_experts=4, top_k=0, d_ff_expert=8)


def test_indivisible_batch_falls_back_to_one_group():
    """A batch the group count does not divide clamps to G=1 (and even a
    config that bypassed validation cannot reach the G=0 division)."""
    moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, groups=3)
    m = MoEMLP(8, moe)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8))  # 2 % 3 != 0
    out, _ = m(params, x)
    assert out.shape == x.shape
    # forcibly corrupt groups past the frozen-dataclass validation: the
    # runtime clamp (not ZeroDivisionError) must still hold
    object.__setattr__(moe, "groups", 0)
    out0, _ = m(params, x)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out))


def test_gradients_flow_through_dispatch():
    moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8)
    m = MoEMLP(8, moe)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))

    def loss(p):
        out, aux = m(p, x)
        return jnp.sum(out ** 2) + 0.01 * aux["aux_loss"]

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        v = float(jnp.abs(g[name]).sum())
        assert np.isfinite(v) and v > 0, (name, v)
