"""Mixed-signal verification (paper Fig. 3/4): the behavioral
switched-capacitor circuit must reproduce the hardware-constrained software
model bit-exactly (open loop), and degrade gracefully with mismatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.analog import (AnalogConfig, adc_transfer_closed_form,
                               analog_forward, charge_sharing_mvm,
                               energy_per_step, export_layer, make_mismatch,
                               sar_adc)
from repro.core.mingru import MinimalistNetwork


def _net_and_traces(seed, dims=(4, 8, 8, 5), T=25, B=3):
    qcfg = quant.QuantConfig.hardware()
    net = MinimalistNetwork(dims, qcfg=qcfg)
    key = jax.random.PRNGKey(seed)
    params = net.init(key)
    x = (jax.random.uniform(jax.random.fold_in(key, 9), (B, T, dims[0]))
         > 0.5).astype(jnp.float32)
    logits, sw = net(params, x, collect_traces=True)
    return net, params, x, logits, sw


@pytest.mark.parametrize("seed", [0, 7, 42])
def test_open_loop_bit_exact(seed):
    net, params, x, logits, sw = _net_and_traces(seed)
    acfg = AnalogConfig()
    images = [export_layer(params[b.name], acfg) for b in net.blocks]
    forced = [np.asarray(sw[b.name]["out"]) for b in net.blocks[:-1]]
    readout, an = analog_forward(images, x, acfg, forced_inputs=forced)
    for li, b in enumerate(net.blocks):
        # z codes: exactly the same 6 b grid values
        np.testing.assert_array_equal(np.asarray(sw[b.name]["z"]),
                                      np.asarray(an[li]["z"]),
                                      err_msg=f"z mismatch layer {li}")
        # analog h̃ / h traces match to float precision (volts roundtrip)
        for k in ("htilde", "h"):
            np.testing.assert_allclose(np.asarray(an[li][k]),
                                       np.asarray(sw[b.name][k]),
                                       atol=2e-4,
                                       err_msg=f"{k} layer {li}")
        if li < len(net.blocks) - 1:
            h_sw = np.asarray(sw[b.name]["h"])
            flips = (np.asarray(sw[b.name]["out"]) != np.asarray(an[li]["out"]))
            # comparator may flip only where h sits exactly at threshold
            assert not (flips & (np.abs(h_sw) > 1e-4)).any()
    np.testing.assert_allclose(np.asarray(readout), np.asarray(logits),
                               atol=2e-4)


def test_closed_loop_matches_mostly():
    """End-to-end (Fig. 4 regime): binary streams may diverge at threshold
    ties, but the bulk of the activity must agree."""
    net, params, x, logits, sw = _net_and_traces(3, T=30)
    acfg = AnalogConfig()
    images = [export_layer(params[b.name], acfg) for b in net.blocks]
    _, an = analog_forward(images, x, acfg)
    agree = np.mean([
        (np.asarray(sw[b.name]["z"]) == np.asarray(an[li]["z"])).mean()
        for li, b in enumerate(net.blocks)])
    assert agree > 0.9


def test_sar_adc_equals_closed_form():
    acfg = AnalogConfig()
    lsb = 0.0031
    v = jnp.linspace(0.1, 0.7, 4001)
    for off in (-20, -3, 0, 5, 17):
        a = np.asarray(sar_adc(v, acfg, lsb_volts=lsb, offset_code=off))
        b = np.asarray(adc_transfer_closed_form(v, acfg, lsb_volts=lsb,
                                                offset_code=off))
        assert (a == b).mean() > 0.999  # float ties at code edges only


def test_adc_slope_and_offset_mechanisms():
    """Fig. 3C: larger connected-IMC ratio (smaller lsb) -> steeper
    transfer; DAC preset shifts the transfer laterally."""
    acfg = AnalogConfig()
    v = jnp.linspace(0.2, 0.6, 2001)
    steep = np.asarray(sar_adc(v, acfg, lsb_volts=0.002))
    shallow = np.asarray(sar_adc(v, acfg, lsb_volts=0.008))
    # count live-region codes: steeper transfer saturates over fewer volts
    assert (steep > 0).argmax() > (shallow > 0).argmax()
    span = lambda c: (c < 63).sum() - (c == 0).sum()
    assert span(steep) < span(shallow)
    off = np.asarray(sar_adc(v, acfg, lsb_volts=0.004, offset_code=10))
    base = np.asarray(sar_adc(v, acfg, lsb_volts=0.004))
    live = (base > 0) & (base < 63) & (off > 0) & (off < 63)
    assert live.any()
    shift = off.astype(int)[live] - base.astype(int)[live]
    # DAC preset = exact code shift (± float ties at code boundaries)
    assert np.isin(shift, (9, 10, 11)).all()
    assert (shift == 10).mean() > 0.9

def test_charge_sharing_with_mismatch_stays_close():
    acfg = AnalogConfig(mismatch_sigma=0.01)
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (32, 16), 0, 4)
    x = (jax.random.uniform(jax.random.fold_in(key, 1), (4, 32)) > 0.5
         ).astype(jnp.float32)
    caps = 1.0 + acfg.mismatch_sigma * jax.random.normal(key, (33, 16))
    v_ideal = charge_sharing_mvm(x, codes, jnp.zeros(16), acfg)
    v_mm = charge_sharing_mvm(x, codes, jnp.zeros(16), acfg, caps=caps)
    err = np.abs(np.asarray(v_mm - v_ideal))
    assert err.max() < 0.01  # ~1% caps -> millivolt-scale error
    assert err.max() > 0.0   # but not identical


def test_closed_loop_with_mismatch_and_noise_runs():
    net, params, x, logits, sw = _net_and_traces(1, T=10)
    acfg = AnalogConfig(mismatch_sigma=0.005, comparator_noise_v=0.001)
    images = [export_layer(params[b.name], acfg) for b in net.blocks]
    mm = make_mismatch(jax.random.PRNGKey(5), images, acfg)
    readout, an = analog_forward(images, x, acfg, mismatch=mm,
                                 key=jax.random.PRNGKey(6))
    assert np.isfinite(np.asarray(readout)).all()


def test_energy_model_reproduces_paper_bound():
    """Paper §4.2: 4 cores × 64×64, worst case z=1 -> ≤ 169 pJ/step."""
    e = energy_per_step(rows=64, cols=64, n_cores=4, z_mean=1.0)
    assert e["total_pJ"] <= 169.0
    assert e["total_pJ"] > 50.0   # same order as the paper's estimate
    # energy scales with activity (z) and with array size
    e0 = energy_per_step(rows=64, cols=64, n_cores=4, z_mean=0.0)
    assert e0["total_pJ"] < e["total_pJ"]
    e8 = energy_per_step(rows=128, cols=64, n_cores=4, z_mean=1.0)
    np.testing.assert_allclose(e8["total_pJ"] / e["total_pJ"], 2.0, rtol=0.01)
