"""Grid-padded masked chunked prefill (repro.serve.prefill): single-shape
compile class, sliding-window ring wrap regression, and fast-path vs
scanned-reference equivalence for every attention family.

Equivalence checks compare tensors at bf16-appropriate tolerances, never
greedy tokens across program families — cross-program one-ULP argmax ties
flip tokens on random-init bf16 models (recorded from PR 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MLA, LayerSpec, MLAConfig, ModelConfig
from repro.models import build_model
from repro.models.attention import GQAAttention
from repro.serve import DecoderStepModel, chunked_prefill


def _tree_allclose(a, b, atol, rtol):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            atol=atol, rtol=rtol), a, b)


MLA_TEST_CFG = ModelConfig(
    # MLA-only stack (kept MoE-free so this test isolates the latent-cache
    # path; MoE chunking invariance is pinned in tests/test_serve_moe.py)
    name="mla-dense-test", d_model=32, n_layers=2, vocab=128,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
    pattern=(LayerSpec(MLA),),
    mla=MLAConfig(q_lora_rank=16, kv_lora_rank=8, qk_nope_head_dim=8,
                  qk_rope_head_dim=4, v_head_dim=8))


# ---------------------------------------------------------------------------
# compile class
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["minimalist-lm-360m", "gemma3-4b"])
def test_grid_padded_prefill_compiles_one_chunk_shape(arch):
    """Ragged prompt lengths all flow through EXACTLY one compiled chunk
    program (the remainder-shape compile class is gone); the legacy
    remainder mode compiles one program per distinct remainder."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = (3, 5, 8, 13, 21)
    full = {}
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    for P in lens:
        toks = rng.integers(0, cfg.vocab, size=(1, P))
        last, _ = chunked_prefill(sm, params, toks, chunk=8)
        full[P] = (toks, last)
    assert sm._jit_prefill_fast._cache_size() == 1
    # legacy remainder mode: every distinct remainder is its own program
    legacy = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    for P in lens:
        toks, last = full[P]
        llast, _ = chunked_prefill(legacy, params, toks, chunk=8,
                                   pad_to_grid=False)
        np.testing.assert_allclose(np.asarray(llast, np.float32),
                                   np.asarray(last, np.float32),
                                   atol=0.05, rtol=0.05)
    assert legacy._jit_prefill_fast._cache_size() > 1


def test_padded_and_unpadded_prefill_agree():
    """Grid padding is numerically inert: same last-token logits and same
    cache carry as the legacy remainder chunking, for every stack kind."""
    for arch in ("minimalist-lm-360m", "falcon-mamba-7b", "smollm-360m"):
        cfg = get_config(arch + "-smoke")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 13), 0,
                                  cfg.vocab)
        sm = DecoderStepModel(model, max_len=24, prefill_chunk=8)
        lp, cp = chunked_prefill(sm, params, toks, chunk=8)
        lu, cu = chunked_prefill(sm, params, toks, chunk=8,
                                 pad_to_grid=False)
        np.testing.assert_allclose(np.asarray(lp, np.float32),
                                   np.asarray(lu, np.float32),
                                   atol=2e-2, rtol=2e-2)
        _tree_allclose(cp, cu, 2e-2, 2e-2)


# ---------------------------------------------------------------------------
# sliding-window ring buffer
# ---------------------------------------------------------------------------

def test_sliding_window_chunk_write_wrap_regression():
    """Chunk writes that cross the ring boundary neither clobber live
    entries nor skip slots: the wrapped cache and the attention outputs
    match the per-token decode reference exactly (same layer, f32)."""
    cfg = get_config("gemma3-4b-smoke")          # window = 8
    attn = GQAAttention(cfg, local=True)
    params = attn.init(jax.random.PRNGKey(0))
    L = 8
    cache0 = attn.init_cache(1, L, dtype=jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)),
                    jnp.float32)
    # reference: per-token decode through positions 0..15 (ring wraps at 8)
    ref_cache, ref_y = cache0, []
    for t in range(16):
        y, ref_cache = attn.decode(params, x[:, t:t + 1], ref_cache,
                                   jnp.int32(t))
        ref_y.append(y[:, 0])
    # chunked: positions 0..4, then a chunk 5..15 that wraps the ring
    y1, cache = attn.prefill(params, x[:, :5], cache0, jnp.int32(0),
                             length=jnp.int32(5))
    y2, cache = attn.prefill(params, x[:, 5:], cache, jnp.int32(5),
                             length=jnp.int32(11))
    got_y = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got_y[0]),
                               np.asarray(jnp.stack(ref_y, 1)[0]),
                               atol=1e-5, rtol=1e-5)
    _tree_allclose(cache, ref_cache, 1e-6, 1e-6)


def test_sliding_window_masked_tail_never_written():
    """Grid-padding tokens in a wrapping chunk must not scatter into ring
    slots that still hold live positions."""
    cfg = get_config("gemma3-4b-smoke")
    attn = GQAAttention(cfg, local=True)
    params = attn.init(jax.random.PRNGKey(0))
    L = 8
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 12, cfg.d_model)), jnp.float32)
    cache = attn.init_cache(1, L, dtype=jnp.float32)
    _, cache = attn.prefill(params, x[:, :6], cache, jnp.int32(0),
                            length=jnp.int32(6))
    # chunk of width 6 at pos0=6 with only 3 valid tokens: the padded
    # tail (positions 9..11) would alias ring slots 1..3 (live: 1..3+8?)
    # — slots of positions 1..3 — if the write mask leaked
    _, got = attn.prefill(params, x[:, 6:], cache, jnp.int32(6),
                          length=jnp.int32(3))
    ref = attn.init_cache(1, L, dtype=jnp.float32)
    for t in range(9):
        _, ref = attn.decode(params, x[:, t:t + 1], ref, jnp.int32(t))
    _tree_allclose(got, ref, 1e-6, 1e-6)


# ---------------------------------------------------------------------------
# fast path vs scanned reference (sliding window + MLA)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,P,chunk", [
    ("gemma3-4b", 21, 8),      # mixed local/global GQA, ring wraps (L=8)
    ("gemma3-4b", 29, 12),     # chunk larger than the ring
])
def test_windowed_chunked_prefill_matches_scan(arch, P, chunk):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert model.supports_prefill()
    sm = DecoderStepModel(model, max_len=P + 8, prefill_chunk=chunk)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, P), 0, cfg.vocab)
    lf, cf = chunked_prefill(sm, params, toks, chunk=chunk)
    ls, cs = chunked_prefill(sm, params, toks, chunk=chunk,
                             force_scan=True)
    np.testing.assert_allclose(np.asarray(lf, np.float32),
                               np.asarray(ls, np.float32),
                               atol=0.05, rtol=0.05)
    _tree_allclose(cf, cs, 0.05, 0.05)


def test_mla_chunked_prefill_matches_scan_and_decode_continues():
    model = build_model(MLA_TEST_CFG)
    params = model.init(jax.random.PRNGKey(0))
    assert model.supports_prefill()
    sm = DecoderStepModel(model, max_len=32, prefill_chunk=8)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 11), 0,
                              MLA_TEST_CFG.vocab)
    lf, cf = chunked_prefill(sm, params, toks, chunk=8)
    ls, cs = chunked_prefill(sm, params, toks, chunk=8, force_scan=True)
    np.testing.assert_allclose(np.asarray(lf, np.float32),
                               np.asarray(ls, np.float32),
                               atol=0.05, rtol=0.05)
    _tree_allclose(cf, cs, 0.05, 0.05)
    # the carry feeds decode_step: both caches continue to close logits
    nxt = jnp.argmax(lf[:, :MLA_TEST_CFG.vocab], -1)[:, None]
    df, _ = model.decode_step(params, nxt, cf, jnp.int32(11))
    ds, _ = model.decode_step(params, nxt, cs, jnp.int32(11))
    np.testing.assert_allclose(np.asarray(df, np.float32),
                               np.asarray(ds, np.float32),
                               atol=0.05, rtol=0.05)
