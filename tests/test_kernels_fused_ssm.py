"""Pallas fused selective-scan kernel vs materializing oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_ssm import ops, ref

KEY = jax.random.PRNGKey(4)


def _inputs(B, T, di, n, k=0):
    kk = jax.random.fold_in(KEY, k)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(kk, 1), (B, T, di))) * 0.2
    x = jax.random.normal(jax.random.fold_in(kk, 2), (B, T, di))
    Bm = jax.random.normal(jax.random.fold_in(kk, 3), (B, T, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(kk, 4), (B, T, n)) * 0.5
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(kk, 5), (di, n)) * 0.3)
    return dt, x, Bm, Cm, A


@pytest.mark.parametrize("B,T,di,n", [
    (1, 4, 8, 2), (2, 64, 128, 16), (1, 96, 32, 8), (2, 128, 256, 4),
])
def test_matches_reference(B, T, di, n):
    args = _inputs(B, T, di, n, k=T + di)
    want = ref.selective_scan_ref(*args)
    got = ops.selective_scan(*args, "pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,T,di,n", [(1, 32, 64, 4), (2, 64, 32, 8)])
def test_gradients_match_reference(B, T, di, n):
    args = _inputs(B, T, di, n, k=T * di)

    def loss(dt, x, Bm, Cm, A, backend):
        y = ops.selective_scan(dt, x, Bm, Cm, A, backend)
        return jnp.sum(jnp.sin(y))

    want = jax.grad(loss, (0, 1, 2, 3, 4))(*args, "xla")
    got = jax.grad(loss, (0, 1, 2, 3, 4))(*args, "pallas")
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-5, rtol=5e-4)


def test_decay_contracts_state():
    """Strongly negative A ⇒ h forgets: y depends mostly on recent inputs."""
    B, T, di, n = 1, 32, 16, 4
    dt, x, Bm, Cm, A = _inputs(B, T, di, n, k=1)
    A_fast = A * 50.0
    y1 = ops.selective_scan(dt, x, Bm, Cm, A_fast, "xla")
    x2 = x.at[:, :T // 2].set(0.0)  # zero the distant past
    y2 = ops.selective_scan(dt, x2, Bm, Cm, A_fast, "xla")
    np.testing.assert_allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]),
                               atol=1e-4)


def test_mamba_block_fused_equals_xla():
    """MambaBlock end-to-end: ssm_impl='fused' == 'xla'."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.mamba import MambaBlock
    cfg = get_config("falcon-mamba-7b-smoke")
    blk_x = MambaBlock(cfg)
    blk_f = MambaBlock(dataclasses.replace(cfg, ssm_impl="fused"))
    params = blk_x.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    yx = blk_x(params, x)
    yf = blk_f(params, x)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yx),
                               atol=2e-4, rtol=2e-3)


def test_cost_model_beats_materialization():
    f, b = ops.cost_model(16, 4096, 8192, 16, train=True)
    materialized = 3 * 16 * 4096 * 8192 * 16 * 4  # a, b, h in fp32
    # the kernel's floor is reading its O(B·T·di) inputs — still ≥10× less
    # HBM traffic than materializing the (B,T,di,n) tensors
    assert b < materialized / 10
