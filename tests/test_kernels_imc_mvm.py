"""Pallas imc_mvm kernel vs charge-sharing oracle."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra; skip on minimal installs
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels.imc_mvm import ops, ref

KEY = jax.random.PRNGKey(1)


def _inputs(M, K, N, k=0, per_col_scale=True):
    kk = jax.random.fold_in(KEY, k)
    x = (jax.random.uniform(jax.random.fold_in(kk, 0), (M, K)) > 0.5
         ).astype(jnp.float32)
    codes = jax.random.randint(jax.random.fold_in(kk, 1), (K, N), 0, 4
                               ).astype(jnp.int8)
    scale = (jax.random.uniform(jax.random.fold_in(kk, 2), (N,)) * 0.3 + 0.01
             if per_col_scale else jnp.float32(0.1))
    return x, codes, scale


@pytest.mark.parametrize("M,K,N", [
    (1, 1, 1), (3, 5, 7), (8, 128, 128), (17, 70, 50),
    (128, 256, 384), (5, 300, 129),
])
def test_pallas_matches_oracle(M, K, N):
    x, codes, scale = _inputs(M, K, N, k=M * 1000 + N)
    want = ops.imc_mvm(x, codes, scale, backend="xla")
    got = ops.imc_mvm(x, codes, scale, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (128, 256, 128)])
def test_pallas_blocking_invariance(bm, bn, bk):
    x, codes, scale = _inputs(33, 200, 140, k=9)
    want = ops.imc_mvm(x, codes, scale, backend="xla")
    got = ops.imc_mvm(x, codes, scale, backend="pallas",
                      bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_batched_leading_dims():
    x, codes, scale = _inputs(12, 30, 20, k=3)
    x3 = x.reshape(3, 4, 30)
    got = ops.imc_mvm(x3, codes, scale, backend="pallas")
    want = ops.imc_mvm(x, codes, scale, backend="xla").reshape(3, 4, 20)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 16), st.integers(1, 64), st.integers(1, 32),
       st.integers(0, 2 ** 31 - 1))
def test_prop_charge_sharing_is_mean(M, K, N, seed):
    """Eq. 6: the settled voltage is the *mean* of selected weight levels —
    all-ones activations give exactly mean_k(levels[codes])·Δ."""
    k = jax.random.PRNGKey(seed)
    codes = jax.random.randint(k, (K, N), 0, 4).astype(jnp.int8)
    x = jnp.ones((M, K))
    out = ops.imc_mvm(x, codes, 0.2, backend="xla")
    want = ((np.asarray(codes, np.float32) - 1.5) * 0.2).mean(0)
    np.testing.assert_allclose(np.asarray(out)[0], want, atol=1e-6)
    # zero activations -> exactly V0 (zero in weight units)
    out0 = ops.imc_mvm(jnp.zeros((M, K)), codes, 0.2, backend="xla")
    assert float(np.abs(np.asarray(out0)).max()) == 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.integers(2, 32), st.integers(1, 16),
       st.integers(0, 2 ** 31 - 1))
def test_prop_linearity_in_activations(M, K, N, seed):
    """Binary superposition: y(x1 ∨ x2) = y(x1) + y(x2) for disjoint x."""
    k = jax.random.PRNGKey(seed)
    codes = jax.random.randint(k, (K, N), 0, 4).astype(jnp.int8)
    mask = jax.random.uniform(jax.random.fold_in(k, 1), (M, K)) > 0.5
    x1 = mask.astype(jnp.float32)
    x2 = (~mask).astype(jnp.float32)
    y = lambda x: np.asarray(ops.imc_mvm(x, codes, 0.1, backend="xla"))
    np.testing.assert_allclose(y(x1) + y(x2), y(jnp.ones((M, K))), atol=1e-5)
