"""Consolidated roofline table from the multi-pod dry-run results
(benchmarks/results/dryrun/*.json) — the §Roofline source of truth.

Per (arch × shape × mesh): the three terms (compute / memory / collective,
seconds per step on TPU v5e constants), the dominant bottleneck, model-FLOPs
ratio and the roofline fraction.  Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_all():
    out = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def run():
    rows = []
    cells = load_all()
    ok = [c for c in cells if c.get("status") == "ok"]
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"],
                                       c.get("impl", "baseline"))):
        mesh = "multi" if "pod" in c["mesh"]["axes"] else "single"
        impl = c.get("impl", "baseline")
        suffix = "" if impl == "baseline" else f"/{impl}"
        t = c["roofline"]
        rows.append({
            "name": f"roofline/{c['arch']}/{c['shape']}/{mesh}{suffix}",
            "us_per_call": f"{t['bound_s']*1e6:.0f}",
            "derived": (
                f"compute_s={t['compute_s']:.3e};"
                f"memory_s={t['memory_s']:.3e};"
                f"collective_s={t['collective_s']:.3e};"
                f"dominant={t['dominant']};"
                f"useful_flops_ratio={c.get('useful_flops_ratio') or 0:.3f};"
                f"roofline_frac={c.get('roofline_fraction') or 0:.4f}"),
        })
    n_err = len(cells) - len(ok)
    rows.append({"name": "roofline/summary",
                 "derived": f"cells_ok={len(ok)};cells_err={n_err}"})
    return emit(rows)


if __name__ == "__main__":
    run()
