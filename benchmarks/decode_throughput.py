"""Continuous-batching engine vs the static-batch serving baseline.

Workload: 2x`batch` requests with mixed prompt/generation lengths.  The
baseline (launch.serve.generate semantics) runs them as two padded static
waves — every row is locked for (max prompt + max gen) steps.  The engine
admits into `batch` slots, retires sequences the step they finish, and
backfills from the queue, so the same slot batch emits more useful tokens
per wall-second.

Reported per batch size (default 1 / 64 / 256):
  * useful generated tokens/s, end-to-end (prefill + decode, post-warmup)
  * p50 / p99 per-token decode latency (one slot-batch step = one token
    for every active request)
  * sampled decode (temperature/top-k/top-p per slot) vs greedy — the
    overhead of the in-step sampling pipeline (same compiled program)
and for the prefill comparison at prompt length >= 256:
  * chunked prefill (ONE linear_scan per chunk) vs the per-token loop
  * grid-padded chunking (one compiled chunk shape) vs legacy remainder
    chunking across ragged prompt lengths, compile counts included
plus an MoE stack row (qwen3-moe smoke): batch-invariant auto dispatch
(gather-GEMM decode + per-request prefill) vs pooled capacity dispatch,
a sharded row: the engine on a local DxM device mesh (TP params /
caches, DP slots — see README §Sharded serving) vs the no-mesh engine,
and paged-KV rows (README §Paged KV cache): paged vs dense tokens/s at
equal occupancy plus max concurrent long-context requests at fixed KV
memory (dense buys concurrency in slots x max_len bytes; paged in live
pages), plus prefix-cache rows (README §Prefix caching): warm-cache
TTFT at high prompt overlap vs cache-off, and best-of-n via COW fork
vs n independent submissions.

    PYTHONPATH=src python -m benchmarks.decode_throughput \
        [--arch minimalist-lm-360m] [--batches 1,64,256] [--gen 16]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import check, emit, reset_checks, write_bench
from repro.configs import SamplingParams, get_config
from repro.models import build_model
from repro.serve import DecoderStepModel, ServeEngine
from repro.serve.prefill import chunked_prefill


def _workload(rng, cfg, n, pmean, gmean, bucket):
    """Mixed lengths, bucketed to ``bucket`` so prefill compiles O(1) shapes."""
    plens = [max(bucket, bucket * int(rng.integers(1, max(2, pmean // bucket) + 1)))
             for _ in range(n)]
    glens = [int(rng.integers(max(1, gmean // 2), gmean + 1)) for _ in range(n)]
    prompts = [rng.integers(0, cfg.vocab, size=p, dtype=np.int64)
               for p in plens]
    return prompts, glens


def _baseline_step_fn(model):
    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = model.decode_step(params, tok, cache, pos)
        return (jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32),
                cache)
    return step


def _run_baseline(model, params, prompts, glens, max_len, batch, step):
    """Static waves of `batch` padded requests; per-step latencies out."""
    lat = []
    done_tokens = 0
    t0 = time.perf_counter()
    for w in range(0, len(prompts), batch):
        wave_p = prompts[w:w + batch]
        wave_g = glens[w:w + batch]
        P = max(len(p) for p in wave_p)
        G = max(wave_g)
        toks = jnp.asarray(np.stack([np.resize(p, P) for p in wave_p]),
                           jnp.int32)
        cache = model.init_cache(len(wave_p), max_len)
        tok = None
        for t in range(P):                       # per-token prefill
            tok, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        tok.block_until_ready()
        for t in range(G):                       # lock-step decode
            s0 = time.perf_counter()
            tok, cache = step(params, cache, tok[:, None], jnp.int32(P + t))
            tok.block_until_ready()
            lat.append(time.perf_counter() - s0)
        done_tokens += sum(wave_g)               # useful tokens only
    return done_tokens / (time.perf_counter() - t0), np.array(lat)


def _warm_engine(sm, params, batch, plens):
    """Compile every shape the timed run can hit: admission waves are
    padded to powers of two per prompt-length bucket (grid padding makes
    all prompt lengths share one chunk program per wave size), the
    per-wave admission sampler, plus the decode step at the slot-batch
    shape (writes use all-OOB slots: dropped).  jnp arrays throughout so
    the warm dispatch signatures match the engine's exactly.  Paged
    layout: writes use all-OOB page rows (dropped) and the step warms
    with a zero block table."""
    from repro.common import pow2ceil
    from repro.serve.sampling import greedy_arrays
    paged = getattr(sm, "kv_layout", "dense") == "paged"
    state = sm.init_state(batch)
    cap = pow2ceil(max(1, batch))
    for P in sorted(set(plens)):
        B = 1
        while B <= cap:
            toks = jnp.zeros((B, P), jnp.int32)
            last, carry = sm.prefill(params, toks)
            # thread the returned state: a mesh-bound StepModel DONATES
            # the incoming state buffer, so the old reference is dead
            if paged:
                state = sm.write_slots(
                    state, carry, np.full(B, batch, np.int32),
                    pages=np.full((B, sm.max_pages), sm.num_pages(batch),
                                  np.int32), plen=P)
            else:
                state = sm.write_slots(state, carry, np.full(B, batch,
                                                             np.int32))
            np.asarray(sm.sample(last, greedy_arrays(B),
                                 np.full(B, P, np.int32)))
            B *= 2
    kw = dict(bt=np.zeros((batch, sm.max_pages), np.int32)) if paged \
        else {}
    sm.step(params, jnp.zeros(batch, jnp.int32), state,
            jnp.zeros(batch, jnp.int32), jnp.ones(batch, bool), **kw)


def _run_engine(sm, params, prompts, glens, batch, sampled=False):
    eng = ServeEngine(sm, params, slots=batch)
    lat = []
    t0 = time.perf_counter()
    for i, (p, g) in enumerate(zip(prompts, glens)):
        sampling = SamplingParams(temperature=0.8, top_k=50, top_p=0.95,
                                  seed=i) if sampled else None
        eng.submit(p, max_new_tokens=g, sampling=sampling)
    while eng.waiting or eng.active.any():
        eng.admit()                    # keep admission prefill out of the
        s0 = time.perf_counter()       # per-token decode latency samples
        eng.step()
        lat.append(time.perf_counter() - s0)
    return eng.n_emitted / (time.perf_counter() - t0), np.array(lat), eng


def _prefill_compare(model, params, cfg, P, chunk):
    sm = DecoderStepModel(model, max_len=P + 2, prefill_chunk=chunk)
    toks = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab, size=(1, P)),
        jnp.int32)

    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = model.decode_step(params, tok, cache, pos)
        return logits, cache

    def chunked():
        last, cache = chunked_prefill(sm, params, toks, chunk=chunk)
        jax.block_until_ready(last)

    def per_token():
        cache = model.init_cache(1, P + 2)
        logits = None
        for t in range(P):
            logits, cache = step(params, cache, toks[:, t:t + 1],
                                 jnp.int32(t))
        jax.block_until_ready(logits)

    out = {}
    for name, fn in [("chunked", chunked), ("per_token", per_token)]:
        fn()                                       # compile
        times = []
        for _ in range(3):
            s0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - s0)
        out[name] = sorted(times)[1]
    return out


def _attn_prefill_compare(P, chunk):
    """Sliding-window and MLA stacks: the new chunked fast path vs the
    scanned per-token prefill they used to fall back to (PR 2)."""
    rows = []
    for label, arch in (("windowed", "gemma3-4b"),
                        ("mla", "deepseek-v3-671b")):
        cfg = get_config(arch + "-smoke")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sm = DecoderStepModel(model, max_len=P + 2, prefill_chunk=chunk)
        toks = jnp.asarray(np.random.default_rng(4).integers(
            0, cfg.vocab, size=(1, P)), jnp.int32)
        out = {}
        for mode, scan in (("chunked", False), ("scanned", True)):
            def go():
                last, _ = chunked_prefill(sm, params, toks, chunk=chunk,
                                          force_scan=scan)
                jax.block_until_ready(last)
            go()                               # compile
            times = []
            for _ in range(3):
                s0 = time.perf_counter()
                go()
                times.append(time.perf_counter() - s0)
            out[mode] = sorted(times)[1]
        rows.append({
            "name": f"prefill_attn/{label}/P{P}",
            "us_per_call": f"{out['chunked']*1e6:.0f}",
            "derived": f"chunked_s={out['chunked']:.4f};"
                       f"scanned_s={out['scanned']:.4f};"
                       f"speedup={out['scanned']/out['chunked']:.1f}x",
        })
    return rows


def _grid_compare(model, params, cfg, P, chunk):
    """Ragged prompt lengths, cold start: grid padding compiles ONE chunk
    shape; legacy remainder chunking compiles one program per distinct
    remainder — the compile class this PR removes."""
    rng = np.random.default_rng(5)
    lens = sorted({max(1, P - d) for d in (7, 5, 3, 1, 0)})
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, size=(1, p)),
                           jnp.int32) for p in lens]
    out = {}
    for mode, pad in (("padded", True), ("remainder", False)):
        sm = DecoderStepModel(model, max_len=P + 2, prefill_chunk=chunk)
        s0 = time.perf_counter()
        for toks in prompts:
            last, _ = chunked_prefill(sm, params, toks, chunk=chunk,
                                      pad_to_grid=pad)
        jax.block_until_ready(last)
        out[mode] = time.perf_counter() - s0
        out[mode + "_compiles"] = sm._jit_prefill_fast._cache_size()
    return out


def _sharded_compare(model, params, cfg, batch=4, gen=8, prompt=16,
                     chunk=8, mesh_spec=""):
    """Engine on a local DxM device mesh vs the no-mesh engine: tokens/s
    and per-step latency, so the perf trajectory records sharded decode.
    The mesh defaults to the largest (data<=2) x (model<=2) grid the
    local devices allow — on a 1-device host that is 1x1, which measures
    the pure overhead of the sharded path (placement + SPMD annotations);
    force more CPU devices with XLA_FLAGS=--xla_force_host_platform_
    device_count=N to record real TP/DP rows."""
    from repro.launch.mesh import make_local_mesh, mesh_info
    from repro.launch.serve import parse_mesh
    n = len(jax.devices())
    if mesh_spec:
        mesh = parse_mesh(mesh_spec)
    else:
        m = 2 if n >= 2 else 1
        d = 2 if n >= 2 * m and batch % 2 == 0 else 1
        mesh = make_local_mesh(model=m, data=d)
    info = mesh_info(mesh)
    d, m = info["dp"], info["tp"]
    rng = np.random.default_rng(13)
    prompts, glens = _workload(rng, cfg, 2 * batch, prompt, gen, chunk)
    max_len = max(len(p) for p in prompts) + max(glens) + 1
    rows, out = [], {}
    for label, use_mesh in (("single", None), (f"mesh_{d}x{m}", mesh)):
        sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk)
        if use_mesh is not None:
            sm.bind_mesh(use_mesh, batch)
            p = sm.place_params(params)
        else:
            p = params
        _warm_engine(sm, p, batch, [len(q) for q in prompts])
        tps, lat, _eng = _run_engine(sm, p, prompts, glens, batch)
        out[label] = tps
        rows.append({
            "name": f"decode_sharded/{label}/batch{batch}",
            "us_per_call": f"{np.median(lat)*1e6:.0f}",
            "derived": f"tok_s={tps:.1f};"
                       f"p50_ms={np.percentile(lat,50)*1e3:.2f};"
                       f"p99_ms={np.percentile(lat,99)*1e3:.2f}",
        })
    single, mesh_tps = out["single"], out[f"mesh_{d}x{m}"]
    rows[-1]["derived"] += (f";dp={info['dp']};tp={info['tp']};"
                            f"vs_single={mesh_tps/max(single,1e-9):.2f}x")
    return rows


def _paged_compare(batch=4, gen=8, prompt=16, chunk=8):
    """Paged vs dense KV layout on a GQA stack (smollm smoke).

    Rows 1-3: tokens/s and per-step latency at EQUAL occupancy — same
    traffic, same slot count, page pool at dense-equivalent capacity —
    the overhead of page indirection under the default Pallas kernel
    (bf16 and int8 pools).  The int8 row also asserts the acceptance
    bar: its greedy streams are IDENTICAL to the bf16 paged engine's.

    Capacity rows: admission capacity at FIXED KV memory for long
    max_len.  The dense layout preallocates slots x max_len cache rows,
    so its concurrency is bought in max_len-sized bytes no matter how
    long requests actually are; the paged pool spends a page chain per
    LIVE request, and int8 pools halve the page bytes again (plus the
    per-page float32 scale rows).  Concurrency at the same byte budget
    (requests of req_len tokens, max_len 4096): paged admits strictly
    more whenever req_len < max_len, int8 pins >= 1.9x over bf16 paged.

    Cost-model row: the analytic per-step stream bytes of
    kernels.paged_attention.cost_model cross-checked against the
    MEASURED per-page bytes of the real state spec (pool leaves + block
    table) — the satellite fix that keeps the roofline honest."""
    import dataclasses
    from repro.serve import PagedConfig
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qmodel = build_model(dataclasses.replace(cfg, kv_dtype="int8"))
    rng = np.random.default_rng(17)
    prompts, glens = _workload(rng, cfg, 2 * batch, prompt, gen, chunk)
    max_len = max(len(p) for p in prompts) + max(glens) + 1
    rows, out, streams = [], {}, {}
    for layout, m in (("dense", model), ("paged", model),
                      ("paged_int8", qmodel)):
        kw = {} if layout == "dense" else dict(
            kv_layout="paged", paged=PagedConfig(page_size=chunk))
        sm = DecoderStepModel(m, max_len=max_len, prefill_chunk=chunk,
                              **kw)
        _warm_engine(sm, params, batch, [len(p) for p in prompts])
        tps, lat, eng = _run_engine(sm, params, prompts, glens, batch)
        out[layout] = tps
        streams[layout] = [list(map(int, r.tokens)) for r in eng.finished]
        rows.append({
            "name": f"decode_paged/{layout}/batch{batch}",
            "us_per_call": f"{np.median(lat)*1e6:.0f}",
            "derived": f"tok_s={tps:.1f};"
                       f"p50_ms={np.percentile(lat,50)*1e3:.2f};"
                       f"p99_ms={np.percentile(lat,99)*1e3:.2f}",
        })
    check(streams["paged_int8"] == streams["paged"],
          "int8_paged_greedy_identical",
          "int8 paged greedy streams diverged from bf16 paged")
    rows[-2]["derived"] += \
        f";paged_vs_dense={out['paged']/max(out['dense'],1e-9):.2f}x"
    rows[-1]["derived"] += (
        f";int8_vs_bf16={out['paged_int8']/max(out['paged'],1e-9):.2f}x"
        f";greedy_identical=True")

    def nbytes(tree):
        return int(sum(int(np.prod(s.shape)) * s.dtype.itemsize
                       for s in jax.tree_util.tree_leaves(tree)))

    long_max, req_len, ps, dense_slots = 4096, 512, 64, 8
    sm_d = DecoderStepModel(model, max_len=long_max)
    budget = nbytes(sm_d.state_spec(dense_slots))
    admits = {}
    for label, m in (("bf16", model), ("int8", qmodel)):
        sm_p = DecoderStepModel(m, max_len=long_max, kv_layout="paged",
                                paged=PagedConfig(page_size=ps))
        spec1 = sm_p.state_spec(1)      # pool auto-sized to 1 request
        pool_b = nbytes({k: v for k, v in spec1.items()
                         if k in sm_p._pool_names})
        slot_b = nbytes({k: v for k, v in spec1.items()
                         if k not in sm_p._pool_names})
        per_req = (sm_p.pages_for(req_len) * (pool_b // sm_p.max_pages)
                   + slot_b)
        admits[label] = budget // per_req
        admits[label + "_pool_b"] = pool_b
        admits[label + "_sm"] = sm_p
    int8_gain = admits["int8"] / max(admits["bf16"], 1)
    check(int8_gain >= 1.9, "int8_capacity_gain",
          f"int8 capacity gain {int8_gain:.2f}x < pinned 1.9x")
    rows.append({
        "name": f"paged_capacity/max_len{long_max}/req{req_len}",
        "us_per_call": "0",
        "derived": f"budget_mib={budget/2**20:.1f};"
                   f"dense_concurrent={dense_slots};"
                   f"paged_concurrent={admits['bf16']};"
                   f"gain={admits['bf16']/dense_slots:.1f}x;"
                   f"paged_int8_concurrent={admits['int8']};"
                   f"int8_vs_bf16={int8_gain:.2f}x",
    })

    # cost-model cross-check: analytic page-stream bytes (kv + scales +
    # block-table row, B=1) vs the per-page bytes of the REAL spec
    from repro.kernels.paged_attention import cost_model
    n_attn = sum(1 for s in cfg.layer_specs()
                 if s.kind.startswith("attn"))
    cm_row = {"name": f"paged_cost_model/req{req_len}", "us_per_call": "0",
              "derived": ""}
    parts = []
    for label, db, sb in (("bf16", 2, 0), ("int8", 1, 4)):
        full = cost_model(1, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          live_tokens=req_len, page_size=ps,
                          dtype_bytes=db, scale_bytes=sb)[1]
        fixed = cost_model(1, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                           live_tokens=0, page_size=ps, dtype_bytes=db,
                           scale_bytes=sb)[1]
        sm_p = admits[label + "_sm"]
        pages = sm_p.pages_for(req_len)
        model_bytes = (full - fixed) * n_attn      # per-layer -> stack
        per_page = admits[label + "_pool_b"] // sm_p.max_pages
        measured = pages * per_page + pages * 4 * n_attn
        check(model_bytes == measured, f"paged_cost_model_{label}",
              f"cost model {model_bytes} != measured {measured}")
        parts.append(f"{label}_model={model_bytes};"
                     f"{label}_measured={measured}")
    cm_row["derived"] = ";".join(parts) + ";match=True"
    rows.append(cm_row)
    return rows


def _prefix_compare(batch=4, gen=4, prefix_len=256, tail=8, n=6,
                    chunk=16):
    """Prefix cache off vs on at HIGH overlap (every request shares a
    resident ``prefix_len``-token prefix, page- and chunk-aligned):
    per-request TTFT (admission prefill + first token) with a warm
    cache, plus prompt tokens skipped.  And a fork row: n streams off
    one prompt via COW fork vs n independent submissions — best-of-n
    pays the prefill once."""
    from repro.serve import PagedConfig
    cfg = get_config("smollm-360m-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(23)
    pre = rng.integers(0, cfg.vocab, size=prefix_len, dtype=np.int64)
    prompts = [np.concatenate([pre, rng.integers(0, cfg.vocab, size=tail,
                                                 dtype=np.int64)])
               for _ in range(n)]
    max_len = prefix_len + tail + gen + 1
    rows, out = [], {}
    for mode in ("off", "on"):
        sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk,
                              kv_layout="paged",
                              paged=PagedConfig(page_size=chunk))
        eng = ServeEngine(sm, params, slots=batch,
                          prefix_cache=(mode == "on"))
        # warm requests: compile every shape — full prefill, then (cache
        # on) an ATTACHING admission so the seed-gather/tail-prefill
        # programs are built — leaving the shared prefix resident: the
        # steady state the row measures
        eng.submit(prompts[0], max_new_tokens=2)
        eng.run()
        eng.submit(prompts[1], max_new_tokens=2)
        eng.run()
        ttfts = []
        for p in prompts:
            r = eng.submit(p, max_new_tokens=gen)
            s0 = time.perf_counter()
            eng.admit()                    # prefill + first token
            assert r.outputs, "admission did not emit tok0"
            ttfts.append(time.perf_counter() - s0)
            eng.run()                      # drain before the next sample
        out[mode] = float(np.mean(ttfts))
        row = {
            "name": f"prefix_cache/{mode}/P{prefix_len}",
            "us_per_call": f"{out[mode]*1e6:.0f}",
            "derived": f"ttft_ms={out[mode]*1e3:.2f};"
                       f"overlap={prefix_len}/{prefix_len + tail}",
        }
        if mode == "on":
            row["derived"] += (
                f";hits={eng.n_prefix_hits}"
                f";tokens_skipped={eng.n_prefix_tokens}"
                f";ttft_gain={out['off']/max(out['on'],1e-9):.1f}x")
        rows.append(row)

    n_forks = 3
    sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk,
                          kv_layout="paged",
                          paged=PagedConfig(page_size=chunk))
    eng = ServeEngine(sm, params, slots=n_forks + 1)
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run()                              # compile warm-up
    s0 = time.perf_counter()
    parent = eng.submit(prompts[0], max_new_tokens=gen)
    eng.step()
    eng.fork(parent, n_forks)
    eng.run()
    forked = time.perf_counter() - s0
    s0 = time.perf_counter()
    for _ in range(n_forks + 1):
        eng.submit(prompts[0], max_new_tokens=gen)
    eng.run()
    indep = time.perf_counter() - s0
    rows.append({
        "name": f"fork_best_of/{n_forks + 1}/P{prefix_len}",
        "us_per_call": f"{forked*1e6:.0f}",
        "derived": f"forked_s={forked:.4f};independent_s={indep:.4f};"
                   f"speedup={indep/max(forked,1e-9):.1f}x;"
                   f"cow_copies={eng.n_cow_copies}",
    })
    return rows


def _moe_compare(batch=4, gen=8, prompt=16, chunk=8):
    """MoE stack serving: batch-invariant auto dispatch (gather-GEMM
    decode + per-request prefill) vs the pooled capacity dispatch the
    training path uses — same engine, same traffic, tokens/s for both."""
    import dataclasses
    base = get_config("qwen3-moe-30b-a3b-smoke")
    rows = []
    out = {}
    for mode in ("auto", "pooled"):
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, dispatch=mode))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        prompts, glens = _workload(rng, cfg, 2 * batch, prompt, gen, chunk)
        max_len = max(len(p) for p in prompts) + max(glens) + 1
        sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk)
        _warm_engine(sm, params, batch, [len(p) for p in prompts])
        tps, lat, _eng = _run_engine(sm, params, prompts, glens, batch)
        out[mode] = tps
        rows.append({
            "name": f"decode_moe/{mode}/batch{batch}",
            "us_per_call": f"{np.median(lat)*1e6:.0f}",
            "derived": f"tok_s={tps:.1f};"
                       f"p50_ms={np.percentile(lat,50)*1e3:.2f}",
        })
    rows[-1]["derived"] += \
        f";auto_vs_pooled={out['auto']/max(out['pooled'],1e-9):.2f}x"
    return rows


def run(arch="minimalist-lm-360m", batches=(1, 64, 256), gen=16,
        prompt=32, chunk=16, prefill_lens=(256, 512), mesh_spec="",
        kv_layout="dense"):
    reset_checks()
    wall0 = time.perf_counter()
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    rows = []
    layout_kw = {}
    if kv_layout == "paged":
        from repro.serve import PagedConfig
        layout_kw = dict(kv_layout="paged",
                         paged=PagedConfig(page_size=max(chunk, 1)))

    for batch in batches:
        prompts, glens = _workload(rng, cfg, 2 * batch, prompt, gen, chunk)
        max_len = max(len(p) for p in prompts) + max(glens) + 1
        step = _baseline_step_fn(model)
        sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk,
                              **layout_kw)
        # warmup both paths at the timed shapes (compile cost out)
        _run_baseline(model, params, prompts[:batch], [2] * batch,
                      max_len, batch, step)
        _warm_engine(sm, params, batch, [len(p) for p in prompts])

        tps_b, lat_b = _run_baseline(model, params, prompts, glens,
                                     max_len, batch, step)
        tps_e, lat_e, eng = _run_engine(sm, params, prompts, glens, batch)
        tps_s, lat_s, _ = _run_engine(sm, params, prompts, glens, batch,
                                      sampled=True)
        for name, tps, lat in [("static_batch", tps_b, lat_b),
                               ("engine", tps_e, lat_e),
                               ("engine_sampled", tps_s, lat_s)]:
            rows.append({
                "name": f"decode/{name}/batch{batch}",
                "us_per_call": f"{np.median(lat)*1e6:.0f}",
                "derived": f"tok_s={tps:.1f};p50_ms={np.percentile(lat,50)*1e3:.2f};"
                           f"p99_ms={np.percentile(lat,99)*1e3:.2f}",
            })
        rows[-1]["derived"] += (f";sampling_overhead={tps_e/max(tps_s,1e-9):.2f}x"
                                f";compiled_steps={sm._jit_step._cache_size()}")
        rows[-2]["derived"] += f";speedup={tps_e/tps_b:.2f}x;util={eng.utilization:.2f}"

    for P in prefill_lens:
        t = _prefill_compare(model, params, cfg, P, chunk=min(P, 128))
        rows.append({
            "name": f"prefill/P{P}",
            "us_per_call": f"{t['chunked']*1e6:.0f}",
            "derived": f"chunked_s={t['chunked']:.4f};"
                       f"per_token_s={t['per_token']:.4f};"
                       f"speedup={t['per_token']/t['chunked']:.1f}x",
        })
        g = _grid_compare(model, params, cfg, P, chunk=min(P, 128))
        rows.append({
            "name": f"prefill_grid/P{P}",
            "us_per_call": f"{g['padded']*1e6:.0f}",
            "derived": f"padded_s={g['padded']:.4f};"
                       f"remainder_s={g['remainder']:.4f};"
                       f"padded_compiles={g['padded_compiles']};"
                       f"remainder_compiles={g['remainder_compiles']};"
                       f"cold_speedup={g['remainder']/g['padded']:.1f}x",
        })
        rows.extend(_attn_prefill_compare(P, chunk=min(P, 128)))
    rows.extend(_sharded_compare(model, params, cfg, gen=gen,
                                 mesh_spec=mesh_spec))
    rows.extend(_moe_compare(gen=gen))
    rows.extend(_paged_compare(gen=gen))
    rows.extend(_prefix_compare(gen=max(2, gen // 4)))
    emit(rows)
    write_bench("decode_throughput",
                config=dict(arch=arch, batches=list(batches), gen=gen,
                            prompt=prompt, chunk=chunk,
                            prefill_lens=list(prefill_lens),
                            mesh=mesh_spec, kv_layout=kv_layout),
                rows=rows, wall_s=time.perf_counter() - wall0)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minimalist-lm-360m")
    ap.add_argument("--batches", default="1,64,256")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--prefill-lens", default="256,512")
    ap.add_argument("--mesh", default="",
                    help="DxM mesh for the sharded row (default: largest "
                         "2x2-capped grid the local devices allow)")
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="KV layout for the main decode/* engine rows "
                         "(the decode_paged/* comparison rows always run "
                         "both; attention-bearing --arch only for paged)")
    args = ap.parse_args(argv)
    run(arch=args.arch,
        batches=tuple(int(b) for b in args.batches.split(",")),
        gen=args.gen, prompt=args.prompt, chunk=args.chunk,
        prefill_lens=tuple(int(p) for p in args.prefill_lens.split(",")),
        mesh_spec=args.mesh, kv_layout=args.kv_layout)


if __name__ == "__main__":
    main()
