"""Speculative decoding: wave cost vs per-token decode (README section
"Speculative decoding").

What this pins is the MACHINERY, not drafter quality: both models run
with a zeroed LM head, so every logit row is exactly zero and both the
drafter's greedy argmax and the target's pick token 0 — acceptance is
100% by construction.  That makes the measurement deterministic: each
verify wave decides exactly K tokens, so accepted-tokens/step = K and
the batch-1 speedup is the pure ratio (cost of K per-token steps) /
(cost of one propose + verify wave).  Real workloads sit below this
ceiling in proportion to the drafter's actual acceptance rate; the row
is the regression canary for the wave path itself (propose scan, K-wide
verify, page-granular commit, host bookkeeping).

Reported per batch size (default 1 / 4), target smollm smoke (GQA,
paged-gather), drafter minGRU smoke:
  * plain engine tokens/s + per-step p50 vs the spec engine at K=4
  * decoded tokens per engine step (= K at 100% acceptance)
  * acceptance rate (= 1.0 here; < 1 means the wave path regressed)
Asserts: accepted-tokens/step > 1.5 and batch-1 speedup > 1.

    PYTHONPATH=src python -m benchmarks.spec_decode [--spec-k 4] \
        [--batches 1,4] [--gen 32]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import check, emit, reset_checks, write_bench
from repro.configs import get_config
from repro.models import build_model
from repro.serve import (DecoderStepModel, DraftStepModel, PagedConfig,
                         ServeEngine)

TARGET = "smollm-360m-smoke"
DRAFTER = "minimalist-lm-360m-smoke"


def _zero_head(params):
    """Zero the LM head so logits are exactly 0 for every token: greedy
    argmax is deterministically token 0 for ANY stack, which makes an
    arbitrary drafter agree with an arbitrary target on every draft."""
    key = "lm_head" if "lm_head" in params else "embed"
    return {**params,
            key: jax.tree_util.tree_map(jnp.zeros_like, params[key])}


def _build(spec_k, slots, max_len, page_size=16):
    cfg = dataclasses.replace(get_config(TARGET), paged_impl="gather")
    model = build_model(cfg)
    params = _zero_head(model.init(jax.random.PRNGKey(0)))
    sm = DecoderStepModel(model, max_len=max_len, kv_layout="paged",
                          paged=PagedConfig(page_size=page_size))
    kw = {}
    if spec_k > 1:
        dmodel = build_model(get_config(DRAFTER))
        dparams = _zero_head(dmodel.init(jax.random.PRNGKey(1)))
        kw = dict(drafter=DraftStepModel(dmodel, spec_k=spec_k),
                  drafter_params=dparams, spec_k=spec_k)
    return ServeEngine(sm, params, slots=slots, **kw), cfg


def _drain(eng, prompts, glens, timed):
    """Submit the workload and drain it; per-decode-step latencies out.
    Counter deltas (not totals) so a warmup drain on the same engine —
    which owns the compile caches — stays out of the timed numbers."""
    d0, s0 = eng._n_decoded, eng.n_steps
    for p, g in zip(prompts, glens):
        eng.submit(p, max_new_tokens=int(g))
    lat = []
    t0 = time.perf_counter()
    while eng.waiting or eng.active.any():
        eng.admit()
        t1 = time.perf_counter()
        eng.step()
        lat.append(time.perf_counter() - t1)
    dt = time.perf_counter() - t0
    decoded, steps = eng._n_decoded - d0, eng.n_steps - s0
    if not timed:
        return None
    return {"tok_s": decoded / dt, "lat": np.array(lat),
            "per_step": decoded / max(steps, 1)}


def run(spec_k=4, batches=(1, 4), gen=32, prompt=16):
    reset_checks()
    wall0 = time.perf_counter()
    rng = np.random.default_rng(29)
    rows = []
    for batch in batches:
        n = 2 * batch
        prompts = [rng.integers(0, 512, size=prompt, dtype=np.int64)
                   for _ in range(n)]
        glens = [gen] * n
        max_len = prompt + gen + spec_k + 1
        out = {}
        for label, k in (("plain", 1), (f"spec_k{spec_k}", spec_k)):
            eng, _cfg = _build(k, batch, max_len)
            _drain(eng, prompts, glens, timed=False)      # compile
            r = _drain(eng, prompts, glens, timed=True)
            check(eng.pool.pages_in_use == 0,
                  f"pool_drained_{label}_batch{batch}",
                  f"{eng.pool.pages_in_use} pages leaked")
            r["accept"] = eng.stats().accept_rate if k > 1 else 0.0
            out[label] = r
            rows.append({
                "name": f"spec_decode/{label}/batch{batch}",
                "us_per_call": f"{np.median(r['lat'])*1e6:.0f}",
                "derived": f"tok_s={r['tok_s']:.1f};"
                           f"p50_ms={np.percentile(r['lat'],50)*1e3:.2f};"
                           f"tokens_per_step={r['per_step']:.2f};"
                           f"accept_rate={r['accept']:.2f}",
            })
        spec = out[f"spec_k{spec_k}"]
        speedup = spec["tok_s"] / max(out["plain"]["tok_s"], 1e-9)
        rows[-1]["derived"] += f";vs_plain={speedup:.2f}x"
        # the two acceptance bars: the wave must beat per-token decode
        # at batch 1, and each step must decide clearly more than one
        # token (the zero-head drafter makes both deterministic)
        per_slot = spec["per_step"] / max(batch, 1)
        check(per_slot > 1.5, f"tokens_per_step_batch{batch}",
              f"{per_slot:.2f} accepted tokens/step <= 1.5")
        if batch == 1:
            check(speedup > 1.0, "batch1_spec_speedup",
                  f"batch-1 spec speedup {speedup:.2f}x <= 1")
    emit(rows)
    write_bench("spec_decode",
                config=dict(target=TARGET, drafter=DRAFTER, spec_k=spec_k,
                            batches=list(batches), gen=gen, prompt=prompt),
                rows=rows, wall_s=time.perf_counter() - wall0)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--batches", default="1,4")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--prompt", type=int, default=16)
    args = ap.parse_args(argv)
    run(spec_k=args.spec_k,
        batches=tuple(int(b) for b in args.batches.split(",")),
        gen=args.gen, prompt=args.prompt)


if __name__ == "__main__":
    main()
