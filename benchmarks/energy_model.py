"""Paper §4.2: energy per time step of the mixed-signal cores.

The paper bounds a 4-core 64×64 network at ≤169 pJ per time step (worst
case, all switches toggling, z = 1; SAR ADC / routing / control excluded).
This benchmark evaluates our structural energy model at the paper's
configuration and sweeps activity (z) and array geometry.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.analog import EnergyConfig, energy_per_step

PAPER_BOUND_PJ = 169.0


def run():
    rows = []
    base = energy_per_step(rows=64, cols=64, n_cores=4, z_mean=1.0)
    rows.append({
        "name": "energy/paper_config_worst_case",
        "us_per_call": "",
        "derived": f"total_pJ={base['total_pJ']:.1f};"
                   f"paper_bound_pJ={PAPER_BOUND_PJ};"
                   f"within_bound={base['total_pJ'] <= PAPER_BOUND_PJ}",
    })
    for z in (0.0, 0.25, 0.5, 1.0):
        e = energy_per_step(64, 64, 4, z_mean=z)
        rows.append({"name": f"energy/z{z}",
                     "derived": f"total_pJ={e['total_pJ']:.1f}"})
    for r, c, n in ((64, 64, 1), (128, 128, 4), (256, 256, 16)):
        e = energy_per_step(r, c, n)
        rows.append({
            "name": f"energy/{n}x{r}x{c}",
            "derived": f"total_pJ={e['total_pJ']:.1f};"
                       f"pJ_per_synapse={e['total_pJ']/(r*c*n):.4f}",
        })
    # breakdown at the paper config
    rows.append({
        "name": "energy/breakdown_paper_config",
        "derived": ";".join(f"{k}={v*1e12:.1f}pJ" for k, v in base.items()
                            if k.endswith("_J")),
    })
    assert base["total_pJ"] <= PAPER_BOUND_PJ
    return emit(rows)


if __name__ == "__main__":
    run()
