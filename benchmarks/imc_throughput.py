"""IMC projection benchmark (paper §3.1.1, Eq. 6): binary-activation ×
2 b-weight MVM.  Reports XLA-path timing and the derived weight-memory
compression (2 b codes vs fp32: 16×; stored as int8 here: 4× on the wire,
16× in information terms — see kernels/imc_mvm docstring).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.imc_mvm import ops


def run():
    key = jax.random.PRNGKey(0)
    rows = []
    f = jax.jit(lambda x, c, s: ops.imc_mvm(x, c, s, backend="xla"))
    for (M, K, N) in [(256, 64, 64), (1024, 256, 256), (4096, 1024, 1024)]:
        x = (jax.random.uniform(jax.random.fold_in(key, 1), (M, K)) > 0.5
             ).astype(jnp.float32)
        codes = jax.random.randint(jax.random.fold_in(key, 2), (K, N), 0, 4
                                   ).astype(jnp.int8)
        scale = jnp.full((N,), 0.1)
        us = time_fn(f, x, codes, scale, iters=5)
        flops = 2 * M * K * N
        rows.append({
            "name": f"imc_mvm/xla/M{M}_K{K}_N{N}",
            "us_per_call": f"{us:.0f}",
            "derived": f"GFLOPs={flops/us/1e3:.2f};weight_bits=2",
        })
    M, K, N = 128, 128, 128
    x = (jax.random.uniform(key, (M, K)) > 0.5).astype(jnp.float32)
    codes = jax.random.randint(key, (K, N), 0, 4).astype(jnp.int8)
    us = time_fn(lambda: ops.imc_mvm(x, codes, jnp.full((N,), 0.1),
                                     backend="pallas"), iters=2, warmup=1)
    rows.append({
        "name": f"imc_mvm/pallas_interpret/M{M}_K{K}_N{N}",
        "us_per_call": f"{us:.0f}",
        "derived": "interpret=True(CPU validation path)",
    })
    return emit(rows)


if __name__ == "__main__":
    run()
