"""Paper Fig. 4: software model vs mixed-signal (behavioral) simulation.

Trains nothing — builds a hardware-constrained network, exports it to
capacitor codes / DAC presets, runs the switched-capacitor simulator on the
same binary input stream and reports trace agreement:

  * z: exact 6 b code match rate (open loop)
  * h̃, h: RMSE in model units (open loop)
  * binary activations: agreement rate, open and closed loop
  * readout: max abs deviation

Open loop (per-layer teacher forcing) isolates the circuit mapping — it
must be bit-exact up to comparator threshold ties; closed loop is the
paper's end-to-end Fig. 4 regime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import quant
from repro.core.analog import AnalogConfig, analog_forward, export_layer
from repro.core.mingru import MinimalistNetwork


def run():
    qcfg = quant.QuantConfig.hardware()
    dims = (8, 32, 32, 10)
    net = MinimalistNetwork(dims, qcfg=qcfg)
    key = jax.random.PRNGKey(0)
    params = net.init(key)
    B, T = 4, 60
    x = (jax.random.uniform(jax.random.fold_in(key, 1), (B, T, dims[0]))
         > 0.5).astype(jnp.float32)

    logits, sw = net(params, x, collect_traces=True)
    acfg = AnalogConfig()
    images = [export_layer(params[b.name], acfg) for b in net.blocks]

    rows = []
    us = time_fn(lambda: analog_forward(images, x, acfg,
                                        collect_traces=False)[0], iters=3)

    # open loop
    forced = [np.asarray(sw[b.name]["out"]) for b in net.blocks[:-1]]
    ro_o, an_o = analog_forward(images, x, acfg, forced_inputs=forced)
    for li, b in enumerate(net.blocks):
        z_match = float((np.asarray(sw[b.name]["z"])
                         == np.asarray(an_o[li]["z"])).mean())
        h_rmse = float(np.sqrt(np.mean(
            (np.asarray(sw[b.name]["h"]) - np.asarray(an_o[li]["h"])) ** 2)))
        rows.append({
            "name": f"fig4/open_loop/layer{li}",
            "derived": f"z_code_match={z_match:.4f};h_rmse={h_rmse:.2e}",
        })
    # closed loop
    ro_c, an_c = analog_forward(images, x, acfg)
    out_agree = np.mean([
        (np.asarray(sw[b.name]["out"]) == np.asarray(an_c[li]["out"])).mean()
        for li, b in enumerate(net.blocks[:-1])])
    readout_dev = float(np.abs(np.asarray(ro_c) - np.asarray(logits)).max())
    pred_agree = float((np.argmax(np.asarray(ro_c), -1)
                        == np.argmax(np.asarray(logits), -1)).mean())
    rows.append({
        "name": "fig4/closed_loop",
        "us_per_call": f"{us:.0f}",
        "derived": f"binary_agreement={out_agree:.4f};"
                   f"readout_maxdev={readout_dev:.3f};"
                   f"pred_agreement={pred_agree:.3f}",
    })
    # with device non-idealities (mismatch + comparator noise)
    from repro.core.analog import make_mismatch
    acfg_mm = AnalogConfig(mismatch_sigma=0.01, comparator_noise_v=0.002)
    mm = make_mismatch(jax.random.PRNGKey(3), images, acfg_mm)
    ro_m, _ = analog_forward(images, x, acfg_mm, mismatch=mm,
                             key=jax.random.PRNGKey(4),
                             collect_traces=False)
    agree_m = float((np.argmax(np.asarray(ro_m), -1)
                     == np.argmax(np.asarray(logits), -1)).mean())
    rows.append({
        "name": "fig4/closed_loop_1pct_mismatch",
        "derived": f"pred_agreement={agree_m:.3f}",
    })
    return emit(rows)


if __name__ == "__main__":
    run()
