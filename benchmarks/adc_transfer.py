"""Paper Fig. 3C: SAR-ADC transfer characteristics vs slope / offset.

Reproduces the family of transfer curves: slope controlled by the connected
C_IMC/C_ADC segment ratio (input-referred LSB), offset by the capacitive-DAC
preset. Emits, per (lsb, offset): the live-region width in volts and the
transfer midpoint — the quantities Fig. 3C sweeps.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.analog import AnalogConfig, sar_adc


def run():
    acfg = AnalogConfig()
    v = jnp.linspace(0.0, 0.8, 4001)
    rows = []
    for lsb_mv in (2.0, 4.0, 8.0):
        for off in (-16, 0, 16):
            codes = np.asarray(sar_adc(v, acfg, lsb_volts=lsb_mv * 1e-3,
                                       offset_code=off))
            live = (codes > 0) & (codes < 63)
            width = live.sum() * (0.8 / 4000)
            mid_idx = np.abs(codes - 32).argmin()
            us = time_fn(lambda: sar_adc(v, acfg, lsb_volts=lsb_mv * 1e-3,
                                         offset_code=off), iters=5)
            rows.append({
                "name": f"adc_transfer/lsb{lsb_mv}mV_off{off:+d}",
                "us_per_call": f"{us:.1f}",
                "derived": f"live_width_V={width:.3f};"
                           f"midpoint_V={float(v[mid_idx]):.3f}",
            })
    # slope monotonicity check (steeper = narrower live region)
    widths = [float(r["derived"].split(";")[0].split("=")[1])
              for r in rows[::3]]
    assert widths[0] < widths[1] < widths[2], widths
    return emit(rows)


if __name__ == "__main__":
    run()
