"""Paper Fig. 5: the quantization ladder on the sequential task.

Three models, identical topology and parameter count:
  1. fp32 baseline (original minGRU activations)        — paper: 98.1 %
  2. 2 b weights / 6 b biases / binary σ_h              — paper: 97.7 %
  3. fully hardware-compatible (+ hard-σ, 6 b z)        — paper: 96.9 %

Paper numbers are full sMNIST (60 k images, 784 steps, 64-unit layers,
long training); this CPU benchmark runs the procedurally generated
surrogate (DESIGN.md §3) at reduced scale — the MEASURE is the relative
degradation down the ladder, which is what Fig. 5 demonstrates.
Multi-stage QAT (4 gradual phases) is used exactly as in the paper.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.quant import QAT_PHASES
from repro.data.smnist import load_smnist
from repro.train.qat import QATConfig, train_qat

PAPER = {"float": 0.981, "quantized": 0.977, "hardware": 0.969}


def run(fast: bool = True):
    (xtr, ytr), (xte, yte) = load_smnist(seed=0, n_train=1024, n_test=512)
    stride = 8 if fast else 1
    train, test = (xtr[:, ::stride], ytr), (xte[:, ::stride], yte)
    cfg = QATConfig(dims=(1, 48, 48, 10),
                    phase_epochs=(12, 8, 8, 8) if fast else (30, 15, 15, 15),
                    batch=64, lr=5e-3)
    t0 = time.time()
    params, results = train_qat(train, test, cfg, verbose=False)
    dt = time.time() - t0

    # phases 0/2/3 correspond to Fig. 5's float / quantized / hardware
    ladder = {"float": results[0]["test_acc"],
              "quantized": results[2]["test_acc"],
              "hardware": results[3]["test_acc"]}
    rows = []
    for k, acc in ladder.items():
        rows.append({
            "name": f"fig5/{k}",
            "us_per_call": "",
            "derived": f"test_acc={acc:.4f};paper_acc={PAPER[k]:.3f};"
                       f"rel_drop={(ladder['float']-acc):.4f};"
                       f"paper_rel_drop={PAPER['float']-PAPER[k]:.4f}",
        })
    rows.append({"name": "fig5/train_wall_s",
                 "derived": f"{dt:.1f}s;phases=4(QAT)"})
    return emit(rows)


if __name__ == "__main__":
    run()
