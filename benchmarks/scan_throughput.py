"""minGRU state-update engines (paper §2): sequential vs parallel scan vs
Pallas kernel (interpret mode on CPU — correctness-path timing only; the
TPU roofline for the kernel is in EXPERIMENTS.md §Roofline).

Derived metric: elements/s and the parallel-over-sequential speedup — the
minGRU paper's training-time enabler that the MINIMALIST paper inherits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.linear_scan import ops, ref


def run():
    key = jax.random.PRNGKey(0)
    rows = []
    seq = jax.jit(lambda a, b, h0: ref.linear_scan_sequential(a, b, h0))
    par = jax.jit(lambda a, b, h0: ref.linear_scan_associative(a, b, h0))
    for (B, T, D) in [(8, 256, 64), (8, 1024, 64), (1, 4096, 256)]:
        a = jax.random.uniform(jax.random.fold_in(key, 1), (B, T, D))
        b = jax.random.normal(jax.random.fold_in(key, 2), (B, T, D))
        h0 = jnp.zeros((B, D))
        us_seq = time_fn(seq, a, b, h0, iters=5)
        us_par = time_fn(par, a, b, h0, iters=5)
        n = B * T * D
        rows.append({
            "name": f"scan/seq/B{B}_T{T}_D{D}",
            "us_per_call": f"{us_seq:.0f}",
            "derived": f"Melem_s={n/us_seq:.1f}",
        })
        rows.append({
            "name": f"scan/assoc/B{B}_T{T}_D{D}",
            "us_per_call": f"{us_par:.0f}",
            "derived": f"Melem_s={n/us_par:.1f};"
                       f"speedup_vs_seq={us_seq/us_par:.2f}x",
        })
    # pallas kernel (interpret) — correctness-path cost on CPU
    B, T, D = 2, 256, 256
    a = jax.random.uniform(jax.random.fold_in(key, 3), (B, T, D))
    b = jax.random.normal(jax.random.fold_in(key, 4), (B, T, D))
    h0 = jnp.zeros((B, D))
    us = time_fn(lambda: ops.linear_scan(a, b, h0, "pallas"), iters=2,
                 warmup=1)
    rows.append({
        "name": f"scan/pallas_interpret/B{B}_T{T}_D{D}",
        "us_per_call": f"{us:.0f}",
        "derived": "interpret=True(CPU validation path)",
    })
    return emit(rows)


if __name__ == "__main__":
    run()
