"""Shared benchmark utilities: timing, CSV rows, JSON artifacts.

Benchmarks report two ways:

  * ``emit(rows)`` — the historical CSV lines on stdout (kept; CI greps
    them and the perf trajectory in ROADMAP.md quotes them);
  * ``write_bench(name, ...)`` — a machine-readable ``BENCH_<name>.json``
    artifact carrying the run config, every row, every PINNED assertion
    the run verified (recorded via :func:`check`), and wall time — the
    nightly workflow uploads these so perf history is diffable without
    parsing log text.

Artifact schema (``repro-bench/v1``)::

    {"schema": "repro-bench/v1", "name": ..., "created_unix": ...,
     "config": {...}, "rows": [{"name", "us_per_call", "derived"}, ...],
     "assertions": [{"name", "passed", "detail"}, ...],
     "wall_time_s": ...}

``check(cond, name, detail)`` both RECORDS the assertion outcome for the
artifact and raises on failure (same behavior as the bare ``assert`` it
replaces) — a bench artifact therefore lists exactly the invariants the
run proved, and a failed run still dies loudly.
"""
from __future__ import annotations

import json
import os
import time

import jax

#: Assertion outcomes recorded by :func:`check` since :func:`reset_checks`.
_CHECKS: list = []


def time_fn(fn, *args, warmup=2, iters=10, **kw):
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows):
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
    return rows


# -- pinned-assertion recording ------------------------------------------
def reset_checks():
    """Start a fresh assertion record (call at the top of ``run()``)."""
    _CHECKS.clear()


def check(cond, name: str, detail: str = ""):
    """Record a pinned assertion for the bench artifact AND enforce it.

    Drop-in for ``assert cond, f"{name}: {detail}"`` — the outcome is
    recorded (pass or fail) before the failure raises, so a failed
    nightly still uploads an artifact naming the broken invariant."""
    _CHECKS.append({"name": str(name), "passed": bool(cond),
                    "detail": str(detail)})
    assert cond, f"{name}: {detail}"


def checks() -> list:
    """The assertion record accumulated since :func:`reset_checks`."""
    return list(_CHECKS)


# -- machine-readable artifacts ------------------------------------------
def write_bench(name: str, *, config, rows, wall_s, assertions=None,
                out_dir=None) -> str:
    """Write ``BENCH_<name>.json`` (schema ``repro-bench/v1``).

    ``assertions=None`` takes the :func:`check` record accumulated since
    the last :func:`reset_checks`.  ``out_dir`` defaults to ``$BENCH_DIR``
    or the current directory (where CI's upload-artifact glob looks)."""
    doc = {"schema": "repro-bench/v1",
           "name": str(name),
           "created_unix": time.time(),
           "config": dict(config),
           "rows": [dict(r) for r in rows],
           "assertions": (checks() if assertions is None
                          else [dict(a) for a in assertions]),
           "wall_time_s": float(wall_s)}
    validate_bench(doc)
    out_dir = out_dir or os.environ.get("BENCH_DIR") or "."
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench artifact: {path} ({len(doc['rows'])} rows, "
          f"{len(doc['assertions'])} assertions, "
          f"{doc['wall_time_s']:.1f}s)")
    return path


def validate_bench(doc) -> dict:
    """Schema check for a ``repro-bench/v1`` document; raises ValueError
    on shape violations, returns the doc unchanged."""
    if not isinstance(doc, dict):
        raise ValueError(f"bench doc must be a dict, got {type(doc)}")
    if doc.get("schema") != "repro-bench/v1":
        raise ValueError(f"unknown bench schema {doc.get('schema')!r}")
    for key, typ in (("name", str), ("config", dict), ("rows", list),
                     ("assertions", list), ("wall_time_s", (int, float)),
                     ("created_unix", (int, float))):
        if not isinstance(doc.get(key), typ):
            raise ValueError(f"bench field {key!r} must be {typ}, "
                             f"got {type(doc.get(key))}")
    for r in doc["rows"]:
        if not isinstance(r, dict) or "name" not in r:
            raise ValueError(f"bench row must be a dict with 'name': {r!r}")
    for a in doc["assertions"]:
        if (not isinstance(a, dict) or "name" not in a
                or "passed" not in a):
            raise ValueError("bench assertion must be a dict with "
                             f"'name' and 'passed': {a!r}")
    return doc
