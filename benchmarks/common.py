"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup=2, iters=10, **kw):
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows):
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
    return rows
