# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.
#
#   fig5   -> quant_ladder          (paper Fig. 5, quantization ladder)
#   fig4   -> mixed_signal_match    (paper Fig. 4, software vs circuit)
#   fig3C  -> adc_transfer          (paper Fig. 3C, ADC slope/offset)
#   §4.2   -> energy_model          (169 pJ/step bound)
#   §2     -> scan_throughput       (minGRU parallel-scan enabler)
#   §3.1.1 -> imc_throughput        (Eq. 6 IMC projection)
#   assignment §Roofline -> roofline_report (dry-run-derived table)
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (adc_transfer, energy_model, imc_throughput,
                        mixed_signal_match, quant_ladder, roofline_report,
                        scan_throughput)

SUITES = [
    ("adc_transfer", adc_transfer),
    ("energy_model", energy_model),
    ("mixed_signal_match", mixed_signal_match),
    ("scan_throughput", scan_throughput),
    ("imc_throughput", imc_throughput),
    ("quant_ladder", quant_ladder),
    ("roofline_report", roofline_report),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in SUITES:
        t0 = time.time()
        try:
            mod.run()
            print(f"# suite {name} finished in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# suite {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
