"""Scheduling policies under load: TTFT / inter-token latency vs fifo.

The harness replays ONE arrival trace (Poisson arrivals on a virtual
step clock, or a JSON trace file) through the engine once per policy and
reports, per policy:

  * p50 / p99 time-to-first-token, in ENGINE STEPS (deterministic,
    hardware-independent — this is what the improvement is pinned on)
    and in wall-clock ms, overall and for the high-priority class;
  * p50 / p99 inter-token latency (wall time of one PURE decode step —
    every active request emits one token per step; steps that also ran
    admission prefill are excluded so the column is not prefill noise);
  * the engine's final ``stats()`` snapshot (steps, preemptions, slot
    utilization) so the artifact records HOW the policy got its win.

The default trace manufactures an overload: a burst of long low-priority
jobs lands at step 0 (more than the engine has slots), then a Poisson
stream of short jobs — some high-priority — arrives into the jam.
Under fifo the burst forms a convoy: every later arrival, however short
or urgent, waits for it.  ``priority`` preempts the convoy for the
high class; ``sjf`` slots short prefill work around it (aging bounds
how long the burst can be bypassed).  The harness ASSERTS the wins,
each on the class the policy actually optimizes:

  * priority: p99 TTFT (steps) of the HIGH class strictly beats fifo;
  * sjf:      p99 TTFT (steps) of the SHORT class (prompt < the convoy
    length) strictly beats fifo, and p50 across ALL requests strictly
    beats fifo.  The long jobs' aging toll is reported, not pinned —
    under sustained overload every policy's all-requests tail is
    capacity-bound, and trading a bounded few steps of convoy TTFT for
    the short class's tail is exactly sjf's bargain.
  * edf:      deadline-MISS RATE (finish step > the request's deadline,
    scored over the SLO-tagged requests) strictly beats fifo.  Short
    arrivals carry ``deadline = arrival + gen + SLO_SLACK`` on the
    virtual step clock; the convoy is best-effort (no deadline), so it
    sorts last at admission and is the first preemption victim.

Both streams are bitwise identical across policies (counter-based PRNG;
see tests/test_serve_scheduler.py) — the harness also checks that, so a
latency win can never be bought with changed bytes.

    PYTHONPATH=src python -m benchmarks.load_serve [--smoke] \
        [--arch smollm-360m-smoke] [--slots 4] [--n 32] [--rate 1.5] \
        [--policies fifo,priority,sjf,edf] [--trace trace.json]

Trace file format: JSON list of [arrival_step, prompt_len, max_new,
priority] or [arrival_step, prompt_len, max_new, priority, deadline]
rows (sorted by arrival_step; deadline null = best-effort).
"""
from __future__ import annotations

import argparse
import json
import time
from collections import deque

import jax
import numpy as np

from benchmarks.common import check, emit, reset_checks, write_bench
from benchmarks.decode_throughput import _warm_engine
from repro.common import pow2ceil
from repro.configs import get_config
from repro.models import build_model
from repro.serve import DecoderStepModel, PagedConfig, ServeEngine

LONG_P, LONG_G = 24, 16          # the convoy job
SHORT_PS, SHORT_GS = (4, 6, 8), (3, 4, 5, 6)
HIGH_PRIORITY = 5
SLO_SLACK = 12                   # steps past arrival + gen before a miss


def poisson_trace(rng, n, rate, slots, p_high=0.25, p_long=0.1):
    """Burst of ``slots + 1`` long jobs at step 0, then ``n`` Poisson
    arrivals (mean ``rate`` requests/step) of mostly short jobs.  Short
    jobs carry a step-clock deadline (the SLO class edf optimizes);
    the convoy and long arrivals are best-effort (deadline None)."""
    trace = [(0, LONG_P, LONG_G, 0, None) for _ in range(slots + 1)]
    t = 0.0
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        if rng.random() < p_long:
            plen, gen, prio, dl = LONG_P, LONG_G, 0, None
        else:
            plen = int(rng.choice(SHORT_PS))
            gen = int(rng.choice(SHORT_GS))
            prio = HIGH_PRIORITY if rng.random() < p_high else 0
            dl = int(t) + gen + SLO_SLACK
        trace.append((int(t), plen, gen, prio, dl))
    return trace


def load_trace(path):
    with open(path) as f:
        rows = json.load(f)
    return [(int(r[0]), int(r[1]), int(r[2]), int(r[3]),
             None if len(r) < 5 or r[4] is None else float(r[4]))
            for r in rows]


def replay(trace, policy, model, params, cfg, slots, max_len, seed):
    """Drive the engine over the trace on a virtual step clock."""
    chunk = 8
    sm = DecoderStepModel(model, max_len=max_len, prefill_chunk=chunk,
                          kv_layout="paged",
                          paged=PagedConfig(page_size=4))
    # warm every admission-wave shape + the decode step so the wall-ms
    # columns measure scheduling, not XLA compiles (the engine pads each
    # prompt to its chunk grid: chunk = min(prefill_chunk, pow2ceil(P)))
    grid = sorted({-(-p // min(chunk, pow2ceil(p)))
                   * min(chunk, pow2ceil(p))
                   for _s, p, _g, _pr, _dl in trace})
    _warm_engine(sm, params, slots, grid)
    eng = ServeEngine(sm, params, slots=slots, policy=policy)
    rng = np.random.default_rng(seed)    # same seed -> same prompt bytes
    pending = deque(
        (astep, rng.integers(0, cfg.vocab, size=plen), gen, prio, dl)
        for astep, plen, gen, prio, dl in trace)
    arrived, tok0, fin = {}, {}, {}      # req -> arrival/tok0/finish step
    wall_in, wall_tok0 = {}, {}
    itl = []
    step_no = 0

    def observe():
        for r in arrived:
            if r not in tok0 and r.outputs:
                tok0[r] = step_no
                wall_tok0[r] = time.perf_counter()
            if r not in fin and r.finished:
                fin[r] = step_no

    while pending or eng.waiting or bool(eng.active.any()):
        while pending and pending[0][0] <= step_no:
            _a, prompt, gen, prio, dl = pending.popleft()
            r = eng.submit(prompt, max_new_tokens=gen, priority=prio,
                           deadline=dl)
            arrived[r] = step_no
            wall_in[r] = time.perf_counter()
        # step() admits first, then decodes — no explicit admit() here:
        # it would run the policy's begin_round twice per virtual step
        # and age sjf's queue at 2x the configured rate
        prev_steps = eng.n_steps
        prev_done = len(eng.finished)
        prefills = eng.n_emitted - eng._n_decoded
        s0 = time.perf_counter()
        eng.step()
        dt = time.perf_counter() - s0
        observe()                        # tok0 lands at admission or decode
        if eng.n_steps > prev_steps:
            if eng.n_emitted - eng._n_decoded == prefills:
                itl.append(dt)           # pure decode step: keep the
            step_no += 1                 # itl column free of prefill
        elif len(eng.finished) > prev_done:
            continue                     # a wave admitted and retired
        elif pending:                    # idle gap: jump to next arrival
            step_no = max(step_no + 1, pending[0][0])
        else:                            # blocked with no arrivals left
            raise RuntimeError("trace stalled: waiting requests but "
                               "nothing running and nothing arriving")

    assert len(tok0) == len(arrived), "some request never emitted tok0"
    assert len(fin) == len(arrived), "some request never finished"
    recs = [{"req": r,
             "prio": r.priority,
             "ttft_steps": tok0[r] - arrived[r],
             "ttft_ms": (wall_tok0[r] - wall_in[r]) * 1e3,
             "deadline": r.deadline,
             "missed": (r.deadline is not None
                        and fin[r] > r.deadline)}
            for r in arrived]
    streams = {r.uid: list(map(int, r.tokens)) for r in arrived}
    return recs, np.array(itl), eng.stats(), streams


def _pct(vals, q):
    vals = np.asarray(vals, float)
    return float(np.percentile(vals, q)) if len(vals) else 0.0


def summarize(policy, recs, itl, stats):
    rows = []
    classes = [("all", recs),
               ("high", [r for r in recs if r["prio"] > 0]),
               ("short", [r for r in recs
                          if len(r["req"].prompt) < LONG_P])]
    for label, rs in classes:
        steps = [r["ttft_steps"] for r in rs]
        ms = [r["ttft_ms"] for r in rs]
        rows.append({
            "name": f"load_serve/{policy}/ttft_{label}",
            "us_per_call": f"{_pct(ms, 50) * 1e3:.0f}",
            "derived": f"n={len(rs)};"
                       f"p50_steps={_pct(steps, 50):.1f};"
                       f"p99_steps={_pct(steps, 99):.1f};"
                       f"p50_ms={_pct(ms, 50):.2f};"
                       f"p99_ms={_pct(ms, 99):.2f}",
        })
    rows.append({
        "name": f"load_serve/{policy}/itl",
        "us_per_call": f"{np.median(itl) * 1e6:.0f}",
        "derived": f"p50_ms={_pct(itl * 1e3, 50):.2f};"
                   f"p99_ms={_pct(itl * 1e3, 99):.2f};"
                   f"steps={stats.n_steps};"
                   f"preemptions={stats.n_preemptions};"
                   f"util={stats.utilization:.2f}",
    })
    slo = [r for r in recs if r["deadline"] is not None]
    missed = sum(r["missed"] for r in slo)
    rows.append({
        "name": f"load_serve/{policy}/deadline",
        "us_per_call": "0",
        "derived": f"n_slo={len(slo)};missed={missed};"
                   f"miss_rate={missed / max(len(slo), 1):.3f}",
    })
    return rows


def run(arch="smollm-360m-smoke", slots=4, n=32, rate=1.5, seed=0,
        policies=("fifo", "priority", "sjf", "edf"), trace_path=None):
    reset_checks()
    wall0 = time.perf_counter()
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    trace = (load_trace(trace_path) if trace_path
             else poisson_trace(rng, n, rate, slots))
    max_len = max(p + g for _s, p, g, _pr, _dl in trace) + 1

    rows, p99, miss = [], {}, {}
    streams = {}
    for policy in policies:
        recs, itl, stats, toks = replay(trace, policy, model, params,
                                        cfg, slots, max_len, seed + 1)
        streams[policy] = toks
        rows.extend(summarize(policy, recs, itl, stats))
        p99[policy, "all"] = _pct([r["ttft_steps"] for r in recs], 99)
        p99[policy, "high"] = _pct([r["ttft_steps"] for r in recs
                                    if r["prio"] > 0], 99)
        shorts = [r["ttft_steps"] for r in recs
                  if len(r["req"].prompt) < LONG_P]
        p99[policy, "short"] = _pct(shorts, 99)
        p99[policy, "p50"] = _pct([r["ttft_steps"] for r in recs], 50)
        slo = [r for r in recs if r["deadline"] is not None]
        miss[policy] = (sum(r["missed"] for r in slo)
                        / max(len(slo), 1))

    for policy in policies:              # latency won, bytes untouched
        check(streams[policy] == streams[policies[0]],
              f"streams_bitwise_{policy}",
              f"{policy} changed token bytes vs {policies[0]}")

    derived = [f"n_requests={len(trace)}", f"slots={slots}"]
    if "fifo" in policies and "priority" in policies:
        f, p = p99["fifo", "high"], p99["priority", "high"]
        check(p < f, "priority_beats_fifo_high_p99",
              f"priority p99 TTFT (high class) {p:.1f} steps "
              f"did not beat fifo {f:.1f}")
        derived.append(f"high_p99_steps_fifo={f:.1f}")
        derived.append(f"high_p99_steps_priority={p:.1f}")
        derived.append(f"priority_win={f / max(p, 1.0):.1f}x")
    if "fifo" in policies and "sjf" in policies:
        f, s = p99["fifo", "short"], p99["sjf", "short"]
        check(s < f, "sjf_beats_fifo_short_p99",
              f"sjf p99 TTFT (short class) {s:.1f} steps did "
              f"not beat fifo {f:.1f}")
        f50, s50 = p99["fifo", "p50"], p99["sjf", "p50"]
        check(s50 < f50, "sjf_beats_fifo_all_p50",
              f"sjf p50 TTFT (all) {s50:.1f} steps did "
              f"not beat fifo {f50:.1f}")
        derived.append(f"short_p99_steps_fifo={f:.1f}")
        derived.append(f"short_p99_steps_sjf={s:.1f}")
        derived.append(f"sjf_win={f / max(s, 1.0):.1f}x")
        derived.append(f"all_p50_steps_fifo={f50:.1f}")
        derived.append(f"all_p50_steps_sjf={s50:.1f}")
        derived.append(f"all_p99_steps_fifo={p99['fifo', 'all']:.1f}")
        derived.append(f"all_p99_steps_sjf={p99['sjf', 'all']:.1f}")
    if "fifo" in policies and "edf" in policies:
        f, e = miss["fifo"], miss["edf"]
        check(e < f, "edf_beats_fifo_miss_rate",
              f"edf deadline miss rate {e:.3f} did not beat "
              f"fifo {f:.3f}")
        derived.append(f"miss_rate_fifo={f:.3f}")
        derived.append(f"miss_rate_edf={e:.3f}")
    rows.append({"name": "load_serve/summary", "us_per_call": "0",
                 "derived": ";".join(derived)})
    emit(rows)
    write_bench("load_serve",
                config=dict(arch=arch, slots=slots, n=n, rate=rate,
                            seed=seed, policies=list(policies),
                            trace=trace_path),
                rows=rows, wall_s=time.perf_counter() - wall0)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--n", type=int, default=32,
                    help="Poisson arrivals after the overload burst")
    ap.add_argument("--rate", type=float, default=1.5,
                    help="mean arrivals per engine step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policies", default="fifo,priority,sjf,edf")
    ap.add_argument("--trace", default=None,
                    help="JSON trace file: [[step, plen, gen, prio], ..]")
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI (same asserts)")
    args = ap.parse_args(argv)
    n, slots = (12, 2) if args.smoke else (args.n, args.slots)
    run(arch=args.arch, slots=slots, n=n, rate=args.rate,
        seed=args.seed, policies=tuple(args.policies.split(",")),
        trace_path=args.trace)


if __name__ == "__main__":
    main()
