"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minimalist-lm-360m \
        --steps 300 --batch 8 --seq 256

Runs on whatever devices exist (CPU here, TPU pods in production — the
same code path; only the mesh constructor differs).  Uses the synthetic
structured-token pipeline, AdamW + cosine, checkpoint/restart, straggler
monitoring, and optional int8 gradient compression.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLMDataset, ShardedLoader
from repro.models import build_model
from repro.optim import AdamW, cosine_schedule
from repro.train import Trainer, TrainConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minimalist-lm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config variant")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    model = build_model(cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq)
    loader = ShardedLoader(ds, global_batch=args.batch)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=args.steps // 20,
                                   total=args.steps))
    tcfg = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, microbatch=args.microbatch,
                       grad_compress=args.grad_compress, log_every=10)
    trainer = Trainer(model, opt, tcfg, loader=loader)
    params, step = trainer.run()
    losses = [h["loss"] for h in trainer.history]
    if losses:
        k = max(1, len(losses) // 10)
        print(f"done at step {step}; loss first-{k}-mean "
              f"{sum(losses[:k])/k:.4f} -> last-{k}-mean "
              f"{sum(losses[-k:])/k:.4f}")
    return trainer


if __name__ == "__main__":
    main()
