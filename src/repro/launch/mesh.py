"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before any jax import; tests and benchmarks see the 1 real CPU device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1):
    """Small (data, model) mesh for tests / benchmarks / local serving.

    Uses the first ``data*model`` local devices — a 2×2 mesh on an
    8-device host is fine (the rest idle).  Asking for more devices than
    exist raises a ValueError naming both counts, so a bad ``--mesh``
    flag fails at startup instead of deep inside jax.
    """
    if model < 1 or data < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data} "
                         f"model={model}")
    devices = jax.devices()
    need, n = model * data, len(devices)
    if need > n:
        raise ValueError(
            f"mesh data={data} x model={model} needs {need} devices but "
            f"only {n} are available (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N to fake "
            f"more on CPU)")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:need])


def mesh_info(mesh) -> dict:
    """Axis sizes plus the derived DP / TP degrees.  Meshes without a
    "pod" axis (every local mesh) get pod=1 folded into ``dp`` — callers
    should read ``dp``/``tp`` instead of poking at raw axis names."""
    axes = dict(mesh.shape)
    return {"axes": axes,
            "n_devices": int(np.prod(list(axes.values()))),
            "dp": int(axes.get("pod", 1)) * int(axes.get("data", 1)),
            "tp": int(axes.get("model", 1))}
