"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before any jax import; tests and benchmarks see the 1 real CPU device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    assert model * data <= n, (model, data, n)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_info(mesh) -> dict:
    return {"axes": dict(mesh.shape),
            "n_devices": int(np.prod(list(mesh.shape.values())))}
