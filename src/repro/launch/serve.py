"""Batched serving driver: prefill + greedy decode with per-layer caches.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 32 --gen 32

The decode inner loop is the jitted ``serve_step`` (same function the
multi-pod dry-run lowers at the decode_32k / long_500k shapes).  Prefill
is implemented by stepping the cache through the prompt (cache-writing
prefill); the O(1)-state mixers (minGRU — the paper's edge-inference case —
and Mamba) make this linear-time with constant memory.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def generate(model, params, prompts, *, max_len, gen_tokens):
    """prompts: (B, P) int32. Returns (B, gen_tokens) generated ids."""
    B, P = prompts.shape
    cache = model.init_cache(B, max_len)

    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = model.decode_step(params, tok, cache, pos)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    # prefill: feed prompt tokens, ignore logits
    tok = None
    for t in range(P):
        tok, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    out = []
    for t in range(gen_tokens):
        out.append(tok)
        tok, cache = step(params, cache, tok[:, None], jnp.int32(P + t))
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(model, params, prompts,
                   max_len=args.prompt_len + args.gen + 1,
                   gen_tokens=args.gen)
    out.block_until_ready()
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen)
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. prefill + compile)")
    print("sample:", np.asarray(out[0, :16]))
    return out


if __name__ == "__main__":
    main()
