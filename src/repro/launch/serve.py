"""Serving driver: continuous-batching streaming decode (repro.serve).

    PYTHONPATH=src python -m repro.launch.serve --arch minimalist-lm-360m \
        --smoke --requests 16 --slots 4 --prompt-len 32 --gen 32

The engine admits requests of mixed prompt/generation lengths into a
fixed-capacity slot batch: prompts are consumed by the grid-padded
chunked prefill (one ``linear_scan`` per chunk for the O(1)-state mixers
— the paper's edge-inference property — and exactly one compiled chunk
shape across ragged prompt lengths), decode is ONE jitted slot-batch step
per token, and finished sequences retire the step they complete so their
slots go straight back into circulation.  ``--temperature/--top-k/--top-p``
turn on per-request sampling (counter-based PRNG: reproducible per
request, same compiled step as greedy).  ``--mesh DxM`` serves under a
local device mesh (TP params/caches over "model", DP slots over "data";
README §Sharded serving).  ``--kv-layout paged`` stores attention K/V in
a shared page pool with per-request block tables (``--page-size``,
``--num-pages``; README §Paged KV cache) — memory scales with live
tokens and admission defers when the pool is full.  ``--drafter ARCH
--spec-k K`` turns on speculative decoding: a pure-recurrent draft
model proposes ``k-1`` greedy tokens per wave and the target verifies
all ``k`` in one paged call (README §Speculative decoding; greedy
streams stay bitwise identical to plain decode).  ``--baseline`` runs
the old static-batch loop instead (kept as the benchmark reference).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SamplingParams, ServeConfig, get_config
from repro.launch.mesh import make_local_mesh, mesh_info
from repro.models import build_model
from repro.serve import DecoderStepModel, ServeEngine, Telemetry


def generate(model, params, prompts, *, max_len, gen_tokens):
    """Static-batch baseline: per-token prefill + lock-step greedy decode.

    prompts: (B, P) int32. Returns (B, gen_tokens) generated ids.  Every
    sequence occupies its batch row for the full P + gen_tokens steps —
    the reference the continuous-batching engine is benchmarked against.
    """
    B, P = prompts.shape
    cache = model.init_cache(B, max_len)

    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = model.decode_step(params, tok, cache, pos)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    # prefill: feed prompt tokens, ignore logits
    tok = None
    for t in range(P):
        tok, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    out = []
    for t in range(gen_tokens):
        out.append(tok)
        tok, cache = step(params, cache, tok[:, None], jnp.int32(P + t))
    return jnp.stack(out, axis=1)


def build_engine(model, params, serve: ServeConfig = ServeConfig(),
                 mesh=None, telemetry=None):
    kw = {}
    if serve.kv_layout == "paged":
        from repro.serve import PagedConfig
        kw = dict(kv_layout="paged",
                  paged=PagedConfig(page_size=serve.page_size,
                                    num_pages=serve.num_pages))
    sm = DecoderStepModel(model, max_len=serve.max_len,
                          prefill_chunk=serve.prefill_chunk, **kw)
    if serve.drafter:
        from repro.serve import DraftStepModel
        dcfg = get_config(serve.drafter)
        dmodel = build_model(dcfg)
        dparams = dmodel.init(jax.random.PRNGKey(1))
        kw = dict(drafter=DraftStepModel(
                      dmodel, spec_k=serve.spec_k,
                      prefill_chunk=serve.prefill_chunk),
                  drafter_params=dparams, spec_k=serve.spec_k)
    else:
        kw = {}
    return ServeEngine(sm, params, slots=serve.slots, mesh=mesh,
                       prefix_cache=serve.prefix_cache,
                       policy=serve.policy, telemetry=telemetry, **kw)


def parse_mesh(spec: str):
    """'DxM' -> a local (data=D, model=M) mesh; '' -> None (no mesh)."""
    if not spec:
        return None
    try:
        d, m = (int(v) for v in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh expects DxM (e.g. 2x2), got {spec!r}")
    return make_local_mesh(model=m, data=d)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minimalist-lm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="mean prompt length; actual lengths vary ±50%%")
    ap.add_argument("--gen", type=int, default=32,
                    help="mean generation budget; actual budgets vary ±50%%")
    ap.add_argument("--prefill-chunk", type=int, default=256)
    ap.add_argument("--max-len", type=int, default=0,
                    help="attention cache length (default: fits the longest "
                         "request)")
    ap.add_argument("--scan-backend", default=None,
                    choices=[None, "seq", "xla", "pallas", "pallas_tpu"],
                    help="linear-scan backend for recurrent prefill")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "pooled", "per_request", "auto"],
                    help="MoE dispatch mode (MoE stacks only): 'auto' "
                         "(default) serves batch-invariantly — gather-GEMM "
                         "decode + per-request prefill; 'pooled' reverts "
                         "to the capacity-limited training dispatch, whose "
                         "routing depends on co-batched traffic")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter (0 disables)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass (1.0 disables)")
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request PRNG seed base (request i uses "
                         "seed+i; decoding is reproducible per request)")
    ap.add_argument("--mesh", default="",
                    help="serve under a DxM local device mesh (e.g. 2x2 = "
                         "data 2 x model 2): params and caches TP-shard "
                         "over 'model' via the logical-axis rules, slots "
                         "DP-shard over 'data'; needs D*M local devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N fakes them on CPU)")
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="attention KV-cache layout: 'dense' preallocates "
                         "(slots, max_len) rows per slot; 'paged' shares "
                         "a page pool with per-request block tables so "
                         "memory scales with live tokens (README §Paged "
                         "KV cache)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=["bf16", "int8"],
                    help="paged KV-pool storage dtype: 'int8' stores "
                         "symmetric per-page codes + float32 scales per "
                         "page per KV head — half the pool bytes, so "
                         "~2x the concurrent requests fit a fixed pool "
                         "(README §Paged KV cache)")
    ap.add_argument("--paged-impl", default=None,
                    choices=["gather", "pallas", "pallas_tpu"],
                    help="paged decode read: 'pallas' (default) = the "
                         "block-table kernel, interpret off-TPU / "
                         "compiled on TPU; 'gather' = dense-view oracle "
                         "(bitwise-dense, slower); 'pallas_tpu' = "
                         "compiled only")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool capacity; 0 auto-sizes to the dense "
                         "equivalent (slots x pages-per-max-len-request) "
                         "— set lower to actually cap memory (admission "
                         "defers when the pool is full)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged layout only: pin finished prompts' pages "
                         "so requests sharing a page-aligned prompt "
                         "prefix attach to them and prefill only the "
                         "tail (README §Prefix caching)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "sjf", "edf"],
                    help="admission/preemption policy: 'fifo' = strict "
                         "arrival order with defer-at-head; 'priority' "
                         "= per-request priority classes (may preempt "
                         "lower-priority running requests under the "
                         "paged layout); 'sjf' = shortest-prefill-first "
                         "with aging; 'edf' = earliest-deadline-first "
                         "(submit(deadline=...); may preempt later-"
                         "deadline running requests under the paged "
                         "layout) (README §Scheduling & preemption)")
    ap.add_argument("--drafter", default="",
                    help="speculative decoding: arch name of a pure "
                         "O(1)-state draft model (e.g. minimalist-lm-"
                         "360m-smoke) proposing greedy k-token waves "
                         "the target verifies in one paged call; needs "
                         "--kv-layout paged and a matching vocab "
                         "(README §Speculative decoding)")
    ap.add_argument("--spec-k", type=int, default=1,
                    help="speculative verify width: tokens decided per "
                         "wave per slot (1 = off; needs --drafter)")
    ap.add_argument("--verbose", action="store_true",
                    help="print a per-step stats line (occupancy, "
                         "queue depth, pool pages, preemptions)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="record request-lifecycle + wave spans and save "
                         "them as Chrome trace_event JSON — open in "
                         "https://ui.perfetto.dev (README §Observability)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the engine metrics registry "
                         "(engine.metrics()) as JSON after the run")
    ap.add_argument("--fork", type=int, default=0,
                    help="fork the FIRST admitted request into N extra "
                         "copy-on-write streams after one decode step "
                         "(paged layout; demonstrates best-of-n page "
                         "sharing)")
    ap.add_argument("--baseline", action="store_true",
                    help="run the static-batch loop instead of the engine")
    args = ap.parse_args(argv)
    if min(args.requests, args.gen, args.prompt_len, args.slots) < 1:
        ap.error("--requests, --gen, --prompt-len and --slots must all "
                 "be >= 1")
    if args.mesh and args.baseline:
        ap.error("--mesh applies to the engine, not the static baseline")
    try:
        mesh = parse_mesh(args.mesh)
    except ValueError as e:
        ap.error(str(e))

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    if args.scan_backend:
        cfg = dataclasses.replace(cfg, scan_backend=args.scan_backend)
    if args.kv_dtype or args.paged_impl:
        if args.kv_layout != "paged":
            ap.error("--kv-dtype / --paged-impl need --kv-layout paged")
        if args.kv_dtype:
            cfg = dataclasses.replace(cfg, kv_dtype=args.kv_dtype)
        if args.paged_impl:
            cfg = dataclasses.replace(cfg, paged_impl=args.paged_impl)
    if args.moe_dispatch:
        if cfg.moe is None:
            ap.error(f"--moe-dispatch given but {cfg.name} has no MoE "
                     "layers")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=args.moe_dispatch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    lo = max(1, args.prompt_len // 2)
    plens = rng.integers(lo, args.prompt_len * 3 // 2 + 1, args.requests)
    glens = rng.integers(max(1, args.gen // 2),
                         args.gen * 3 // 2 + 1, args.requests)
    prompts = [rng.integers(0, cfg.vocab, size=p, dtype=np.int64)
               for p in plens]
    max_len = args.max_len or int(plens.max() + glens.max() + 1)

    if args.baseline:
        # static batch: pad every prompt to the longest, run the worst case
        P, G = int(plens.max()), int(glens.max())
        batch = np.stack([np.resize(p, P) for p in prompts])
        t0 = time.time()
        out = generate(model, params, jnp.asarray(batch, jnp.int32),
                       max_len=max_len, gen_tokens=G)
        out.block_until_ready()
        dt = time.time() - t0
        total = args.requests * (P + G)
        print(f"baseline: {out.shape} in {dt:.2f}s "
              f"({total/dt:.1f} tok/s incl. prefill + compile)")
        return out

    if args.prefix_cache and args.kv_layout != "paged":
        ap.error("--prefix-cache needs --kv-layout paged")
    if args.fork and args.kv_layout != "paged":
        ap.error("--fork needs --kv-layout paged")
    drafter_name = args.drafter and (
        args.drafter + ("-smoke" if args.smoke
                        and not args.drafter.endswith("-smoke") else ""))
    if drafter_name and args.kv_layout != "paged":
        ap.error("--drafter needs --kv-layout paged")
    if args.spec_k > 1 and not drafter_name:
        ap.error("--spec-k > 1 needs --drafter")
    telemetry = None
    if args.trace or args.metrics:
        telemetry = Telemetry(trace=bool(args.trace))
    eng = build_engine(model, params,
                       ServeConfig(slots=args.slots, max_len=max_len,
                                   prefill_chunk=args.prefill_chunk,
                                   kv_layout=args.kv_layout,
                                   page_size=args.page_size,
                                   num_pages=args.num_pages,
                                   prefix_cache=args.prefix_cache,
                                   policy=args.policy,
                                   spec_k=args.spec_k,
                                   drafter=drafter_name),
                       mesh=mesh, telemetry=telemetry)
    if eng.drafter is not None:
        print(f"speculative decoding: drafter {drafter_name}, "
              f"k={args.spec_k}")
    if eng.pool is not None:
        print(f"paged KV: {eng.pool.num_pages} pages x "
              f"{args.page_size} tokens, "
              f"<= {eng.pool.max_pages} pages/request"
              + (", prefix cache on" if eng.prefix_cache else ""))
    if mesh is not None:
        info = mesh_info(mesh)
        print(f"mesh: {info['axes']} (dp={info['dp']} tp={info['tp']}, "
              f"{info['n_devices']} devices)")
    t0 = time.time()
    first = None
    for i, (p, g) in enumerate(zip(prompts, glens)):
        sampling = None
        if args.temperature > 0:
            sampling = SamplingParams(temperature=args.temperature,
                                      top_k=args.top_k, top_p=args.top_p,
                                      seed=args.seed + i)
        r = eng.submit(p, max_new_tokens=int(g), sampling=sampling)
        first = first or r
    if args.fork:
        eng.step()                       # admit + one decode step
        room = int(args.slots - eng.active.sum())
        if first.finished or not room:
            print(f"fork skipped: request uid={first.uid} "
                  + ("already finished" if first.finished
                     else "no free slot (raise --slots above the "
                          "request count to demo forking)"))
        else:
            kids = eng.fork(first, min(args.fork, room))
            print(f"forked request uid={first.uid} into "
                  f"{len(kids)} COW streams")
    done = eng.run(verbose=args.verbose)
    dt = time.time() - t0
    total = int(plens.sum() + glens.sum())
    stats = eng.stats()
    print(f"engine: {len(done)} requests, {eng.n_emitted} tokens in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s incl. prefill + compile), "
          f"slot utilization {stats.utilization:.2f}, "
          f"policy {stats.policy}, {stats.n_preemptions} preemption(s)")
    if eng.drafter is not None:
        print(f"spec decode: accept rate {stats.accept_rate:.2f}, "
              f"{eng.n_emitted / max(eng.n_steps, 1):.2f} "
              f"accepted tokens/step")
    if eng.prefix_cache is not None:
        pc = eng.prefix_cache
        print(f"prefix cache: {eng.n_prefix_hits} hits / "
              f"{pc.misses} misses, {eng.n_prefix_tokens} prompt tokens "
              f"skipped, {len(pc)} entries pinning "
              f"{pc.pinned_pages} pages, {pc.n_evicted} evicted")
    if eng.n_forks or eng.n_cow_copies:
        print(f"forks: {eng.n_forks}, COW page copies: "
              f"{eng.n_cow_copies}")
    if args.trace:
        eng.telemetry.save_trace(args.trace)
        print(f"trace: {len(eng.telemetry.trace)} events -> {args.trace} "
              "(open in https://ui.perfetto.dev)")
    if args.metrics:
        print("metrics:", json.dumps(eng.metrics(), indent=2,
                                     sort_keys=True))
    print("sample:", done[0].tokens[:16])
    return done


if __name__ == "__main__":
    main()
