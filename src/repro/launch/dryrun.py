import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (into benchmarks/results/dryrun/*.json):
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes
  * collective bytes parsed from the SPMD-partitioned HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    sizes — per-device, post-partitioning)
  * the three roofline terms for TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI) — see EXPERIMENTS.md §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-moe-30b-a3b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCHS, ASSIGNED, SHAPES, get_config, input_specs,
                           shape_supported)
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.models import build_model
from repro.optim import AdamW
from repro.parallel import sharding as shd

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_SHAPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|s8|u8|s16|u16|"
                       r"s32|u32|s64|u64|pred)\[([0-9,]*)\]")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(m) -> int:
    dtype, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the partitioned HLO."""
    out = {k: {"count": 0, "operand_bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match op lines like: %x = f32[..] all-reduce(f32[..] %y), ...
        for kind in _COLLECTIVES:
            if f" {kind}(" in s or f"{kind}-start(" in s:
                # operand shapes: everything inside the call parens
                call = s.split(f"{kind}(", 1)[-1] if f" {kind}(" in s \
                    else s.split(f"{kind}-start(", 1)[-1]
                call = call.split(")", 1)[0]
                b = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(call))
                if b == 0:  # fall back to the op's own output shape
                    m = _SHAPE_RE.search(s)
                    b = _shape_bytes(m) if m else 0
                out[kind]["count"] += 1
                out[kind]["operand_bytes"] += b
                break
    # bytes-on-wire model (ring algorithms): all-reduce moves ~2× operand
    total_wire = sum(
        v["operand_bytes"] * (2 if k == "all-reduce" else 1)
        for k, v in out.items())
    out["total_operand_bytes"] = sum(v["operand_bytes"]
                                     for v in out.values()
                                     if isinstance(v, dict))
    out["total_wire_bytes"] = total_wire
    return out


# ---------------------------------------------------------------------------


def build_train_step(model, opt):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, opt_m = opt.update(grads, opt_state, params)
        metrics = dict(metrics, **opt_m)
        return params, opt_state, metrics

    return train_step


def build_serve_step(model):
    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, tokens, cache, pos)
        # greedy sampling (argmax) — serving inner loop
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def build_prefill_step(model, cfg):
    def prefill_step(params, batch):
        return model(params, **batch)

    return prefill_step


def _build(cfg, remat, scan_layers=True):
    if cfg.arch_type == "audio":
        return build_model(cfg, scan_layers=scan_layers)
    return build_model(cfg, remat=remat, scan_layers=scan_layers)


def optimized_cfg(cfg, mesh):
    """Hillclimbed variant: Pallas flash attention + fused selective scan
    (lowered as cost stubs — Pallas is TPU-only; launch.dryrun adds the
    kernels' analytic cost, see kernel_costs) + group-local MoE dispatch
    with explicit sharding constraints (groups = DP degree)."""
    import dataclasses
    kw = {"moe_constraints": cfg.moe is not None}
    if cfg.n_heads:
        kw["attention_impl"] = "stub"
    if cfg.mamba is not None:
        kw["ssm_impl"] = "stub"
    if cfg.moe is not None:
        # (dispatch is already pinned to "pooled" by lower_cell)
        kw["moe"] = dataclasses.replace(cfg.moe,
                                        groups=mesh_info(mesh)["dp"])
    return dataclasses.replace(cfg, **kw)


def kernel_costs(cfg, shape, mesh):
    """Analytic per-device (flops, hbm_bytes) of the Pallas kernel regions
    replaced by stubs in the optimized lowering.

    Sharding mirror of parallel.sharding rules: batch divides by the DP
    degree; heads divide by the model degree only when shardable
    (replicated attention repeats the compute on every model shard — the
    honest accounting for heads % model != 0 archs)."""
    from repro.kernels.flash_attention.ops import cost_model as fa_cost
    from repro.kernels.fused_ssm.ops import cost_model as ssm_cost

    sh = SHAPES[shape]
    info = mesh_info(mesh)
    dp, tp = info["dp"], info["tp"]
    B = max(sh["global_batch"] // dp, 1)
    S = sh["seq_len"]
    train = sh["kind"] == "train"
    if sh["kind"] == "decode":   # decode paths don't use the stubs
        return 0.0, 0.0

    flops = bytes_ = 0.0
    for spec in cfg.layer_specs():
        if spec.kind in ("attn", "attn_local") and cfg.n_heads:
            H = cfg.n_heads // tp if cfg.n_heads % tp == 0 else cfg.n_heads
            KV = (cfg.n_kv_heads // tp if cfg.n_kv_heads % tp == 0
                  else cfg.n_kv_heads)
            window = cfg.sliding_window if spec.kind == "attn_local" else None
            f, b = fa_cost(B, H, KV, S, cfg.head_dim, causal=True,
                           window=window, train=train)
            flops += f
            bytes_ += b
        elif spec.kind == "mla" and cfg.mla:
            m = cfg.mla
            H = cfg.n_heads // tp if cfg.n_heads % tp == 0 else cfg.n_heads
            hd = (m.qk_nope_head_dim + m.qk_rope_head_dim
                  + m.v_head_dim) // 2   # qk + pv matmul average width
            f, b = fa_cost(B, H, H, S, hd, causal=True, train=train)
            flops += f
            bytes_ += b
        elif spec.kind == "mamba" and cfg.mamba:
            di = cfg.mamba.d_inner(cfg.d_model)
            di = di // tp if di % tp == 0 else di
            f, b = ssm_cost(B, S, di, cfg.mamba.d_state, train=train)
            flops += f
            bytes_ += b
    return flops, bytes_


def _lower(model, cfg, shape, mesh, *, zero1, donate, rules):
    """Lower one step function for (model, shape) on mesh (under the mesh
    context so PartitionSpec-based sharding constraints resolve)."""
    with mesh:
        return _lower_inner(model, cfg, shape, mesh, zero1=zero1,
                            donate=donate, rules=rules)


def _lower_inner(model, cfg, shape, mesh, *, zero1, donate, rules):
    sh = SHAPES[shape]
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(model.init, key)
    p_spec = shd.param_specs(model, p_shapes, mesh, rules)
    p_shard = shd.named_sharding_tree(p_spec, mesh)
    p_args = shd.attach(p_shapes, p_shard)
    ispec = input_specs(cfg, shape)

    if sh["kind"] == "train":
        opt = AdamW(lr=3e-4)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_spec = shd.opt_state_specs(p_spec, p_shapes, mesh, zero1=zero1)
        o_shard = shd.named_sharding_tree(o_spec, mesh)
        o_args = shd.attach(o_shapes, o_shard)
        b_spec = shd.batch_specs(ispec, mesh)
        b_args = shd.attach(ispec, shd.named_sharding_tree(b_spec, mesh))
        step = build_train_step(model, opt)
        jitted = jax.jit(step, donate_argnums=(0, 1) if donate else (),
                         out_shardings=(p_shard, o_shard, None))
        return jitted.lower(p_args, o_args, b_args)
    if sh["kind"] == "prefill":
        b_spec = shd.batch_specs(ispec, mesh)
        b_args = shd.attach(ispec, shd.named_sharding_tree(b_spec, mesh))
        jitted = jax.jit(build_prefill_step(model, cfg))
        return jitted.lower(p_args, b_args)
    # decode
    B = sh["global_batch"]
    c_shapes = model.cache_spec(B, sh["seq_len"])
    c_spec = shd.cache_specs(model.cache_axes(), c_shapes, mesh)
    c_shard = shd.named_sharding_tree(c_spec, mesh)
    c_args = shd.attach(c_shapes, c_shard)
    # decode inputs through the SAME per-slot spec builder the serving
    # engine uses (repro.serve.protocol) — dim0 is the slot/batch axis
    io = {"tok": jax.ShapeDtypeStruct((B, 1), jnp.int32),
          "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    io_args = shd.attach(io, shd.named_sharding_tree(
        shd.slot_specs(io, mesh), mesh))
    jitted = jax.jit(build_serve_step(model),
                     donate_argnums=(1,) if donate else (),
                     out_shardings=(None, c_shard))
    return jitted.lower(p_args, c_args, io_args["tok"], io_args["pos"])


def _analyze(compiled):
    """cost_analysis + collective bytes of one compiled executable."""
    try:
        cost = compiled.cost_analysis()
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and
                  k in ("flops", "bytes accessed", "transcendentals")}
    except Exception as e:  # pragma: no cover
        cost_d = {"error": str(e)}
    coll = parse_collective_bytes(compiled.as_text())
    return cost_d, coll


def depth_variant(cfg, n_units: int):
    """Config with head/tail preserved and n_units pattern repeats."""
    import dataclasses
    kw = dict(n_layers=(len(cfg.head_layers) + len(cfg.tail_layers)
                        + n_units * len(cfg.pattern)))
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = n_units
    return dataclasses.replace(cfg, **kw)


def unit_extrapolated_costs(cfg, shape, mesh, *, remat, zero1, rules):
    """XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count (verified), so scanned-layer costs must be reconstructed.  We
    compile unrolled 1-unit and 2-unit variants: each metric is linear in
    unit count (U_k = base + k·body), so body = U2 − U1 and the full-depth
    total is U1 + (K−1)·body.  Head/tail layers live in `base`."""
    res = []
    for k in (1, 2):
        cfgk = depth_variant(cfg, k)
        modelk = _build(cfgk, remat, scan_layers=False)
        lowered = _lower(modelk, cfgk, shape, mesh, zero1=zero1,
                         donate=False, rules=rules)
        res.append(_analyze(lowered.compile()))
    (c1, k1), (c2, k2) = res
    K = cfg.n_repeats

    def extr(a, b):
        return a + (K - 1) * max(b - a, 0.0)

    cost = {m: extr(c1.get(m, 0.0), c2.get(m, 0.0))
            for m in ("flops", "bytes accessed", "transcendentals")}
    coll = {}
    for kind in _COLLECTIVES:
        coll[kind] = {
            "count": int(extr(k1[kind]["count"], k2[kind]["count"])),
            "operand_bytes": extr(k1[kind]["operand_bytes"],
                                  k2[kind]["operand_bytes"]),
        }
    coll["total_operand_bytes"] = sum(v["operand_bytes"]
                                      for v in coll.values())
    coll["total_wire_bytes"] = sum(
        v["operand_bytes"] * (2 if kind == "all-reduce" else 1)
        for kind, v in coll.items() if isinstance(v, dict))
    return cost, coll, {"unit1": {"cost": c1, "coll_wire": k1["total_wire_bytes"]},
                        "unit2": {"cost": c2, "coll_wire": k2["total_wire_bytes"]}}


def lower_cell(arch: str, shape: str, mesh, *, remat="full", zero1=False,
               rules_overrides=None, donate=True, skip_full=False,
               impl="baseline"):
    """Lower + compile one (arch, shape) on a mesh. Returns result dict."""
    cfg = get_config(arch)
    if cfg.moe is not None:
        # cost cells model the pooled EP capacity dispatch on every route
        # (decode included): the serving-side gather-GEMM / per-request
        # paths exist for batch-invariance, not as a production EP
        # lowering, and would distort the HBM/FLOPs proof
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch="pooled"))
    if impl == "optimized":
        cfg = optimized_cfg(cfg, mesh)
    sh = SHAPES[shape]
    rules = shd.make_rules(rules_overrides)

    # 1) full-depth scanned compile — the pass/fail + memory proof
    t_lower = t_compile = 0.0
    mem_d = {}
    if not skip_full:
        model = _build(cfg, remat)
        t0 = time.time()
        lowered = _lower(model, cfg, shape, mesh, zero1=zero1, donate=donate,
                         rules=rules)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        try:
            mem = compiled.memory_analysis()
            mem_d = {k: int(getattr(mem, k)) for k in
                     ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")
                     if hasattr(mem, k)}
        except Exception as e:  # pragma: no cover
            mem_d = {"error": str(e)}
        del compiled, lowered

    # 2) per-unit cost extrapolation (see unit_extrapolated_costs)
    cost_d, coll, unit_raw = unit_extrapolated_costs(
        cfg, shape, mesh, remat=remat, zero1=zero1, rules=rules)

    # 3) analytic cost of Pallas kernel regions (stub-lowered)
    kadj = {"flops": 0.0, "bytes": 0.0}
    if impl == "optimized":
        kf, kb = kernel_costs(cfg, shape, mesh)
        kadj = {"flops": kf, "bytes": kb}
        cost_d["flops"] = cost_d.get("flops", 0.0) + kf
        cost_d["bytes accessed"] = cost_d.get("bytes accessed", 0.0) + kb

    n_dev = mesh_info(mesh)["n_devices"]
    flops_dev = cost_d.get("flops", 0.0)
    bytes_dev = cost_d.get("bytes accessed", 0.0)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll["total_wire_bytes"] / ICI_BW,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]

    cfg_params = cfg.param_count()
    cfg_active = cfg.active_param_count()
    tokens = sh["global_batch"] * (sh["seq_len"] if sh["kind"] != "decode"
                                   else 1)
    model_flops = 6 * cfg_active * tokens if sh["kind"] == "train" \
        else 2 * cfg_active * tokens
    ideal_s = model_flops / n_dev / PEAK_FLOPS
    if sh["kind"] == "decode":
        # decode is weight-streaming-bound: the floor is reading the active
        # params once per step (bf16), sharded across all chips
        ideal_s = max(ideal_s, cfg_active * 2 / n_dev / HBM_BW)
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_info(mesh),
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": cost_d,
        "collectives": {k: v for k, v in coll.items()},
        "unit_raw": unit_raw,
        "roofline": terms,
        "params": cfg_params, "active_params": cfg_active,
        "model_flops_global": model_flops,
        "model_flops_per_dev": model_flops / n_dev,
        "useful_flops_ratio": (model_flops / n_dev) / flops_dev
        if flops_dev else None,
        "roofline_fraction": ideal_s / terms["bound_s"]
        if terms["bound_s"] else None,
        "remat": remat, "zero1": zero1, "impl": impl,
        "kernel_adjustment": kadj,
    }
    return result


def run_cell(arch, shape, mesh_kind, **kw):
    tag = f"{arch}__{shape}__{mesh_kind}"
    if kw.get("impl", "baseline") != "baseline":
        tag += "__" + kw["impl"]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        res = lower_cell(arch, shape, mesh, **kw)
    except Exception as e:
        res = {"arch": arch, "shape": shape, "mesh": mesh_info(mesh),
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, tag + ".json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1, default=str)
    return res


def cells(archs=None, shapes=None):
    for arch in (archs or ASSIGNED):
        cfg = get_config(arch)
        for shape in (shapes or SHAPES):
            if shape_supported(cfg, shape):
                yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--impl", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = list(cells([args.arch] if args.arch else None,
                      [args.shape] if args.shape else None)) \
        if (args.all or not (args.arch and args.shape)) \
        else [(args.arch, args.shape)]

    for arch, shape in todo:
        for mk in meshes:
            tag = f"{arch}__{shape}__{mk}"
            if args.impl != "baseline":
                tag += "__" + args.impl
            path = os.path.join(RESULTS_DIR, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                try:
                    if json.load(open(path)).get("status") == "ok":
                        print(f"SKIP {tag}")
                        continue
                except Exception:
                    pass
            t0 = time.time()
            res = run_cell(arch, shape, mk, remat=args.remat,
                           zero1=args.zero1, impl=args.impl)
            ok = res["status"]
            dom = res.get("roofline", {}).get("dominant", "-")
            print(f"{ok:5s} {tag:60s} {time.time()-t0:7.1f}s dominant={dom}",
                  flush=True)
            if ok == "ok":
                mem = res.get("memory_analysis", {})
                cost = res.get("cost_analysis", {})
                print(f"      memory_analysis: "
                      f"args={mem.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
                      f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.2f}GB | "
                      f"cost_analysis: flops={cost.get('flops', 0):.3e} "
                      f"bytes={cost.get('bytes accessed', 0):.3e} | "
                      f"coll_wire={res['collectives']['total_wire_bytes']:.3e}B",
                      flush=True)


if __name__ == "__main__":
    main()
