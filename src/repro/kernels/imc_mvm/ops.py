"""Public wrapper for the IMC MVM kernel.

Inference-only op (the hardware path): weights are frozen 2 b codes, so no
VJP is defined for `codes`; gradients w.r.t. the binary activations are
given a straight-through surrogate so the op can sit inside QAT graphs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.imc_mvm import ref
from repro.kernels.imc_mvm.imc_mvm import imc_mvm_pallas

_DEFAULT_BACKEND = "xla"


def _round_up(x, m):
    return (x + m - 1) // m * m


def imc_mvm(x, codes, scale, *, backend=_DEFAULT_BACKEND,
            bm=128, bn=128, bk=128):
    """Charge-sharing MVM: (x @ deq(codes)) / K.

    x: (..., K) in {0,1}; codes: (K, N) int; scale: scalar or (N,).
    """
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (codes.shape[1],))
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    if backend == "xla":
        out = ref.imc_mvm_ref(x2, codes, scale)
    elif backend in ("pallas", "pallas_tpu"):
        M = x2.shape[0]
        N = codes.shape[1]
        bm_, bn_, bk_ = (min(bm, _round_up(M, 8)), min(bn, _round_up(N, 128)),
                         min(bk, _round_up(K, 128)))
        Mp, Np, Kp = _round_up(M, bm_), _round_up(N, bn_), _round_up(K, bk_)
        xp = jnp.pad(x2.astype(jnp.float32), [(0, Mp - M), (0, Kp - K)])
        # pad codes with 1.5-offset-neutral values? code padding contributes
        # (c-1.5)≠0 even for x=0 rows — but padded x rows are 0 so K-padding
        # of codes only meets x-padding columns == 0; safe. N-padding sliced.
        cp = jnp.pad(codes.astype(jnp.int8), [(0, Kp - K), (0, Np - N)])
        sp = jnp.pad(scale, [(0, Np - N)])
        out = imc_mvm_pallas(xp, cp, sp, bm=bm_, bn=bn_, bk=bk_,
                             interpret=(backend == "pallas"))
        # kernel divides by padded K; rescale to true K
        out = out[:M, :N] * (Kp / K)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out.reshape(*lead, codes.shape[1])
