"""Pallas TPU kernel: binary-activation × 2 b-weight IMC matmul (Eq. 6).

TPU adaptation of the switched-capacitor charge-sharing MVM (DESIGN.md §3):
the MXU plays the role of the capacitor array.  Key properties exploited:

  * Weights live in HBM as **int8 codes** (2 b of information; int8 is the
    narrowest dense dtype with native TPU load paths).  Dequantization
    ``w = (code − 1.5)·Δ`` is two VPU ops performed on the VMEM tile right
    before the MXU op — a 4× reduction in weight HBM traffic vs fp32, which
    is what makes the kernel memory-roofline-optimal for the skinny
    activation shapes RNN inference produces.
  * Activations are binary but stored as bf16 0/1 (TPU has no 1 b datapath);
    the matmul then *is* the select-and-accumulate of the circuit.
  * The 1/K charge-sharing normalization folds into the output epilogue.
  * Blocking: (bm × bk) ⊗ (bk × bn) MXU tiles, K-axis innermost and
    sequential, fp32 accumulator in VMEM scratch (one per (m, n) tile).

Grid: (M/bm, N/bn, K/bk), dimension_semantics = (parallel, parallel,
arbitrary) so the accumulator carries across the contraction axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

from repro.kernels.imc_mvm.ref import LEVEL_OFFSET


def _imc_kernel(x_ref, codes_ref, scale_ref, out_ref, acc_ref, *, n_k: int,
                inv_k: float):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # (bm, bk) bf16 {0,1}
    w = (codes_ref[...].astype(jnp.float32) - LEVEL_OFFSET)  # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x.astype(jnp.float32), w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _():
        scale = scale_ref[...].astype(jnp.float32)   # (1, bn) per-column Δ
        out_ref[...] = (acc_ref[...] * scale * inv_k).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"))
def imc_mvm_pallas(x, codes, scale, *, bm: int = 128, bn: int = 128,
                   bk: int = 128, interpret: bool = True,
                   out_dtype=jnp.float32):
    """x: (M, K) {0,1}; codes: (K, N) int8; scale: (N,) -> (M, N).

    M % bm == K % bk == N % bn == 0 (ops.py pads).
    """
    M, K = x.shape
    K2, N = codes.shape
    assert K == K2 and scale.shape == (N,)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)

    kern = functools.partial(_imc_kernel, n_k=n_k, inv_k=1.0 / K)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="imc_mvm",
    )(x, codes, scale.reshape(1, N))
