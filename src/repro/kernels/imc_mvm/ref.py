"""Pure-jnp oracle for the switched-capacitor IMC projection (paper Eq. 6).

The circuit: binary activations x_i ∈ {0,1} connect the shared row lines to
the four weight potentials; each synapse samples the line selected by its
2 b code; column-wise charge sharing settles at the *mean* of the sampled
voltages.  In weight units (relative to the zero level V_0):

    y_j = (1/K) · Σ_i  x_i · Δ · level(code_ij) ,
    level(c) = c − 1.5  ∈  {−1.5, −0.5, +0.5, +1.5}

i.e. a matmul of a binary activation vector with a 2 b-dequantized weight
matrix, scaled by 1/K (charge sharing normalizes by the number of
capacitors, not by the number of active inputs).
"""
from __future__ import annotations

import jax.numpy as jnp

LEVEL_OFFSET = 1.5  # level(c) = c - 1.5 for c in {0,1,2,3}


def dequantize_codes(codes, scale):
    """codes: int (..., K, N) in [0,4); scale Δ: scalar or (N,)."""
    return (codes.astype(jnp.float32) - LEVEL_OFFSET) * scale


def imc_mvm_ref(x, codes, scale):
    """x: (M, K) binary {0,1}; codes: (K, N) 2 b; -> (M, N) fp32.

    Returns the charge-sharing column mean: (x @ W_deq) / K.
    """
    K = x.shape[-1]
    w = dequantize_codes(codes, scale)
    return (x.astype(jnp.float32) @ w) / K
