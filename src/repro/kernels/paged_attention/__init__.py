"""Paged-attention decode kernels (block-table K/V page indirection)."""
from repro.kernels.paged_attention.ops import (paged_gqa_attention,
                                               paged_mla_attention)

__all__ = ["paged_gqa_attention", "paged_mla_attention"]
