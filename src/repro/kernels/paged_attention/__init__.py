"""Paged-attention decode kernels (block-table K/V page indirection)."""
from repro.kernels.paged_attention import quant
from repro.kernels.paged_attention.ops import (cost_model, cost_model_mla,
                                               paged_gqa_attention,
                                               paged_mla_attention)

__all__ = ["paged_gqa_attention", "paged_mla_attention", "cost_model",
           "cost_model_mla", "quant"]
