"""Pure-jnp oracle for paged (block-table) single-token decode attention.

The KV cache lives in a shared page pool ``(num_pages, page_size, ...)``;
each request owns a chain of page ids (one block-table row).  The dense
cache entry at in-cache index ``j`` of request ``b`` is

    pool[block_tables[b, j // page_size], j % page_size]

Three index-space families, matching the dense decode paths in
``repro.models.attention`` exactly:

  * global GQA       — in-cache index j IS the absolute position
  * sliding-window   — j is a RING index over ``length`` entries; the
                       position it holds is the largest p <= pos with
                       p % length == j (wrap-free: the bounded page chain
                       is recycled in place as the window slides)
  * MLA latent pages — like global, over compressed (ckv, k_rope) latents

Entries whose reconstructed position is masked (unwritten ring slots,
positions beyond ``pos``, outside the window) contribute EXACTLY zero
attention weight regardless of page content, so stale pages from freed
requests and unallocated block-table entries can never leak into an
output — the property the serving engine's page recycling relies on.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import quant

NEG_INF = -1e30


def gather_pages(pool, block_tables, length):
    """Dense view of the first ``length`` in-cache entries per request.

    pool: (P, page, ...); block_tables: (B, n_chain) int32 ->
    (B, length, ...).  Out-of-range page ids clamp (jnp gather), which is
    safe: any entry they produce is masked by position."""
    ps = pool.shape[1]
    idx = jnp.arange(length)
    pages = block_tables[:, idx // ps]            # (B, length)
    return pool[pages, idx[None, :] % ps]


def gather_dequant(pool, scale, block_tables, length, dtype=jnp.float32):
    """Dense dequantized view of an int8 pool's first ``length`` entries.

    Gathers codes AND their per-page scales through the block table —
    per-request traffic only, never the whole pool.  pool: (P, page,
    *feat, d) int8; scale: (P, *feat) f32 -> (B, length, *feat, d)."""
    ps = pool.shape[1]
    idx = jnp.arange(length)
    pages = block_tables[:, idx // ps]            # (B, length)
    vals = pool[pages, idx[None, :] % ps].astype(jnp.float32)
    sc = scale[pages]                             # (B, length, *feat)
    return (vals * sc[..., None]).astype(dtype)


def paged_positions(pos, length, window=None):
    """Reconstructed absolute position + validity per in-cache index.

    pos: (B,) current decode position.  Returns (k_pos, valid), both
    (B, length): ``valid`` marks entries a query at ``pos`` may attend."""
    idx = jnp.arange(length)
    if window is None:
        k_pos = jnp.broadcast_to(idx[None, :], (pos.shape[0], length))
    else:
        # ring entry j holds the latest position <= pos congruent to
        # j (mod length) — same formula as the dense ring decode
        k_pos = pos[:, None] - ((pos[:, None] - idx[None, :]) % length)
    valid = (k_pos >= 0) & (k_pos <= pos[:, None])
    if window is not None:
        valid &= (pos[:, None] - k_pos) < window
    return k_pos, valid


def paged_gqa_ref(q, pool_k, pool_v, block_tables, pos, *, length,
                  window=None, k_scale=None, v_scale=None):
    """q: (B, H, hd); pool_k/v: (P, page, KV, hd); pos: (B,) -> (B, H, hd).

    fp32 score/softmax math (the kernel's numerics), grouped queries
    share KV heads without expanding them in memory.  With int8 pools
    pass ``k_scale``/``v_scale`` (P, KV): the oracle dequantizes the
    whole pool up front — definitional, not efficient."""
    B, H, hd = q.shape
    KV = pool_k.shape[2]
    G = H // KV
    if k_scale is not None:
        pool_k = quant.dequantize(pool_k, k_scale)
        pool_v = quant.dequantize(pool_v, v_scale)
    kd = gather_pages(pool_k, block_tables, length)   # (B, L, KV, hd)
    vd = gather_pages(pool_v, block_tables, length)
    _k_pos, valid = paged_positions(pos, length, window)
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,blkd->bkgl", qg, kd.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", w, vd.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_mla_ref(q_abs, q_rope, pool_ckv, pool_krope, block_tables, pos, *,
                  length, scale, ckv_scale=None, krope_scale=None):
    """Weight-absorbed MLA decode over latent pages.

    q_abs: (B, H, r) absorbed queries; q_rope: (B, H, dr); pool_ckv:
    (P, page, r); pool_krope: (P, page, dr) -> latent output (B, H, r)
    (the caller up-projects through W^{UV}).  With int8 latent pools
    pass ``ckv_scale``/``krope_scale`` (P,)."""
    if ckv_scale is not None:
        pool_ckv = quant.dequantize(pool_ckv, ckv_scale)
        pool_krope = quant.dequantize(pool_krope, krope_scale)
    ccd = gather_pages(pool_ckv, block_tables, length)     # (B, L, r)
    crd = gather_pages(pool_krope, block_tables, length)   # (B, L, dr)
    _k_pos, valid = paged_positions(pos, length, None)
    scores = (jnp.einsum("bhr,blr->bhl", q_abs.astype(jnp.float32),
                         ccd.astype(jnp.float32))
              + jnp.einsum("bhk,blk->bhl", q_rope.astype(jnp.float32),
                           crd.astype(jnp.float32))) * scale
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhl,blr->bhr", w, ccd.astype(jnp.float32))
    return out.astype(q_abs.dtype)
