"""Symmetric int8 per-page quantization for the paged KV cache.

One scale per (page, feature-row): a pool leaf shaped
``(num_pages, page_size, *feat, d)`` quantizes with a float32 scale
tensor shaped ``(num_pages, *feat)`` — the page axis and the trailing
vector dim share a scale, everything in between (e.g. the KV-head axis)
gets its own.  For GQA pools ``(P, ps, KV, hd)`` that is a scale per
page per KV head; for MLA latent pools ``(P, ps, r)`` a scale per page.

Code grid: SYMMETRIC round-to-nearest onto ``[-QMAX, QMAX]`` with
``QMAX = 127`` — the two's-complement code -128 is never emitted.  This
is deliberately the *symmetric* convention of the paper's weight/bias
DACs (``core.quant.quantize_bias_6b`` clips to the 63-code grid
[-31, 31]; see the grid notes there), NOT the full two's-complement
grid of the ADC preset (``quantize_gate_bias_adc``, [-32, 31]): an
asymmetric grid would make ``dequant(quant(-x)) != -dequant(quant(x))``
and bias every attention score sum.  With ``scale = absmax / QMAX``
round-trip error is bounded by half an LSB: ``|x - deq(q(x))| <=
0.5 * scale`` elementwise (exactly the property the hypothesis suite
pins).

``MIN_SCALE`` floors the scale so all-zero pages stay invertible
(codes 0, scale MIN_SCALE) and the engine's monotone scale update never
divides by zero when rescaling a page's existing codes.
"""
from __future__ import annotations

import jax.numpy as jnp

QMAX = 127
MIN_SCALE = 1e-8


def _expand(scale, ndim, page_axis):
    """Re-insert the two reduced axes so ``scale`` broadcasts against the
    codes: (P, *feat) -> (P, 1, *feat, 1) for ndim-dim page rows."""
    return jnp.expand_dims(scale, (page_axis, ndim - 1))


def page_abs_scale(x, *, page_axis=1):
    """absmax/QMAX scale over (page_axis, last axis), floored at
    MIN_SCALE.  x: (..., page, *feat, d) -> float32 (..., *feat)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)),
                axis=(page_axis, x.ndim - 1))
    return jnp.maximum(s / QMAX, MIN_SCALE)


def quantize(x, scale, *, page_axis=1):
    """Round-to-nearest symmetric int8 codes for page rows ``x`` under
    per-row ``scale`` (shape = x.shape minus page_axis and last axis)."""
    s = _expand(scale, x.ndim, page_axis)
    codes = jnp.round(x.astype(jnp.float32) / s)
    return jnp.clip(codes, -QMAX, QMAX).astype(jnp.int8)


def dequantize(codes, scale, *, page_axis=1, dtype=jnp.float32):
    """codes * scale, broadcast per page row."""
    s = _expand(scale, codes.ndim, page_axis)
    return (codes.astype(jnp.float32) * s).astype(dtype)


def rescale_codes(codes, old_scale, new_scale, *, page_axis=1):
    """Re-express existing codes under a grown scale: round(codes *
    old/new).  The engine's scale update is monotone (new >= old), so the
    ratio is <= 1 and re-clipping is a no-op; in the steady state
    old == new bitwise, the ratio is exactly 1.0, and round(c * 1.0) == c
    — repeated decode writes never perturb stored pages.  A fresh page
    passes old_scale = 0 so the stale tenant's codes zero out."""
    ratio = _expand((old_scale / new_scale).astype(jnp.float32),
                    codes.ndim, page_axis)
    codes = jnp.round(codes.astype(jnp.float32) * ratio)
    return jnp.clip(codes, -QMAX, QMAX).astype(jnp.int8)
