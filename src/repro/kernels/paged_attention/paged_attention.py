"""Pallas TPU paged-attention decode kernels (block-table page gather).

Why this kernel exists: the serving engine's paged KV cache stores K/V in
a shared page pool ``(num_pages, page_size, ...)`` with per-request page
chains.  The XLA reference path materializes a dense ``(B, L, ...)`` view
of every request's chain each step — O(B·L·d) transient HBM traffic and
memory that defeats the point of paging.  This kernel reads K/V pages
directly through the block table instead: the page id is SCALAR-PREFETCHED
(``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index map DMAs exactly
the pages a request owns, one page per innermost grid step, with the
online-softmax state (m, l, acc) resident in VMEM.  Nothing dense is ever
materialized; HBM traffic is the live pages + q/out.

Grid (GQA): (B, KV, n_pages) with the page axis innermost and sequential;
each step loads pool block ``block_tables[b, p]`` for kv head ``kv``.
Masking reconstructs the absolute position of every in-page entry:

  * global:       k_pos = j            (in-cache index == position)
  * window ring:  k_pos = pos - ((pos - j) % length)   [length <= window]

Pages with no attendable entry (``p*page_size > pos``) are skipped via
``pl.when`` — that gate is also what keeps the online softmax sound (a
fully-masked tile would poison the running max).  MLA runs the same
schedule over latent pages with a rank-space score sum
(q_abs·ckvᵀ + q_rope·kropeᵀ) and a latent-space output (w·ckv).

The ``_q8`` variants read int8 pools with per-page float32 scales
(GQA: one per page per KV head; MLA: one per page — see
``paged_attention.quant``).  The scale rides in as a (1, 1) block
through the same block-table index map as the page it describes and the
dequant (codes * scale) happens in-register right before the q·Kᵀ and
P·V dots — HBM streams half the KV bytes and nothing dequantized is
ever written back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _page_mask(pos, p, ps, length, window):
    """(1, ps) additive mask for page ``p``'s entries vs query at ``pos``."""
    j = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    if window is None:
        k_pos = j
    else:
        k_pos = pos - ((pos - j) % length)
    ok = (j < length) & (k_pos >= 0) & (k_pos <= pos)
    if window is not None:
        ok &= (pos - k_pos) < window
    return jnp.where(ok, 0.0, NEG_INF)


def _online_update(s, v, acc, m_s, l_s):
    """One online-softmax accumulation step.  s: (R, ps) fp32 scores,
    v: (ps, D) fp32 values; scratch acc (R, D), m_s/l_s (R, 1)."""
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * alpha + p.sum(-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new


def _gqa_kernel(pos_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                acc, m_s, l_s, *, ps, n_pages, length, window, scale):
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    pos = pos_ref[b]
    # skip pages with no attendable entry: the first page always has one
    # (ring: position pos % length aliases into the live prefix; global:
    # every j <= pos), so the gate only drops unwritten chain tails
    @pl.when((p * ps <= pos) & (p * ps < length))
    def _():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (ps, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _page_mask(pos, p, ps, length, window)
        _online_update(s, v_ref[0, :, 0, :].astype(jnp.float32),
                       acc, m_s, l_s)

    @pl.when(p == n_pages - 1)
    def _():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("length", "window", "interpret"))
def paged_gqa_fwd(q, pool_k, pool_v, block_tables, pos, *, length,
                  window=None, interpret=True):
    """q: (B, H, hd); pool_k/v: (P, page, KV, hd); block_tables:
    (B, >=ceil(length/page)) int32; pos: (B,) int32 -> (B, H, hd)."""
    B, H, hd = q.shape
    _P, ps, KV, _ = pool_k.shape
    G = H // KV
    n_pages = -(-length // ps)
    bt = block_tables[:, :n_pages].astype(jnp.int32)
    qg = q.reshape(B, KV, G, hd)
    kern = functools.partial(_gqa_kernel, ps=ps, n_pages=n_pages,
                             length=length, window=window,
                             scale=1.0 / (hd ** 0.5))
    kv_map = lambda b, kv, p, pos_ref, bt_ref: (bt_ref[b, p], 0, kv, 0)
    q_map = lambda b, kv, p, pos_ref, bt_ref: (b, kv, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), q_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_gqa_decode",
    )(pos.astype(jnp.int32), bt, qg, pool_k, pool_v)
    return out.reshape(B, H, hd)


def _gqa_kernel_q8(pos_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                   o_ref, acc, m_s, l_s, *, ps, n_pages, length, window,
                   scale):
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    pos = pos_ref[b]

    @pl.when((p * ps <= pos) & (p * ps < length))
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _page_mask(pos, p, ps, length, window)
        _online_update(s, v_ref[0, :, 0, :].astype(jnp.float32)
                       * vs_ref[0, 0], acc, m_s, l_s)

    @pl.when(p == n_pages - 1)
    def _():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("length", "window", "interpret"))
def paged_gqa_fwd_q8(q, pool_k, pool_v, k_scale, v_scale, block_tables,
                     pos, *, length, window=None, interpret=True):
    """Int8 pools + per-(page, kv-head) float32 scales.

    q: (B, H, hd); pool_k/v: (P, page, KV, hd) int8; k/v_scale: (P, KV)
    float32 -> (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    _P, ps, KV, _ = pool_k.shape
    G = H // KV
    n_pages = -(-length // ps)
    bt = block_tables[:, :n_pages].astype(jnp.int32)
    qg = q.reshape(B, KV, G, hd)
    kern = functools.partial(_gqa_kernel_q8, ps=ps, n_pages=n_pages,
                             length=length, window=window,
                             scale=1.0 / (hd ** 0.5))
    kv_map = lambda b, kv, p, pos_ref, bt_ref: (bt_ref[b, p], 0, kv, 0)
    sc_map = lambda b, kv, p, pos_ref, bt_ref: (bt_ref[b, p], kv)
    q_map = lambda b, kv, p, pos_ref, bt_ref: (b, kv, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), q_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, 1), sc_map),
            pl.BlockSpec((1, 1), sc_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_gqa_decode_q8",
    )(pos.astype(jnp.int32), bt, qg, pool_k, pool_v,
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
    return out.reshape(B, H, hd)


def _mla_kernel(pos_ref, bt_ref, qa_ref, qr_ref, ckv_ref, kr_ref, o_ref,
                acc, m_s, l_s, *, ps, n_pages, length, scale):
    b, p = pl.program_id(0), pl.program_id(1)

    @pl.when(p == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    pos = pos_ref[b]

    @pl.when((p * ps <= pos) & (p * ps < length))
    def _():
        qa = qa_ref[0].astype(jnp.float32)    # (H, r)
        qr = qr_ref[0].astype(jnp.float32)    # (H, dr)
        ckv = ckv_ref[0].astype(jnp.float32)  # (ps, r)
        kr = kr_ref[0].astype(jnp.float32)    # (ps, dr)
        s = (jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32))
        s = s * scale + _page_mask(pos, p, ps, length, None)
        _online_update(s, ckv, acc, m_s, l_s)

    @pl.when(p == n_pages - 1)
    def _():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("length", "scale", "interpret"))
def paged_mla_fwd(q_abs, q_rope, pool_ckv, pool_krope, block_tables, pos,
                  *, length, scale, interpret=True):
    """q_abs: (B, H, r); q_rope: (B, H, dr); pool_ckv: (P, page, r);
    pool_krope: (P, page, dr) -> latent output (B, H, r)."""
    B, H, r = q_abs.shape
    _P, ps, _ = pool_ckv.shape
    dr = q_rope.shape[-1]
    n_pages = -(-length // ps)
    bt = block_tables[:, :n_pages].astype(jnp.int32)
    kern = functools.partial(_mla_kernel, ps=ps, n_pages=n_pages,
                             length=length, scale=scale)
    page_map = lambda b, p, pos_ref, bt_ref: (bt_ref[b, p], 0, 0)
    q_map = lambda b, p, pos_ref, bt_ref: (b, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, r), q_map),
            pl.BlockSpec((1, H, dr), q_map),
            pl.BlockSpec((1, ps, r), page_map),
            pl.BlockSpec((1, ps, dr), page_map),
        ],
        out_specs=pl.BlockSpec((1, H, r), q_map),
        scratch_shapes=[
            pltpu.VMEM((H, r), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_abs.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="paged_mla_decode",
    )(pos.astype(jnp.int32), bt, q_abs, q_rope, pool_ckv, pool_krope)


def _mla_kernel_q8(pos_ref, bt_ref, qa_ref, qr_ref, ckv_ref, kr_ref,
                   cs_ref, rs_ref, o_ref, acc, m_s, l_s, *, ps, n_pages,
                   length, scale):
    b, p = pl.program_id(0), pl.program_id(1)

    @pl.when(p == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    pos = pos_ref[b]

    @pl.when((p * ps <= pos) & (p * ps < length))
    def _():
        qa = qa_ref[0].astype(jnp.float32)                     # (H, r)
        qr = qr_ref[0].astype(jnp.float32)                     # (H, dr)
        ckv = ckv_ref[0].astype(jnp.float32) * cs_ref[0, 0]    # (ps, r)
        kr = kr_ref[0].astype(jnp.float32) * rs_ref[0, 0]      # (ps, dr)
        s = (jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32))
        s = s * scale + _page_mask(pos, p, ps, length, None)
        _online_update(s, ckv, acc, m_s, l_s)

    @pl.when(p == n_pages - 1)
    def _():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("length", "scale", "interpret"))
def paged_mla_fwd_q8(q_abs, q_rope, pool_ckv, pool_krope, ckv_scale,
                     krope_scale, block_tables, pos, *, length, scale,
                     interpret=True):
    """Int8 latent pools + per-page float32 scales.

    pool_ckv: (P, page, r) int8; pool_krope: (P, page, dr) int8;
    ckv/krope_scale: (P,) float32 -> latent output (B, H, r)."""
    B, H, r = q_abs.shape
    _P, ps, _ = pool_ckv.shape
    dr = q_rope.shape[-1]
    n_pages = -(-length // ps)
    bt = block_tables[:, :n_pages].astype(jnp.int32)
    kern = functools.partial(_mla_kernel_q8, ps=ps, n_pages=n_pages,
                             length=length, scale=scale)
    page_map = lambda b, p, pos_ref, bt_ref: (bt_ref[b, p], 0, 0)
    sc_map = lambda b, p, pos_ref, bt_ref: (bt_ref[b, p], 0)
    q_map = lambda b, p, pos_ref, bt_ref: (b, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, r), q_map),
            pl.BlockSpec((1, H, dr), q_map),
            pl.BlockSpec((1, ps, r), page_map),
            pl.BlockSpec((1, ps, dr), page_map),
            pl.BlockSpec((1, 1), sc_map),
            pl.BlockSpec((1, 1), sc_map),
        ],
        out_specs=pl.BlockSpec((1, H, r), q_map),
        scratch_shapes=[
            pltpu.VMEM((H, r), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_abs.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="paged_mla_decode_q8",
    )(pos.astype(jnp.int32), bt, q_abs, q_rope, pool_ckv, pool_krope,
      ckv_scale.astype(jnp.float32).reshape(-1, 1),
      krope_scale.astype(jnp.float32).reshape(-1, 1))
