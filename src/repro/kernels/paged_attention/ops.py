"""Public paged-attention decode ops + analytic cost models.

``paged_gqa_attention`` / ``paged_mla_attention`` dispatch one
single-token decode read of a paged KV cache:

  * backend "xla"         — dense-gather reference (ref.py): materializes
                            each request's page chain and runs masked
                            softmax attention.  The definitional oracle.
  * backend "pallas"      — the block-table kernel, PLATFORM-ADAPTIVE:
                            interpret mode off-TPU (CPU tests and dev
                            boxes), compiled on TPU.  The default
                            serving path (``ModelConfig.paged_impl``).
  * backend "pallas_tpu"  — compiled unconditionally (fails fast off-TPU;
                            use to guarantee the production lowering).

Passing the per-page scale tensors (``k_scale``/``v_scale`` for GQA,
``ckv_scale``/``krope_scale`` for MLA) selects the int8 read path: the
kernels dequantize in-register (see ``quant``), the oracle dequantizes
up front.  Scales must come as a pair — an int8 pool without its scales
is uninterpretable.

Decode is inference-only, so no custom VJP is defined (the train/prefill
regimes never see a page table).  ``cost_model`` (GQA, window-aware) and
``cost_model_mla`` (latent pages) return the analytic per-call
(flops, hbm_bytes): paged decode is memory-bound — it streams the LIVE
pages once (the dense path would stream slots × max_len regardless of
occupancy), plus q/out, which is the whole point.
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention import ref
from repro.kernels.paged_attention.paged_attention import (
    paged_gqa_fwd, paged_gqa_fwd_q8, paged_mla_fwd, paged_mla_fwd_q8)

BACKENDS = ("xla", "pallas", "pallas_tpu")


def _check_backend(backend):
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")


def _interpret(backend):
    # "pallas" = fast path everywhere: interpret off-TPU, compiled on TPU
    return backend == "pallas" and jax.default_backend() != "tpu"


def _check_scales(a, b, names):
    if (a is None) != (b is None):
        raise ValueError(f"pass both {names} or neither (int8 pools are "
                         "uninterpretable without their scales)")


def paged_gqa_attention(q, pool_k, pool_v, block_tables, pos, *, length,
                        window=None, backend="xla", k_scale=None,
                        v_scale=None):
    """q: (B, H, hd); pool_k/v: (P, page, KV, hd) with H % KV == 0;
    block_tables: (B, n_chain) int32 page ids; pos: (B,) -> (B, H, hd).

    ``length`` is the dense cache length being emulated (ring length for
    sliding-window, where it must be <= ``window``).  ``k_scale`` /
    ``v_scale`` (P, KV) float32 select the int8 read path."""
    _check_backend(backend)
    _check_scales(k_scale, v_scale, "k_scale/v_scale")
    if window is not None and length > window:
        raise ValueError(f"ring length {length} exceeds window {window} "
                         "(pass length = min(window, max_len))")
    if backend == "xla":
        return ref.paged_gqa_ref(q, pool_k, pool_v, block_tables, pos,
                                 length=length, window=window,
                                 k_scale=k_scale, v_scale=v_scale)
    if k_scale is not None:
        return paged_gqa_fwd_q8(q, pool_k, pool_v, k_scale, v_scale,
                                block_tables, pos, length=length,
                                window=window,
                                interpret=_interpret(backend))
    return paged_gqa_fwd(q, pool_k, pool_v, block_tables, pos,
                         length=length, window=window,
                         interpret=_interpret(backend))


def paged_mla_attention(q_abs, q_rope, pool_ckv, pool_krope, block_tables,
                        pos, *, length, scale, backend="xla",
                        ckv_scale=None, krope_scale=None):
    """Weight-absorbed MLA decode over latent pages -> (B, H, r) latent
    output (caller up-projects through W^{UV}).  ``ckv_scale`` /
    ``krope_scale`` (P,) float32 select the int8 read path."""
    _check_backend(backend)
    _check_scales(ckv_scale, krope_scale, "ckv_scale/krope_scale")
    if backend == "xla":
        return ref.paged_mla_ref(q_abs, q_rope, pool_ckv, pool_krope,
                                 block_tables, pos, length=length,
                                 scale=scale, ckv_scale=ckv_scale,
                                 krope_scale=krope_scale)
    if ckv_scale is not None:
        return paged_mla_fwd_q8(q_abs, q_rope, pool_ckv, pool_krope,
                                ckv_scale, krope_scale, block_tables, pos,
                                length=length, scale=scale,
                                interpret=_interpret(backend))
    return paged_mla_fwd(q_abs, q_rope, pool_ckv, pool_krope, block_tables,
                         pos, length=length, scale=scale,
                         interpret=_interpret(backend))


def cost_model(B, H, KV, hd, *, live_tokens, page_size, dtype_bytes=2,
               window=None, scale_bytes=0):
    """Analytic (flops, hbm_bytes) for one paged GQA decode call.

    flops: 2 matmuls (q·Kᵀ, P·V) over the live tokens = 4·B·H·T·hd.
    hbm_bytes: the LIVE K/V pages streamed once (rounded up to whole
    pages — the page is the DMA granule) + q and out; block tables are
    int32 noise.  Compare: a dense decode streams slots × max_len K/V
    regardless of how many tokens are actually live.

    A sliding-window ring holds at most ``window`` live entries — its
    page chain is bounded and recycled in place, so both terms cap
    there (the old model overcounted long-context window rows by
    live/window×).  ``dtype_bytes`` prices the POOL dtype (1 for int8);
    q/out are activations and stay in the model dtype (bf16 = 2).  For
    int8 pools pass ``scale_bytes=4`` to charge the per-(page, KV-head)
    float32 scales of each K and V page."""
    live = live_tokens if window is None else min(live_tokens, window)
    pages = -(-live // page_size)
    flops = 4 * B * H * live * hd
    kv = 2 * B * pages * page_size * KV * hd * dtype_bytes
    sc = 2 * B * pages * KV * scale_bytes
    qo = 2 * B * H * hd * 2
    bt = B * pages * 4
    return flops, kv + sc + qo + bt


def cost_model_mla(B, H, r, dr, *, live_tokens, page_size, dtype_bytes=2,
                   scale_bytes=0):
    """Analytic (flops, hbm_bytes) for one paged MLA decode call.

    Latent pages stream (r + dr)-dim ROWS — ckv plus k_rope — not
    KV×hd: bytes are B·pages·ps·(r+dr)·dtype_bytes once (the old GQA
    model had no MLA variant and the roofline rows priced phantom KV
    heads).  flops: scores read both latents (2·B·H·T·(r+dr)) and the
    P·V output contracts over ckv only (2·B·H·T·r).  q_abs/q_rope/out
    stay in the model dtype; ``scale_bytes=4`` adds the two per-page
    float32 scales (ckv, krope) for int8 latent pools."""
    pages = -(-live_tokens // page_size)
    flops = 2 * B * H * live_tokens * (r + dr) + 2 * B * H * live_tokens * r
    kv = B * pages * page_size * (r + dr) * dtype_bytes
    sc = 2 * B * pages * scale_bytes
    qo = B * H * (r + dr) * 2 + B * H * r * 2
    bt = B * pages * 4
    return flops, kv + sc + qo + bt
