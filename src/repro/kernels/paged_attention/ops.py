"""Public paged-attention decode ops + analytic cost model.

``paged_gqa_attention`` / ``paged_mla_attention`` dispatch one
single-token decode read of a paged KV cache:

  * backend "xla"         — dense-gather reference (ref.py): materializes
                            each request's page chain and runs masked
                            softmax attention.  The definitional oracle.
  * backend "pallas"      — the TPU kernel in interpret mode (CPU tests)
  * backend "pallas_tpu"  — compiled (production)

Decode is inference-only, so no custom VJP is defined (the train/prefill
regimes never see a page table).  ``cost_model`` returns the analytic
per-call (flops, hbm_bytes): paged decode is memory-bound — it streams
the LIVE pages once (the dense path would stream slots × max_len
regardless of occupancy), plus q/out, which is the whole point.
"""
from __future__ import annotations

from repro.kernels.paged_attention import ref
from repro.kernels.paged_attention.paged_attention import (paged_gqa_fwd,
                                                           paged_mla_fwd)

BACKENDS = ("xla", "pallas", "pallas_tpu")


def _check_backend(backend):
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")


def paged_gqa_attention(q, pool_k, pool_v, block_tables, pos, *, length,
                        window=None, backend="xla"):
    """q: (B, H, hd); pool_k/v: (P, page, KV, hd) with H % KV == 0;
    block_tables: (B, n_chain) int32 page ids; pos: (B,) -> (B, H, hd).

    ``length`` is the dense cache length being emulated (ring length for
    sliding-window, where it must be <= ``window``)."""
    _check_backend(backend)
    if window is not None and length > window:
        raise ValueError(f"ring length {length} exceeds window {window} "
                         "(pass length = min(window, max_len))")
    if backend == "xla":
        return ref.paged_gqa_ref(q, pool_k, pool_v, block_tables, pos,
                                 length=length, window=window)
    return paged_gqa_fwd(q, pool_k, pool_v, block_tables, pos,
                         length=length, window=window,
                         interpret=(backend == "pallas"))


def paged_mla_attention(q_abs, q_rope, pool_ckv, pool_krope, block_tables,
                        pos, *, length, scale, backend="xla"):
    """Weight-absorbed MLA decode over latent pages -> (B, H, r) latent
    output (caller up-projects through W^{UV})."""
    _check_backend(backend)
    if backend == "xla":
        return ref.paged_mla_ref(q_abs, q_rope, pool_ckv, pool_krope,
                                 block_tables, pos, length=length,
                                 scale=scale)
    return paged_mla_fwd(q_abs, q_rope, pool_ckv, pool_krope, block_tables,
                         pos, length=length, scale=scale,
                         interpret=(backend == "pallas"))


def cost_model(B, H, KV, hd, *, live_tokens, page_size, dtype_bytes=2):
    """Analytic (flops, hbm_bytes) for one paged GQA decode call.

    flops: 2 matmuls (q·Kᵀ, P·V) over the live tokens = 4·B·H·T·hd.
    hbm_bytes: the LIVE K/V pages streamed once (rounded up to whole
    pages — the page is the DMA granule) + q and out; block tables are
    int32 noise.  Compare: a dense decode streams slots × max_len K/V
    regardless of how many tokens are actually live."""
    pages = -(-live_tokens // page_size)
    flops = 4 * B * H * live_tokens * hd
    kv = 2 * B * pages * page_size * KV * hd * dtype_bytes
    qo = 2 * B * H * hd * dtype_bytes
    bt = B * pages * 4
    return flops, kv + qo + bt
