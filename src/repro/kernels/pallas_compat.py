"""Version tolerance for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels are written against the new name, so resolve whichever one the
pinned toolchain provides.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
