"""Public flash-attention op with custom VJP + analytic roofline cost model.

``flash_attention(q, k, v, causal, window, backend)``:
  * backend "pallas"      — the TPU kernel in interpret mode (CPU tests)
  * backend "pallas_tpu"  — compiled (production)
  * backend "xla"         — naive reference (baseline path)

The VJP runs the FlashAttention-2 backward kernels (dKdV + dQ), reducing
dk/dv over GQA groups.  ``cost_model`` returns the analytic per-call
(flops, hbm_bytes) used by launch.dryrun when accounting kernel regions the
XLA cost model cannot see into (Pallas custom calls are opaque).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import flash_bwd, flash_fwd


def _blocks(S):
    for b in (128, 64, 32, 16, 8, 4, 2, 1):
        if S % b == 0:
            return b
    return 1


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, window=None, backend="pallas"):
    """q: (B, H, S, D); k, v: (B, KV, S, D) with H % KV == 0 -> (B, H, S, D)."""
    out, _ = _fwd(q, k, v, causal, window, backend)
    return out


def _fwd(q, k, v, causal, window, backend):
    if backend == "xla":
        G = q.shape[1] // k.shape[1]
        kx = jnp.repeat(k, G, axis=1)
        vx = jnp.repeat(v, G, axis=1)
        out = ref.mha_ref(q, kx, vx, causal=causal, window=window)
        return out, (q, k, v, out, None)
    group = q.shape[1] // k.shape[1]
    b = _blocks(q.shape[2])
    out, lse = flash_fwd(q, k, v, bq=b, bk=b, causal=causal, window=window,
                         group=group, interpret=(backend == "pallas"))
    return out, (q, k, v, out, lse)


def _bwd(causal, window, backend, res, g):
    q, k, v, out, lse = res
    group = q.shape[1] // k.shape[1]
    if backend == "xla" or lse is None:
        # differentiate the reference directly
        def f(q, k, v):
            G = q.shape[1] // k.shape[1]
            return ref.mha_ref(q, jnp.repeat(k, G, 1), jnp.repeat(v, G, 1),
                               causal=causal, window=window)

        _, vjp = jax.vjp(f, q, k, v)
        return vjp(g)
    b = _blocks(q.shape[2])
    dq, dk, dv = flash_bwd(q, k, v, out, lse, g, bq=b, bk=b, causal=causal,
                           window=window, group=group,
                           interpret=(backend == "pallas"))
    B, H, S, D = q.shape
    KV = k.shape[1]
    dk = dk.reshape(B, KV, H // KV, S, D).sum(2).astype(k.dtype)
    dv = dv.reshape(B, KV, H // KV, S, D).sum(2).astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


flash_attention.defvjp(lambda q, k, v, c, w, b: _fwd(q, k, v, c, w, b),
                       _bwd)


def cost_model(B, H, KV, S, D, *, causal=True, window=None, train=True,
               dtype_bytes=2):
    """Analytic (flops, hbm_bytes) per flash-attention call.

    flops: 2 matmuls fwd (QKᵀ, PV) = 4·B·H·S_eff·S·D; bwd adds 3 matmul
    pairs + recompute ≈ 2.5× fwd.  causal/window halve/shrink S_eff.
    hbm_bytes: q,k,v read + o written (+ lse), ×3 passes for bwd (re-read in
    dKdV and dQ) + gradient writes — O(S·D), never O(S²).
    """
    frac = 0.5 if causal else 1.0
    if window is not None and window < S:
        frac = min(frac, window / S)
    fwd_flops = 4 * B * H * S * S * D * frac
    flops = fwd_flops * (1 + 2.5 if train else 1)
    qkv = B * (H + 2 * KV) * S * D * dtype_bytes
    o = B * H * S * D * dtype_bytes
    lse = B * H * S * 4
    passes = 3 if train else 1
    grads = (qkv + o) if train else 0
    return flops, qkv * passes + o + lse * passes + grads
