"""Pallas TPU flash attention (FlashAttention-2 schedule), fwd + bwd.

Why this kernel exists (DESIGN.md §Perf, hillclimb cell A): the naive
attention path materializes the (S, S) score matrix in HBM — at train_4k it
is ~8 GB/layer/device for even a small model and dominates the memory
roofline term by >10×.  The flash schedule keeps score tiles resident in
VMEM (online softmax), so HBM traffic is O(S·d) instead of O(S²).

Forward: grid (B, H, S/bq, S/bk) with the KV axis innermost and sequential;
running (m, l, acc) live in VMEM scratch; out + logsumexp written at the
last KV block.  Causal and sliding-window masks are applied in-kernel; with
causality, KV blocks entirely above the diagonal are skipped via pl.when.

Backward (FlashAttention-2 style, two passes sharing one kernel body each):
  * dKdV kernel: grid (B, H, S/bk, S/bq) — for a fixed KV tile, iterate Q
    tiles, recompute p = exp(qkᵀ·scale − L), accumulate dv += pᵀ·do and
    dk += dsᵀ·q with ds = p ∘ (do·vᵀ − D), D = rowsum(do ∘ o).
  * dQ kernel: grid (B, H, S/bq, S/bk) — for a fixed Q tile, iterate KV
    tiles, accumulate dq += ds·k.
Residuals saved from fwd: out and L = m + log(l) (one fp32 per row).

GQA is handled by index maps (kv_head = q_head // group) — K/V are never
expanded in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _mask(qi, ki, bq, bk, *, causal, window):
    """Additive mask for a (bq, bk) tile at block coords (qi, ki)."""
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= (qpos - kpos) < window
    return jnp.where(ok, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m_s, l_s, *,
                bq, bk, n_k, scale, causal, window):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    # skip fully-masked KV tiles (strictly above the diagonal)
    run = True
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, (qi * bq) - (ki * bk + bk - 1) < window) \
            if not isinstance(run, bool) else \
            ((qi * bq) - (ki * bk + bk - 1) < window)

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _mask(qi, ki, bq, bk, causal=causal, window=window)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == n_k - 1)
    def _():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l))[:, 0].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "window",
                                             "group", "interpret"))
def flash_fwd(q, k, v, *, bq=128, bk=128, causal=True, window=None,
              group=1, interpret=True):
    """q: (B, H, S, D); k, v: (B, H//group, S, D) -> (out, lse)."""
    B, H, S, D = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_k = S // bq, S // bk
    grid = (B, H, n_q, n_k)
    kern = functools.partial(_fwd_kernel, bq=bq, bk=bk, n_k=n_k,
                             scale=1.0 / math.sqrt(D), causal=causal,
                             window=window)
    kv_map = lambda b, h, qi, ki: (b, h // group, ki, 0)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), kv_map),
            pl.BlockSpec((1, 1, bk, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_fwd",
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                bq, bk, n_q, scale, causal, window):
    ki, qi = pl.program_id(2), pl.program_id(3)

    @pl.when(qi == 0)
    def _():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = True
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)
    if window is not None:
        cond = (qi * bq) - (ki * bk + bk - 1) < window
        run = jnp.logical_and(run, cond) if not isinstance(run, bool) else cond

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)     # (bq, D)
        lse = lse_ref[0, 0].astype(jnp.float32)   # (bq,)
        delta = delta_ref[0, 0].astype(jnp.float32)  # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _mask(qi, ki, bq, bk, causal=causal, window=window)
        p = jnp.exp(s - lse[:, None])             # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_acc, *, bq, bk, n_k, scale, causal, window):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    run = True
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)
    if window is not None:
        cond = (qi * bq) - (ki * bk + bk - 1) < window
        run = jnp.logical_and(run, cond) if not isinstance(run, bool) else cond

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)
        delta = delta_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _mask(qi, ki, bq, bk, causal=causal, window=window)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "window",
                                             "group", "interpret"))
def flash_bwd(q, k, v, o, lse, do, *, bq=128, bk=128, causal=True,
              window=None, group=1, interpret=True):
    """Returns (dq, dk, dv); dk/dv are per-(q-)head (caller reduces groups)."""
    B, H, S, D = q.shape
    n_q, n_k = S // bq, S // bk
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)
    scale = 1.0 / math.sqrt(D)
    kv_map4 = lambda b, h, x, y: (b, h // group, y, 0)  # noqa: E731

    dkv = pl.pallas_call(
        functools.partial(_dkv_kernel, bq=bq, bk=bk, n_q=n_q, scale=scale,
                          causal=causal, window=window),
        grid=(B, H, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, ki, qi: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, ki, qi: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_dkv",
    )(q, k, v, do, lse, delta)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bq=bq, bk=bk, n_k=n_k, scale=scale,
                          causal=causal, window=window),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), kv_map4),
            pl.BlockSpec((1, 1, bk, D), kv_map4),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_dq",
    )(q, k, v, do, lse, delta)
    return dq, dkv[0], dkv[1]
