"""Pure-jnp oracle for (causal / sliding-window) multi-head attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q, k, v, *, causal=True, window=None):
    """q, k, v: (B, H, S, D) (kv heads already expanded). -> (B, H, S, D)."""
    S = q.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window is not None:
        mask &= (idx[:, None] - idx[None, :]) < window
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(q.dtype), v)
