# Pallas TPU kernels for the paper's compute hot-spots:
#   linear_scan — chunked diagonal linear recurrence h_t = a_t*h_{t-1} + b_t
#                 (the minGRU/Mamba state update, paper §2 Eq. 1 / §3.1.3)
#   imc_mvm     — binary-activation × 2 b-weight charge-sharing matmul
#                 (the switched-capacitor IMC projection, paper §3.1.1 Eq. 6)
# Each has <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper with
# custom_vjp) and ref.py (pure-jnp oracle used by tests & as CPU fallback).
#   flash_attention — FlashAttention-2 fwd/bwd, GQA via index maps (§Perf A)
#   fused_ssm   — fused Mamba selective scan fwd/bwd (§Perf cell C)
#   minimalist_block — the paper's whole core as ONE fused inference kernel
