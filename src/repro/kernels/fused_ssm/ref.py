"""Pure-jnp oracle for the fused selective scan (Mamba-1 SSM core).

    a_t = exp(dt_t ⊙ A)                    (B,T,di,n)
    h_t = a_t ⊙ h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t
    y_t = Σ_n h_t ⊙ C_t

The *fused* kernel never materializes a, b or h in HBM — this reference
does (it is the memory-roofline baseline the kernel eliminates).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dt, x, Bm, Cm, A):
    """dt, x: (B,T,di); Bm, Cm: (B,T,n); A: (di,n) -> y: (B,T,di)."""
    a = jnp.exp(dt[..., None] * A)                      # (B,T,di,n)
    b = (dt * x)[..., None] * Bm[:, :, None, :]         # (B,T,di,n)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    B, T, di = x.shape
    n = A.shape[1]
    h0 = jnp.zeros((B, di, n), a.dtype)
    _, hs = jax.lax.scan(step, h0,
                         (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1)                          # (B,T,di,n)
    y = jnp.einsum("btdn,btn->btd", hs, Cm)
    return y
