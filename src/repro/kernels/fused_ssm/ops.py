"""Public fused selective-scan op with custom VJP + analytic cost model."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_ssm import ref
from repro.kernels.fused_ssm.fused_ssm import fused_ssm_bwd, fused_ssm_fwd


def _blk(v, opts):
    for b in opts:
        if v % b == 0:
            return b
    return 1


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def selective_scan(dt, x, Bm, Cm, A, backend="pallas"):
    """dt, x: (B,T,di); Bm, Cm: (B,T,n); A: (di,n) -> y (B,T,di)."""
    y, _ = _fwd(dt, x, Bm, Cm, A, backend)
    return y


def _fwd(dt, x, Bm, Cm, A, backend):
    if backend == "xla":
        return ref.selective_scan_ref(dt, x, Bm, Cm, A), \
            (dt, x, Bm, Cm, A, None)
    tblk = _blk(x.shape[1], (256, 128, 64, 32, 16, 8, 4, 2, 1))
    dblk = _blk(x.shape[2], (128, 64, 32, 16, 8, 4, 2, 1))
    y, h_entries = fused_ssm_fwd(dt, x, Bm, Cm, A, tblk=tblk, dblk=dblk,
                                 interpret=(backend == "pallas"))
    return y, (dt, x, Bm, Cm, A, h_entries)


def _bwd(backend, res, dy):
    dt, x, Bm, Cm, A, h_entries = res
    if backend == "xla" or h_entries is None:
        _, vjp = jax.vjp(lambda *a: ref.selective_scan_ref(*a),
                         dt, x, Bm, Cm, A)
        return vjp(dy)
    tblk = _blk(x.shape[1], (256, 128, 64, 32, 16, 8, 4, 2, 1))
    dblk = _blk(x.shape[2], (128, 64, 32, 16, 8, 4, 2, 1))
    ddt, dx, dBp, dCp, dAp = fused_ssm_bwd(
        dt, x, Bm, Cm, A, h_entries, dy, tblk=tblk, dblk=dblk,
        interpret=(backend == "pallas"))
    B, T, di = x.shape
    n_d = di // dblk
    dB = dBp.reshape(B, n_d, T, -1).sum(1).astype(Bm.dtype)
    dC = dCp.reshape(B, n_d, T, -1).sum(1).astype(Cm.dtype)
    dA = dAp.sum(0).astype(A.dtype)
    return (ddt.astype(dt.dtype), dx.astype(x.dtype), dB, dC, dA)


selective_scan.defvjp(lambda dt, x, Bm, Cm, A, b: _fwd(dt, x, Bm, Cm, A, b),
                      _bwd)


def cost_model(B, T, di, n, *, train=True, dtype_bytes=2, tblk=256):
    """Analytic (flops, hbm_bytes) per fused selective-scan call.

    flops: fwd ≈ 6 VPU ops per (t, d, n) element (exp, 2 mul-add for the
    recurrence, mul-add for y) ⇒ 6·B·T·di·n; bwd ≈ 2.5× (recompute + grads).
    hbm_bytes: inputs dt,x (B·T·di), B,C (B·T·n), y out, chunk-entry
    residuals (B·T/tblk·di·n fp32); bwd re-reads inputs + writes grads.
    The (B,T,di,n) a/b/h tensors NEVER touch HBM — that is the point.
    """
    el = B * T * di * n
    flops = 6 * el * (3.5 if train else 1.0)
    io = (2 * B * T * di + 2 * B * T * n) * dtype_bytes
    resid = (B * (T // tblk) * di * n) * 4
    out = B * T * di * dtype_bytes
    if train:
        return flops, 2 * io + 2 * out + 2 * resid + io  # re-read + grads
    return flops, io + out + resid
