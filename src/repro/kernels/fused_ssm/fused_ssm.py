"""Pallas TPU kernel: fused Mamba-1 selective scan, fwd + bwd.

Hillclimb cell C (falcon-mamba train_4k, EXPERIMENTS.md §Perf): the XLA
path materializes a = exp(dt⊗A), b = (dt·x)⊗B and the state trajectory h —
three (B, T, d_inner, n) tensors ≈ 34 TB/device/step at train_4k.  This
kernel computes the discretization AND the y = Σ_n h∘C contraction inside
VMEM; HBM sees only the O(B·T·d_inner) inputs/outputs — the TPU-native
version of Mamba's fused CUDA scan (hardware adaptation per DESIGN.md §3).

Forward: grid (B, di/dblk, T/tblk), time chunks sequential, carry h
(dblk, n) in VMEM scratch; emits y and the chunk-entry states
(B, n_chunks, di, n) as bwd residuals.

Backward: same grid with the time axis *reversed* by index maps; per chunk
it (1) recomputes h locally from the saved chunk-entry state, storing the
trajectory in a (tblk, dblk, n) VMEM scratch, then (2) runs the reverse
recurrence λ_t = dh_t ∘ a_t with all parameter/input gradients computed on
the fly.  dA/dB/dC partial sums are emitted per (batch, di-block) and
reduced in ops.py (avoids cross-grid-cell write races).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _fwd_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, hout_ref,
                h_s, *, tblk):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        h_s[...] = jnp.zeros_like(h_s)

    # save chunk-entry state (bwd residual)
    hout_ref[0, 0] = h_s[...].astype(hout_ref.dtype)

    A = a_ref[...].astype(jnp.float32)              # (dblk, n)
    dt = dt_ref[0].astype(jnp.float32)              # (tblk, dblk)
    x = x_ref[0].astype(jnp.float32)
    Bm = b_ref[0].astype(jnp.float32)               # (tblk, n)
    Cm = c_ref[0].astype(jnp.float32)

    def step(t, h):
        a_t = jnp.exp(dt[t][:, None] * A)           # (dblk, n)
        h = a_t * h + (dt[t] * x[t])[:, None] * Bm[t][None, :]
        y_ref[0, t, :] = (h * Cm[t][None, :]).sum(-1).astype(y_ref.dtype)
        return h

    h_s[...] = jax.lax.fori_loop(0, tblk, step, h_s[...])


def _bwd_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, dy_ref,
                ddt_ref, dx_ref, db_ref, dc_ref, da_ref,
                lam_s, htraj_s, da_s, *, tblk, n_t):
    ti = pl.program_id(2)   # reversed by index maps: ti=0 is the LAST chunk

    @pl.when(ti == 0)
    def _():
        lam_s[...] = jnp.zeros_like(lam_s)
        da_s[...] = jnp.zeros_like(da_s)

    A = a_ref[...].astype(jnp.float32)              # (dblk, n)
    dt = dt_ref[0].astype(jnp.float32)              # (tblk, dblk)
    x = x_ref[0].astype(jnp.float32)
    Bm = b_ref[0].astype(jnp.float32)               # (tblk, n)
    Cm = c_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)              # (tblk, dblk)
    h_entry = h0_ref[0, 0].astype(jnp.float32)      # (dblk, n)

    # (1) local forward recompute, storing the in-chunk trajectory
    def fstep(t, h):
        a_t = jnp.exp(dt[t][:, None] * A)
        h = a_t * h + (dt[t] * x[t])[:, None] * Bm[t][None, :]
        htraj_s[t] = h
        return h

    jax.lax.fori_loop(0, tblk, fstep, h_entry)

    # (2) reverse pass with λ carry
    def bstep(i, lam):
        t = tblk - 1 - i
        a_t = jnp.exp(dt[t][:, None] * A)
        h_prev = jnp.where(t == 0, h_entry, htraj_s[jnp.maximum(t - 1, 0)])
        h_t = htraj_s[t]
        dh = dy[t][:, None] * Cm[t][None, :] + lam      # (dblk, n)
        dc_ref[0, t, :] = (dy[t][:, None] * h_t).sum(0).astype(dc_ref.dtype)
        da_t = dh * h_prev
        ddt_ref[0, t, :] = ((da_t * A * a_t).sum(-1)
                            + (dh * Bm[t][None, :]).sum(-1) * x[t]
                            ).astype(ddt_ref.dtype)
        dx_ref[0, t, :] = (dt[t] * (dh * Bm[t][None, :]).sum(-1)
                           ).astype(dx_ref.dtype)
        db_ref[0, t, :] = (dh * (dt[t] * x[t])[:, None]).sum(0
                                                             ).astype(db_ref.dtype)
        da_s[...] += da_t * dt[t][:, None] * a_t
        return dh * a_t

    lam_s[...] = jax.lax.fori_loop(0, tblk, bstep, lam_s[...])

    @pl.when(ti == n_t - 1)
    def _():
        da_ref[0] = da_s[...].astype(da_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tblk", "dblk", "interpret"))
def fused_ssm_fwd(dt, x, Bm, Cm, A, *, tblk=64, dblk=128, interpret=True):
    """Returns (y, h_entries): y (B,T,di); h_entries (B, T/tblk, di, n)."""
    B, T, di = x.shape
    n = A.shape[1]
    assert T % tblk == 0 and di % dblk == 0, (T, tblk, di, dblk)
    n_t = T // tblk
    grid = (B, di // dblk, n_t)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, tblk=tblk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tblk, dblk), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, tblk, dblk), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, tblk, n), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, tblk, n), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((dblk, n), lambda b, d, t: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tblk, dblk), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, 1, dblk, n), lambda b, d, t: (b, t, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, di), x.dtype),
            jax.ShapeDtypeStruct((B, n_t, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dblk, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="fused_ssm_fwd",
    )(dt, x, Bm, Cm, A)


@functools.partial(jax.jit, static_argnames=("tblk", "dblk", "interpret"))
def fused_ssm_bwd(dt, x, Bm, Cm, A, h_entries, dy, *, tblk=64, dblk=128,
                  interpret=True):
    """Returns (ddt, dx, dB_partial, dC_partial, dA_partial).

    dB/dC partials have an extra leading di-block axis; dA partials an
    extra batch axis — ops.py reduces them."""
    B, T, di = x.shape
    n = A.shape[1]
    n_t = T // tblk
    n_d = di // dblk
    rev = lambda b, d, t: (b, n_t - 1 - t, d)       # reversed time chunks
    return pl.pallas_call(
        functools.partial(_bwd_kernel, tblk=tblk, n_t=n_t),
        grid=(B, n_d, n_t),
        in_specs=[
            pl.BlockSpec((1, tblk, dblk), rev),
            pl.BlockSpec((1, tblk, dblk), rev),
            pl.BlockSpec((1, tblk, n), lambda b, d, t: (b, n_t - 1 - t, 0)),
            pl.BlockSpec((1, tblk, n), lambda b, d, t: (b, n_t - 1 - t, 0)),
            pl.BlockSpec((dblk, n), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, 1, dblk, n),
                         lambda b, d, t: (b, n_t - 1 - t, d, 0)),
            pl.BlockSpec((1, tblk, dblk), rev),
        ],
        out_specs=[
            pl.BlockSpec((1, tblk, dblk), rev),
            pl.BlockSpec((1, tblk, dblk), rev),
            pl.BlockSpec((1, tblk, n),
                         lambda b, d, t: (b * n_d + d, n_t - 1 - t, 0)),
            pl.BlockSpec((1, tblk, n),
                         lambda b, d, t: (b * n_d + d, n_t - 1 - t, 0)),
            pl.BlockSpec((1, dblk, n), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, di), jnp.float32),
            jax.ShapeDtypeStruct((B, T, di), jnp.float32),
            jax.ShapeDtypeStruct((B * n_d, T, n), jnp.float32),
            jax.ShapeDtypeStruct((B * n_d, T, n), jnp.float32),
            jax.ShapeDtypeStruct((B, di, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dblk, n), jnp.float32),          # λ carry
            pltpu.VMEM((tblk, dblk, n), jnp.float32),    # local trajectory
            pltpu.VMEM((dblk, n), jnp.float32),          # dA accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="fused_ssm_bwd",
    )(dt, x, Bm, Cm, A, h_entries, dy)
