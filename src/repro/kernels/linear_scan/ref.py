"""Pure-jnp oracle for the diagonal linear recurrence

    h_t = a_t ⊙ h_{t-1} + b_t ,   t = 0..T-1,  h_{-1} = h0

which is the minGRU state update (a = 1 - z, b = z ⊙ h̃, paper Eq. 1) and —
with per-channel decays — the Mamba-1 selective-SSM recurrence.

Two references:
  * ``linear_scan_sequential``  — definitional lax.scan (ground truth)
  * ``linear_scan_associative`` — jax.lax.associative_scan (the parallel
    training algorithm the minGRU paper enables), used as the XLA fallback
    on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_sequential(a, b, h0):
    """a, b: (B, T, D); h0: (B, D) -> h: (B, T, D)."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.swapaxes(a, 0, 1), jnp.swapaxes(b, 0, 1)))
    return jnp.swapaxes(hs, 0, 1)


def linear_scan_associative(a, b, h0):
    """Parallel (Blelloch) form via the associative operator
    (a2, b2) ∘ (a1, b1) = (a1*a2, a2*b1 + b2), fp32 accumulation."""
    dt = a.dtype
    a32 = a.astype(jnp.float32)
    # fold h0 into the first step: b_0' = a_0*h0 + b_0
    b32 = b.astype(jnp.float32)
    b32 = b32.at[:, 0, :].add(a32[:, 0, :] * h0.astype(jnp.float32))

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(dt)


def mingru_ref(x, wh, bh, wz, bz, h0, *, gate_fn, out_fn):
    """Full minGRU block oracle: projections + gate + scan + output act."""
    htilde = x @ wh + bh
    z = gate_fn(x @ wz + bz)
    h = linear_scan_sequential(1.0 - z, z * htilde, h0)
    return out_fn(h), h
