"""jit'd public wrapper for the linear_scan kernel, with a custom VJP.

The adjoint of the recurrence  h_t = a_t ⊙ h_{t-1} + b_t  is itself a
reverse-time diagonal linear recurrence:

    λ_t = g_t + a_{t+1} ⊙ λ_{t+1}          (λ: cotangent of h)
    ∂b_t = λ_t ,  ∂a_t = λ_t ⊙ h_{t-1} ,  ∂h0 = a_0 ⊙ λ_0

so the backward pass reuses the *same* scan engine on time-reversed inputs —
one extra memory-bound pass, no O(T) recomputation and no saved
intermediates beyond the forward output itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan import ref
from repro.kernels.linear_scan.linear_scan import linear_scan_pallas

# Backend selection:
#   "xla"       — associative scan (O(log T) depth); default on CPU hosts
#   "pallas"    — the TPU kernel in interpret mode (CPU validation)
#   "pallas_tpu"— the TPU kernel, compiled (production)
#   "seq"       — definitional lax.scan (debugging)
_DEFAULT_BACKEND = "xla"


def _round_up(x, m):
    return (x + m - 1) // m * m


def _dispatch(a, b, h0, backend, tblk, dblk):
    if backend == "seq":
        return ref.linear_scan_sequential(a, b, h0)
    if backend == "xla":
        return ref.linear_scan_associative(a, b, h0)
    if backend in ("pallas", "pallas_tpu"):
        B, T, D = a.shape
        tblk = min(tblk, T)
        dblk = min(dblk, _round_up(D, 128))
        Tp, Dp = _round_up(T, tblk), _round_up(D, dblk)
        pad3 = [(0, 0), (0, Tp - T), (0, Dp - D)]
        ap = jnp.pad(a, pad3)           # a=0 in padding keeps the carry exact
        bp = jnp.pad(b, pad3)
        h0p = jnp.pad(h0, [(0, 0), (0, Dp - D)])
        h = linear_scan_pallas(ap, bp, h0p, tblk=tblk, dblk=dblk,
                               interpret=(backend == "pallas"))
        return h[:, :T, :D]
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def linear_scan(a, b, h0, backend=_DEFAULT_BACKEND, tblk=256, dblk=256):
    """h_t = a_t ⊙ h_{t-1} + b_t over axis 1. a, b: (B,T,D); h0: (B,D)."""
    return _dispatch(a, b, h0, backend, tblk, dblk)


def _fwd(a, b, h0, backend, tblk, dblk):
    h = _dispatch(a, b, h0, backend, tblk, dblk)
    return h, (a, h, h0)


def _bwd(backend, tblk, dblk, res, g):
    a, h, h0 = res
    # a shifted one step forward in time, reversed:  A_rev[t] = a[T-t]
    a_shift = jnp.concatenate(
        [jnp.zeros_like(a[:, :1]), jnp.flip(a[:, 1:], axis=1)], axis=1)
    g_rev = jnp.flip(g, axis=1)
    lam_rev = _dispatch(a_shift, g_rev, jnp.zeros_like(h0), backend, tblk, dblk)
    lam = jnp.flip(lam_rev, axis=1)
    h_prev = jnp.concatenate([h0[:, None, :], h[:, :-1, :]], axis=1)
    da = lam * h_prev
    db = lam
    dh0 = a[:, 0, :] * lam[:, 0, :]
    return da, db, dh0


linear_scan.defvjp(_fwd, _bwd)


def mingru_scan(z, htilde, h0, **kw):
    """minGRU state update (paper Eq. 1): h_t = (1−z_t)⊙h_{t−1} + z_t⊙h̃_t."""
    return linear_scan(1.0 - z, z * htilde, h0, **kw)
