"""Pallas TPU kernel: chunked diagonal linear recurrence (first-order scan).

    h_t = a_t ⊙ h_{t-1} + b_t

TPU mapping (hardware-adaptation notes, DESIGN.md §3):
  * The recurrence is element-wise over the channel dim D — the "capacitor
    swap" of the paper keeps state updates fully local, which on TPU means
    the scan body is pure VPU work, vectorized across (8, 128) vregs.
  * Grid is (B, D/dblk, T/tblk).  The last grid axis iterates time chunks
    *sequentially* ("arbitrary" dimension semantics); the running state h is
    carried across time chunks in a VMEM scratch buffer, so HBM traffic is
    exactly one read of (a, b) and one write of h — the kernel is
    memory-bound by construction (arithmetic intensity 2 flops / 12 bytes
    at bf16) and the roofline target is HBM bandwidth.
  * Within a chunk the time loop is a jax.lax.fori_loop over tblk steps;
    each step is a (1, dblk)-wide fused multiply-add.
  * dblk is a multiple of 128 (lane width); tblk trades VMEM footprint
    (3 · tblk · dblk · 4 B) against grid overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _scan_kernel(h0_ref, a_ref, b_ref, out_ref, carry_ref, *, tblk: int):
    """One (batch, channel-block, time-chunk) grid cell."""
    t_idx = pl.program_id(2)

    # On the first time chunk, seed the carry from h0.
    @pl.when(t_idx == 0)
    def _():
        carry_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)   # (1, tblk, dblk)
    b = b_ref[...].astype(jnp.float32)

    def step(i, h):
        h = a[0, i, :] * h + b[0, i, :]
        out_ref[0, i, :] = h.astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, tblk, step, carry_ref[0, :])
    carry_ref[0, :] = h


@functools.partial(jax.jit, static_argnames=("tblk", "dblk", "interpret"))
def linear_scan_pallas(a, b, h0, *, tblk: int = 256, dblk: int = 256,
                       interpret: bool = True):
    """a, b: (B, T, D); h0: (B, D) -> h: (B, T, D).

    Shapes must satisfy T % tblk == 0 and D % dblk == 0 (ops.py pads).
    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    B, T, D = a.shape
    assert b.shape == (B, T, D) and h0.shape == (B, D)
    assert T % tblk == 0 and D % dblk == 0, (T, tblk, D, dblk)
    grid = (B, D // dblk, T // tblk)

    kern = functools.partial(_scan_kernel, tblk=tblk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # h0: one (1, dblk) tile per (batch, channel-block); constant in t
            pl.BlockSpec((1, dblk), lambda bi, di, ti: (bi, di)),
            pl.BlockSpec((1, tblk, dblk), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, tblk, dblk), lambda bi, di, ti: (bi, ti, di)),
        ],
        out_specs=pl.BlockSpec((1, tblk, dblk), lambda bi, di, ti: (bi, ti, di)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, dblk), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="linear_scan",
    )(h0, a, b)
