"""Pallas TPU kernel: the MINIMALIST core as ONE fused inference kernel.

This is the digital twin of the paper's switched-capacitor core (§3) at
kernel granularity — one HBM pass per time chunk performs what one clock
phase of the circuit performs:

  MXU:  the two interleaved IMC matrix-vector products (h̃ and z columns,
        2 b codes dequantized in VMEM — weights stay int8 in HBM, 4× less
        weight traffic, exactly the circuit's "weights never move" story)
  VPU:  the SAR-ADC transfer  z = floor(63·hard_sigmoid(·))/63
        (quant.quantize_unit_6b's grid — bit-exact with the circuit),
        the capacitor-swap state update  h ← z·h̃ + (1−z)·h  with the
        state resident in VMEM across the whole sequence (the kernel
        analogue of "no buffering, charge stays on the capacitors"),
        and the comparator  y = Θ(h).

Grid (B, N/nblk, T/tblk), time sequential; carry h in VMEM scratch.
Inputs per cell: x chunk (tblk, K) binary; codes (K, nblk) int8 ×2;
biases (nblk,) ×2.  Outputs: y (binary) and h (analog trace) chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

GATE_UNITS = 63.0


def _kernel(x_ref, ch_ref, cz_ref, bh_ref, bz_ref, h0_ref, y_ref, h_ref,
            h_s, *, tblk, scale):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)                       # (tblk, K)
    wh = (ch_ref[...].astype(jnp.float32) - 1.5) * scale   # (K, nblk)
    wz = (cz_ref[...].astype(jnp.float32) - 1.5) * scale
    pre_h = jax.lax.dot_general(x, wh, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        + bh_ref[...].astype(jnp.float32)
    pre_z = jax.lax.dot_general(x, wz, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        + bz_ref[...].astype(jnp.float32)
    # SAR-ADC transfer (mid-rise floor on the 63-unit capacitor grid)
    zq = jnp.floor(jnp.clip(pre_z / 6.0 + 0.5, 0.0, 1.0) * GATE_UNITS) \
        / GATE_UNITS

    def step(t, h):
        h = zq[t] * pre_h[t] + (1.0 - zq[t]) * h
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        y_ref[0, t, :] = (h > 0.0).astype(y_ref.dtype)
        return h

    h_s[0] = jax.lax.fori_loop(0, tblk, step, h_s[0])


def _step_kernel(x_ref, ch_ref, cz_ref, bh_ref, bz_ref, h0_ref, y_ref, h_ref,
                 *, scale):
    x = x_ref[...].astype(jnp.float32)                     # (B, K)
    wh = (ch_ref[...].astype(jnp.float32) - 1.5) * scale   # (K, nblk)
    wz = (cz_ref[...].astype(jnp.float32) - 1.5) * scale
    pre_h = jax.lax.dot_general(x, wh, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        + bh_ref[...].astype(jnp.float32)
    pre_z = jax.lax.dot_general(x, wz, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        + bz_ref[...].astype(jnp.float32)
    zq = jnp.floor(jnp.clip(pre_z / 6.0 + 0.5, 0.0, 1.0) * GATE_UNITS) \
        / GATE_UNITS
    h = zq * pre_h + (1.0 - zq) * h0_ref[...].astype(jnp.float32)
    h_ref[...] = h.astype(h_ref.dtype)
    y_ref[...] = (h > 0.0).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "nblk", "interpret"))
def minimalist_step_pallas(x, codes_h, codes_z, scale, bh, bz, h_prev, *,
                           nblk=128, interpret=True):
    """ONE decode step of the fused core: projection + SAR-ADC gate +
    capacitor-swap state update + comparator in a single kernel launch —
    the serving engine's hot path at O(1) state.

    x: (B, K) {0,1}; codes: (K, N) int8; bh/bz: (N,); h_prev: (B, N)
    -> (y, h) each (B, N).  N % nblk == 0.
    """
    B, K = x.shape
    N = codes_h.shape[1]
    assert N % nblk == 0, (N, nblk)
    kern = functools.partial(_step_kernel, scale=float(scale))
    return pl.pallas_call(
        kern,
        grid=(N // nblk,),
        in_specs=[
            pl.BlockSpec((B, K), lambda n: (0, 0)),
            pl.BlockSpec((K, nblk), lambda n: (0, n)),
            pl.BlockSpec((K, nblk), lambda n: (0, n)),
            pl.BlockSpec((1, nblk), lambda n: (0, n)),
            pl.BlockSpec((1, nblk), lambda n: (0, n)),
            pl.BlockSpec((B, nblk), lambda n: (0, n)),
        ],
        out_specs=[
            pl.BlockSpec((B, nblk), lambda n: (0, n)),
            pl.BlockSpec((B, nblk), lambda n: (0, n)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), x.dtype),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="minimalist_step",
    )(x, codes_h, codes_z, bh.reshape(1, N), bz.reshape(1, N), h_prev)


@functools.partial(jax.jit,
                   static_argnames=("scale", "tblk", "nblk", "interpret"))
def minimalist_block_pallas(x, codes_h, codes_z, scale, bh, bz, h0, *,
                            tblk=128, nblk=128, interpret=True):
    """x: (B,T,K) {0,1}; codes: (K,N) int8; scale float; bh/bz: (N,);
    h0: (B,N) -> (y, h) each (B,T,N).  T % tblk == 0, N % nblk == 0."""
    B, T, K = x.shape
    N = codes_h.shape[1]
    assert T % tblk == 0 and N % nblk == 0, (T, tblk, N, nblk)
    grid = (B, N // nblk, T // tblk)
    kern = functools.partial(_kernel, tblk=tblk, scale=float(scale))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tblk, K), lambda b, n, t: (b, t, 0)),
            pl.BlockSpec((K, nblk), lambda b, n, t: (0, n)),
            pl.BlockSpec((K, nblk), lambda b, n, t: (0, n)),
            pl.BlockSpec((1, nblk), lambda b, n, t: (0, n)),
            pl.BlockSpec((1, nblk), lambda b, n, t: (0, n)),
            pl.BlockSpec((1, nblk), lambda b, n, t: (b, n)),
        ],
        out_specs=[
            pl.BlockSpec((1, tblk, nblk), lambda b, n, t: (b, t, n)),
            pl.BlockSpec((1, tblk, nblk), lambda b, n, t: (b, t, n)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, N), x.dtype),
            jax.ShapeDtypeStruct((B, T, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, nblk), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="minimalist_block",
    )(x, codes_h, codes_z, bh.reshape(1, N), bz.reshape(1, N), h0)
