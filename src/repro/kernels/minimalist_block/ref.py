"""Oracle for the fused MINIMALIST block (inference, hardware mode).

Exactly core.mingru.MinGRUBlock under QuantConfig.hardware(), expressed on
exported hardware quantities (2 b codes + shared layer scale + quantized
biases):

    h̃_t = (x_t @ deq(codes_h))·Δ + b_h
    z_t  = floor(63·clip((x_t @ deq(codes_z))·Δ + b_z)/6 + ½, 0, 1))/63
    h_t  = z_t ⊙ h̃_t + (1 − z_t) ⊙ h_{t−1}
    y_t  = Θ(h_t)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import quant


def minimalist_block_ref(x, codes_h, codes_z, scale, bh, bz, h0):
    """x: (B,T,K) in {0,1}; codes: (K,N); scale: scalar; bh/bz: (N,);
    h0: (B,N).  Returns (y=Θ(h), h) each (B,T,N)."""
    wh = (codes_h.astype(jnp.float32) - 1.5) * scale
    wz = (codes_z.astype(jnp.float32) - 1.5) * scale
    htilde = x @ wh + bh
    z = quant.quantize_unit_6b(quant.hard_sigmoid(x @ wz + bz))

    hs = []
    h = h0
    for t in range(x.shape[1]):
        h = z[:, t] * htilde[:, t] + (1.0 - z[:, t]) * h
        hs.append(h)
    h_seq = jnp.stack(hs, axis=1)
    return (h_seq > 0.0).astype(x.dtype), h_seq


def minimalist_step_ref(x, codes_h, codes_z, scale, bh, bz, h_prev):
    """Single fused decode step. x: (B, K) in {0,1}; h_prev: (B, N).
    Returns (y=Θ(h), h) each (B, N)."""
    wh = (codes_h.astype(jnp.float32) - 1.5) * scale
    wz = (codes_z.astype(jnp.float32) - 1.5) * scale
    htilde = x @ wh + bh
    z = quant.quantize_unit_6b(quant.hard_sigmoid(x @ wz + bz))
    h = z * htilde + (1.0 - z) * h_prev
    return (h > 0.0).astype(x.dtype), h
