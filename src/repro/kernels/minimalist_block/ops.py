"""Public wrapper for the fused MINIMALIST inference kernel.

Inference-only (the deployment path of the paper's edge accelerator);
training uses the STE-quantized MinGRUBlock.  ``from_block_params`` exports
a trained block exactly like analog.export_layer does for the circuit.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels.minimalist_block import ref
from repro.kernels.minimalist_block.minimalist_block import (
    minimalist_block_pallas, minimalist_step_pallas)


def _pad_to(v, m):
    return (v + m - 1) // m * m


def _largest_divisor(n, ladder=(128, 64, 32, 16, 8, 4, 2, 1)):
    """Biggest tile in the ladder dividing n (1 always does)."""
    for cand in ladder:
        if n % cand == 0:
            return cand
    return n


def from_block_params(params):
    """Trained MinGRUBlock params -> (codes_h, codes_z, scale, bh, bz)."""
    scale = float(np.maximum(
        np.asarray(quant.weight_scale(params["wh"])),
        np.asarray(quant.weight_scale(params["wz"]))))
    ch = np.asarray(quant.quantize_weights_2b(params["wh"], scale)[1],
                    np.int8)
    cz = np.asarray(quant.quantize_weights_2b(params["wz"], scale)[1],
                    np.int8)
    bh = np.asarray(quant.quantize_bias_6b(params["bh"]))
    bz = np.asarray(quant.quantize_gate_bias_adc(params["bz"]))
    return ch, cz, scale, bh, bz


def minimalist_block(x, codes_h, codes_z, scale, bh, bz, h0=None, *,
                     backend="pallas"):
    """Fused hardware-mode block inference. Returns (y=Θ(h), h)."""
    B, T, K = x.shape
    N = codes_h.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, N), jnp.float32)
    if backend == "xla":
        return ref.minimalist_block_ref(x, jnp.asarray(codes_h),
                                        jnp.asarray(codes_z), scale,
                                        jnp.asarray(bh), jnp.asarray(bz), h0)
    tblk = _largest_divisor(T)
    nblk = _largest_divisor(N)
    y, h = minimalist_block_pallas(
        x, jnp.asarray(codes_h, jnp.int8), jnp.asarray(codes_z, jnp.int8),
        float(scale), jnp.asarray(bh, jnp.float32),
        jnp.asarray(bz, jnp.float32), h0, tblk=tblk, nblk=nblk,
        interpret=(backend == "pallas"))
    return y, h


def minimalist_step(x, codes_h, codes_z, scale, bh, bz, h_prev, *,
                    backend="pallas"):
    """Fused single-step hardware-mode decode: projection + gate + state
    update + comparator in ONE kernel.  x: (B, K); h_prev: (B, N) ->
    (y=Θ(h), h) each (B, N).  The serving engine's decode hot path."""
    N = codes_h.shape[1]
    if backend == "xla":
        return ref.minimalist_step_ref(x, jnp.asarray(codes_h),
                                       jnp.asarray(codes_z), scale,
                                       jnp.asarray(bh), jnp.asarray(bz),
                                       h_prev)
    nblk = _largest_divisor(N)
    return minimalist_step_pallas(
        x, jnp.asarray(codes_h, jnp.int8), jnp.asarray(codes_z, jnp.int8),
        float(scale), jnp.asarray(bh, jnp.float32),
        jnp.asarray(bz, jnp.float32), h_prev, nblk=nblk,
        interpret=(backend == "pallas"))


def cost_model(B, T, K, N, *, dtype_bytes=2):
    """Analytic (flops, hbm_bytes) per fused block call: two MVMs on the
    MXU + O(BTN) VPU work; HBM sees x once, int8 codes once, y/h out."""
    flops = 2 * 2 * B * T * K * N + 8 * B * T * N
    bytes_ = (B * T * K * dtype_bytes        # x (binary, stored bf16)
              + 2 * K * N                    # int8 code matrices
              + B * T * N * (dtype_bytes + 4))  # y + h out
    return flops, bytes_
