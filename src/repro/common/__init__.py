from repro.common.pytree import tree_size_bytes, tree_param_count, map_with_axes
from repro.common.precision import Policy, DEFAULT_POLICY
