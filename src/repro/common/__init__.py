from repro.common.pytree import tree_size_bytes, tree_param_count, map_with_axes
from repro.common.precision import Policy, DEFAULT_POLICY


def pow2ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1; pow2ceil(0) == 1).  The ONE
    bucket-rounding rule shared by serving admission waves, prefill chunk
    capping, and benchmark warm-up — these must agree or warmed jit
    shapes desynchronize from the engine's and retrace."""
    return 1 << max(0, int(n) - 1).bit_length()
