"""Small pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    """Total number of scalar parameters in a pytree (works on abstract values)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def tree_size_bytes(tree) -> int:
    """Total bytes of a pytree (works on ShapeDtypeStruct leaves)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize for l in leaves))


def map_with_axes(fn, params, axes):
    """tree_map over (param, logical_axes) pairs. `axes` mirrors `params`
    with tuples of logical axis names (or None) as leaves."""
    return jax.tree_util.tree_map(
        fn, params, axes, is_leaf=lambda x: x is None or isinstance(x, tuple)
    )


def flatten_dict(d, prefix=()):
    """Nested dict -> {('a','b'): leaf}."""
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out.update(flatten_dict(v, prefix + (k,)))
        else:
            out[prefix + (k,)] = v
    return out


def unflatten_dict(flat):
    out = {}
    for path, v in flat.items():
        cur = out
        for k in path[:-1]:
            cur = cur.setdefault(k, {})
        cur[path[-1]] = v
    return out
