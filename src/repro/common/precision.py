"""Mixed-precision policy.

Parameters are kept in ``param_dtype`` (fp32 by default), compute is done in
``compute_dtype`` (bf16 by default for the large-model configs, fp32 for the
paper-scale MINIMALIST nets where analog fidelity matters), and reductions /
softmax / scan carries accumulate in ``accum_dtype``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    accum_dtype: jnp.dtype = jnp.float32

    def cast_to_compute(self, tree):
        import jax

        def cast(x):
            if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
                return x.astype(self.compute_dtype)
            return x

        return jax.tree_util.tree_map(cast, tree)


DEFAULT_POLICY = Policy()
FP32_POLICY = Policy(compute_dtype=jnp.float32)
