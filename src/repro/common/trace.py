"""Chrome ``trace_event`` recording (host side only).

:class:`TraceRecorder` accumulates events in the Trace Event Format —
the JSON schema Chrome's ``about:tracing`` and Perfetto
(https://ui.perfetto.dev) load directly — so a serving run can be
inspected as a timeline: one track per request, one track for the
engine's admission/decode waves, counter tracks for pool occupancy.

Every timestamp is host wall time (``perf_counter`` microseconds,
relative to recorder construction).  Nothing here ever touches device
state or jitted programs: recording is append-to-a-python-list, and the
serving engine only calls in around (never inside) its device calls —
see :mod:`repro.serve.telemetry` for the contract.

Event phases used (one dict per event, Trace Event Format fields):

  * ``B``/``E`` — begin/end of a nested duration span on a (pid, tid)
    track; ``E`` carries the span's end-time ``args`` (e.g. tokens
    emitted by a decode wave).
  * ``i`` — an instant marker (scope ``t`` = thread).
  * ``C`` — a counter sample; Perfetto renders each ``args`` key as a
    stacked series.
  * ``M`` — metadata (thread names).

:func:`validate_chrome_trace` is the schema check the test-suite and CI
smoke run against a saved trace: required fields per phase, and every
``B`` matched by a properly nested ``E`` on its track.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List

__all__ = ["TraceRecorder", "validate_chrome_trace"]


class TraceRecorder:
    """Append-only Chrome trace_event buffer.

    ``clock`` is injectable for tests; it must be monotonic.  All
    methods are O(1) appends — the recorder is safe to leave attached
    to a serving engine for the length of a run (events are plain
    dicts; a 10k-step run records a few MB).
    """

    def __init__(self, *, pid: int = 0, clock=time.perf_counter):
        self.pid = int(pid)
        self._clock = clock
        self._t0 = clock()
        self.events: List[Dict[str, Any]] = []
        self._named_tids: set = set()

    def __len__(self):
        return len(self.events)

    def now_us(self) -> float:
        """Microseconds since recorder construction (the ``ts`` base)."""
        return (self._clock() - self._t0) * 1e6

    def thread_name(self, tid: int, name: str):
        """Label a track (idempotent): Perfetto shows this instead of a
        bare tid."""
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self.events.append({"ph": "M", "name": "thread_name",
                            "pid": self.pid, "tid": int(tid),
                            "args": {"name": str(name)}})

    def begin(self, name: str, tid: int = 0, **args):
        self.events.append({"ph": "B", "name": str(name), "cat": "serve",
                            "ts": self.now_us(), "pid": self.pid,
                            "tid": int(tid), "args": args})

    def end(self, tid: int = 0, name: str = "", **args):
        ev = {"ph": "E", "ts": self.now_us(), "pid": self.pid,
              "tid": int(tid), "args": args}
        if name:
            ev["name"] = str(name)
        self.events.append(ev)

    def instant(self, name: str, tid: int = 0, **args):
        self.events.append({"ph": "i", "name": str(name), "cat": "serve",
                            "s": "t", "ts": self.now_us(),
                            "pid": self.pid, "tid": int(tid),
                            "args": args})

    def counter(self, name: str, tid: int = 0, **values):
        self.events.append({"ph": "C", "name": str(name),
                            "ts": self.now_us(), "pid": self.pid,
                            "tid": int(tid), "args": values})

    def to_json(self) -> Dict[str, Any]:
        """The JSON-object form of the Trace Event Format (the one with
        a ``traceEvents`` key — what Perfetto's file picker expects)."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path


def validate_chrome_trace(doc) -> Dict[str, int]:
    """Schema + well-formedness check for a Chrome trace_event document.

    Raises ``ValueError`` on the first violation; returns summary counts
    (``events``, ``spans``, ``tracks``) on success.  Checks:

      * ``doc`` is the JSON-object form: a dict whose ``traceEvents``
        is a list of event dicts;
      * every event has a string ``ph``; timed phases carry a numeric
        ``ts`` and integer ``pid``/``tid``; all but ``E`` carry a name;
      * per (pid, tid) track, ``ts`` never decreases and ``B``/``E``
        events form a properly nested, fully closed stack (a named
        ``E`` must close the matching ``B``).
    """
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("trace must be an object with a 'traceEvents' "
                         "list (the Chrome JSON-object format)")
    stacks: Dict[tuple, List[str]] = {}
    last_ts: Dict[tuple, float] = {}
    n_spans = 0
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict) or not isinstance(ev.get("ph"), str):
            raise ValueError(f"event {i}: not a dict with a 'ph' phase")
        ph = ev["ph"]
        if ph == "M":
            continue
        if ph not in ("B", "E", "i", "C", "X"):
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"event {i} ({ph}): missing numeric 'ts'")
        if not isinstance(ev.get("pid"), int) or not isinstance(
                ev.get("tid"), int):
            raise ValueError(f"event {i} ({ph}): missing int pid/tid")
        if ph != "E" and not isinstance(ev.get("name"), str):
            raise ValueError(f"event {i} ({ph}): missing 'name'")
        key = (ev["pid"], ev["tid"])
        if ev["ts"] < last_ts.get(key, float("-inf")):
            raise ValueError(
                f"event {i} ({ph}): ts went backwards on track {key}")
        last_ts[key] = ev["ts"]
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(
                    f"event {i}: 'E' with no open span on track {key}")
            top = stack.pop()
            if ev.get("name") and ev["name"] != top:
                raise ValueError(
                    f"event {i}: 'E' named {ev['name']!r} closes "
                    f"{top!r} on track {key} (improper nesting)")
            n_spans += 1
    open_spans = {k: v for k, v in stacks.items() if v}
    if open_spans:
        raise ValueError(f"unclosed spans at end of trace: {open_spans}")
    return {"events": len(doc["traceEvents"]), "spans": n_spans,
            "tracks": len(last_ts)}
