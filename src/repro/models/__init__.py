# NOTE: keep this __init__ lazy — repro.core.mingru imports
# repro.models.module, and an eager transformer import here would close an
# import cycle (transformer uses core.mingru for the paper's LM mixer).
from repro.models.module import Module, Dense, Embedding, RMSNorm, LayerNorm


def build_model(cfg, **kw):
    """Factory: config -> model instance."""
    from repro.models.transformer import DecoderLM
    from repro.models.whisper import EncDecLM

    if cfg.arch_type == "audio":
        kw.pop("remat", None)
        return EncDecLM(cfg, **kw)
    return DecoderLM(cfg, **kw)
