"""Unified decoder LM covering the assigned architecture pool.

Per-layer heterogeneity (Jamba 1:7 mamba:attn, Gemma-3 5:1 local:global,
DeepSeek first-3-dense) is expressed as head-layers + a repeating pattern
unit + tail-layers (configs.base.ModelConfig).  The pattern unit is scanned
with jax.lax.scan over its repeats so compiled HLO size is O(|unit|), not
O(n_layers) — required to compile the 61–88-layer configs in the dry-run and
the production pattern (remat-friendly) anyway.

Execution regimes:
  * __call__ / loss    — full-sequence training & prefill
  * decode_step        — one token against per-layer caches (GQA ring buffer
                         for local attention, MLA latent cache, Mamba O(1)
                         state, minGRU O(1) state)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_LOCAL, MAMBA, MINGRU, MLA,
                                LayerSpec, ModelConfig)
from repro.core.mingru import MinGRUBlock
from repro.core.quant import QuantConfig
from repro.models.attention import GQAAttention, MLAAttention
from repro.models.mamba import MambaBlock
from repro.models.moe import DenseMLP, MoEMLP
from repro.models.module import (Embedding, Module, RMSNorm, stacked_init,
                                 stacked_axes)

_QUANT_MODES = {
    "float": QuantConfig.float_baseline,
    "quantized": QuantConfig.quantized,
    "hardware": QuantConfig.hardware,
}


class MinGRUMixer(Module):
    """The paper's minGRU block as an LM time-mixing layer (DESIGN.md §4).

    Pure paper semantics inside the block (input-only gates, diagonal
    recurrence, optional 2 b/6 b/binary constraints); the surrounding
    residual stream is the standard pre-norm transformer residual so the
    block is drop-in comparable with attention/mamba mixers.
    """

    def __init__(self, cfg: ModelConfig, *, scan_backend=None,
                 dtype=jnp.float32, name="mingru"):
        self.cfg = cfg
        qcfg = _QUANT_MODES[cfg.mingru_quant]()
        self.block = MinGRUBlock(cfg.d_model, cfg.d_model, qcfg=qcfg,
                                 scan_backend=scan_backend or cfg.scan_backend,
                                 dtype=dtype)
        self.name = name

    def init(self, key):
        return self.block.init(key)

    def axes(self):
        return self.block.axes()

    def __call__(self, params, x, positions=None):
        del positions
        out, _h = self.block(params, x)
        return out

    def cache_spec(self, batch, length, dtype=jnp.float32):
        del length
        return {"h": jax.ShapeDtypeStruct((batch, self.cfg.d_model), dtype)}

    def cache_axes(self):
        return {"h": ("batch", "mlp")}

    def init_cache(self, batch, length=0, dtype=jnp.float32):
        return {"h": jnp.zeros((batch, self.cfg.d_model), dtype)}

    def decode(self, params, x, cache, pos):
        del pos
        out, h = self.block.step(params, x[:, 0, :], cache["h"])
        return out[:, None, :], {"h": h}

    can_prefill = True

    def prefill(self, params, x, cache, pos0, length=None):
        """Chunk prefill: ONE linear_scan over the chunk, O(1) carry.
        ``length`` selects the carry at the last VALID token when the
        chunk tail is grid padding (the scan is causal, so padded inputs
        never reach h[length-1])."""
        del pos0
        out, h = self.block(params, x, h0=cache["h"].astype(x.dtype))
        if length is None:
            carry = h[:, -1]
        else:
            carry = jax.lax.dynamic_index_in_dim(h, length - 1, axis=1,
                                                 keepdims=False)
        return out, {"h": carry.astype(cache["h"].dtype)}


def _make_mixer(cfg: ModelConfig, spec: LayerSpec, dtype):
    if spec.kind == ATTN:
        return GQAAttention(cfg, local=False, dtype=dtype)
    if spec.kind == ATTN_LOCAL:
        return GQAAttention(cfg, local=True, dtype=dtype)
    if spec.kind == MLA:
        return MLAAttention(cfg, dtype=dtype)
    if spec.kind == MAMBA:
        return MambaBlock(cfg, scan_backend=cfg.scan_backend, dtype=dtype)
    if spec.kind == MINGRU:
        return MinGRUMixer(cfg, dtype=dtype)
    raise ValueError(f"unknown block kind {spec.kind}")


class DecoderLayer(Module):
    """pre-norm mixer + residual, then pre-norm MLP (dense/MoE) + residual.

    Mamba layers in pure-SSM stacks (falcon-mamba) have no MLP (d_ff = 0).
    """

    def __init__(self, cfg: ModelConfig, spec: LayerSpec, *,
                 dtype=jnp.float32, name="layer"):
        self.cfg, self.spec = cfg, spec
        self.mixer = _make_mixer(cfg, spec, dtype)
        self.norm1 = RMSNorm(cfg.d_model, eps=cfg.norm_eps, dtype=dtype)
        d_ff = spec.d_ff or cfg.d_ff
        if spec.moe:
            assert cfg.moe is not None
            self.mlp = MoEMLP(cfg.d_model, cfg.moe, dtype=dtype,
                              constraints=cfg.moe_constraints)
        elif d_ff:
            self.mlp = DenseMLP(cfg.d_model, d_ff, dtype=dtype)
        else:
            self.mlp = None
        self.norm2 = RMSNorm(cfg.d_model, eps=cfg.norm_eps, dtype=dtype) \
            if self.mlp else None
        self.name = name

    def init(self, key):
        k1, k2 = jax.random.split(key)
        p = {"mixer": self.mixer.init(k1), "norm1": self.norm1.init(k1)}
        if self.mlp:
            p["mlp"] = self.mlp.init(k2)
            p["norm2"] = self.norm2.init(k2)
        return p

    def axes(self):
        a = {"mixer": self.mixer.axes(), "norm1": self.norm1.axes()}
        if self.mlp:
            a["mlp"] = self.mlp.axes()
            a["norm2"] = self.norm2.axes()
        return a

    def _mlp_tail(self, params, x, route="train"):
        """Residual MLP tail shared by __call__ / decode / prefill.
        ``route`` selects the MoE dispatch path (models.moe): training
        keeps the pooled capacity dispatch, serving prefill groups per
        request row, and the decode step takes the capacity-free
        gather-GEMM — the batch-invariance contract the engine relies on."""
        if self.mlp:
            h = self.norm2(params["norm2"], x)
            if isinstance(self.mlp, MoEMLP):
                m, _aux = self.mlp(params["mlp"], h, route=route)
            else:
                m = self.mlp(params["mlp"], h)
            x = x + m
        return x

    def __call__(self, params, x, positions=None):
        h = self.mixer(params["mixer"], self.norm1(params["norm1"], x),
                       positions=positions)
        return self._mlp_tail(params, x + h)

    def decode(self, params, x, cache, pos):
        h, new_cache = self.mixer.decode(
            params["mixer"], self.norm1(params["norm1"], x), cache, pos)
        return self._mlp_tail(params, x + h, route="decode"), new_cache

    def paged(self) -> bool:
        """True when this layer's cache lives in a shared page pool under
        the paged KV layout (attention mixers); O(1)-state mixers keep
        their per-slot state either way."""
        return hasattr(self.mixer, "decode_paged")

    def decode_paged(self, params, x, cache, pos, bt, active, length):
        """Slot-batched decode against paged caches.  pos/active: (B,)
        vectors; bt: (B, max_pages) shared block table.  O(1)-state
        mixers take their ordinary batched decode (they are
        position-free); attention mixers read/write the page pool."""
        h = self.norm1(params["norm1"], x)
        if self.paged():
            h, new_cache = self.mixer.decode_paged(
                params["mixer"], h, cache, pos, bt, active, length)
        else:
            h, new_cache = self.mixer.decode(params["mixer"], h, cache,
                                             pos)
        return self._mlp_tail(params, x + h, route="decode"), new_cache

    def verify_paged(self, params, x, cache, pos, bt, active, length):
        """Speculative k-token verify: score x (B, K, D) against the
        page pool WITHOUT writing it.  Returns (y, block) where block
        holds the K tokens' cache-dtype K/V for a later commit_paged.
        Only attention mixers support this (the engine restricts
        speculative targets to attention-only stacks — an O(1)-state
        mixer's carry cannot be rolled back per accepted prefix)."""
        if not self.paged():
            raise NotImplementedError(
                "speculative verify requires attention mixers; "
                f"{type(self.mixer).__name__} keeps O(1) state")
        h = self.norm1(params["norm1"], x)
        h, block = self.mixer.verify_paged(params["mixer"], h, cache,
                                           pos, bt, active, length)
        return self._mlp_tail(params, x + h, route="decode"), block

    def commit_paged(self, cache, block, pos, bt, n_commit, active,
                     length):
        """Commit the first n_commit[b] verified tokens of ``block``."""
        return self.mixer.commit_paged(cache, block, pos, bt, n_commit,
                                       active, length)

    def prefill(self, params, x, cache, pos0, length=None):
        """Consume a whole chunk (B, S, D) against the cache in one call.
        ``length`` = number of valid (non-grid-padding) leading tokens."""
        h, new_cache = self.mixer.prefill(
            params["mixer"], self.norm1(params["norm1"], x), cache, pos0,
            length=length)
        return self._mlp_tail(params, x + h, route="prefill"), new_cache

    def can_prefill(self):
        fn = getattr(self.mixer, "prefill", None)
        if fn is None:
            return False
        ok = getattr(self.mixer, "can_prefill", True)
        return ok() if callable(ok) else bool(ok)

    def cache_spec(self, batch, length, dtype=jnp.bfloat16):
        if hasattr(self.mixer, "cache_spec"):
            return self.mixer.cache_spec(batch, length, dtype)
        return {}

    def cache_axes(self):
        if hasattr(self.mixer, "cache_axes"):
            return self.mixer.cache_axes()
        return {}

    def paged_cache_spec(self, batch, length, num_pages, page_size,
                         dtype=jnp.bfloat16):
        if self.paged():
            return self.mixer.paged_cache_spec(num_pages, page_size, dtype)
        return self.cache_spec(batch, length, dtype)

    def paged_cache_axes(self):
        if self.paged():
            return self.mixer.paged_cache_axes()
        return self.cache_axes()

    def init_cache(self, batch, length, dtype=jnp.bfloat16):
        if hasattr(self.mixer, "init_cache"):
            return self.mixer.init_cache(batch, length, dtype)
        return {}


class DecoderLM(Module):
    """Embedding + (head layers, scanned pattern unit, tail layers) + head."""

    def __init__(self, cfg: ModelConfig, *, dtype=jnp.float32,
                 remat: str = "none", scan_layers: bool = True):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.scan_layers = scan_layers and cfg.n_repeats > 1
        self.embed = Embedding(cfg.vocab_padded, cfg.d_model, dtype=dtype)
        self.head_layers = [DecoderLayer(cfg, s, dtype=dtype, name=f"head{i}")
                            for i, s in enumerate(cfg.head_layers)]
        self.unit_layers = [DecoderLayer(cfg, s, dtype=dtype, name=f"unit{i}")
                            for i, s in enumerate(cfg.pattern)]
        self.tail_layers = [DecoderLayer(cfg, s, dtype=dtype, name=f"tail{i}")
                            for i, s in enumerate(cfg.tail_layers)]
        self.final_norm = RMSNorm(cfg.d_model, eps=cfg.norm_eps, dtype=dtype)
        self.name = cfg.name

    # ------------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = {"embed": self.embed.init(ks[0]),
             "final_norm": self.final_norm.init(ks[0])}
        if not cfg.tie_embeddings:
            p["lm_head"] = Embedding(cfg.vocab_padded, cfg.d_model,
                                     dtype=self.dtype).init(ks[3])
        for i, l in enumerate(self.head_layers):
            p[l.name] = l.init(jax.random.fold_in(ks[1], i))
        for i, l in enumerate(self.tail_layers):
            p[l.name] = l.init(jax.random.fold_in(ks[1], 1000 + i))
        if self.scan_layers:
            for i, l in enumerate(self.unit_layers):
                p[l.name] = stacked_init(
                    l, cfg.n_repeats, jax.random.fold_in(ks[2], i))
        else:
            for r in range(cfg.n_repeats):
                for i, l in enumerate(self.unit_layers):
                    p[f"{l.name}_r{r}"] = l.init(
                        jax.random.fold_in(ks[2], r * 131 + i))
        return p

    def axes(self):
        cfg = self.cfg
        a = {"embed": self.embed.axes(),
             "final_norm": self.final_norm.axes()}
        if not cfg.tie_embeddings:
            a["lm_head"] = self.embed.axes()
        for l in self.head_layers + self.tail_layers:
            a[l.name] = l.axes()
        if self.scan_layers:
            for l in self.unit_layers:
                a[l.name] = stacked_axes(l)
        else:
            for r in range(cfg.n_repeats):
                for l in self.unit_layers:
                    a[f"{l.name}_r{r}"] = l.axes()
        return a

    # ------------------------------------------------------------------
    def _run_unit_scanned(self, params, x, positions):
        """lax.scan over pattern repeats; HLO is O(|unit|)."""
        def body(carry, unit_params):
            h = carry
            for i, l in enumerate(self.unit_layers):
                h = l(unit_params[l.name], h, positions=positions)
            return h, None

        fn = body
        if self.remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if self.remat == "full" else
                      jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            fn = jax.checkpoint(body, policy=policy, static_argnums=())

        stacked = {l.name: params[l.name] for l in self.unit_layers}
        x, _ = jax.lax.scan(lambda c, p: fn(c, p), x, stacked)
        return x

    def backbone(self, params, x, positions=None):
        for l in self.head_layers:
            x = l(params[l.name], x, positions=positions)
        if self.scan_layers:
            x = self._run_unit_scanned(params, x, positions)
        else:
            for r in range(self.cfg.n_repeats):
                for l in self.unit_layers:
                    x = l(params[f"{l.name}_r{r}"], x, positions=positions)
        for l in self.tail_layers:
            x = l(params[l.name], x, positions=positions)
        return self.final_norm(params["final_norm"], x)

    def __call__(self, params, tokens=None, positions=None, embeds=None):
        """tokens: (B, S) int32, or embeds: (B, S, D) (VLM/audio stub path);
        both may be given (embeds prepended). Returns logits (B, S, V_pad)."""
        cfg = self.cfg
        parts = []
        if embeds is not None:
            parts.append(embeds.astype(self.compute_dtype()))
        if tokens is not None:
            parts.append(self.embed(params["embed"], tokens))
        x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
        x = x.astype(self.compute_dtype())
        x = self.backbone(params, x, positions=positions)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        return self.embed.attend(head, x)

    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == jnp.float32 else self.dtype

    def loss(self, params, batch):
        """batch: {"tokens": (B,S), "labels": (B,S), optional "embeds"}.
        Labels −1 = masked. Returns (scalar loss, metrics)."""
        logits = self(params, batch.get("tokens"),
                      embeds=batch.get("embeds"))
        labels = batch["labels"]
        S = labels.shape[1]
        logits = logits[:, -S:, :]  # embeds prefix (VLM) produces no loss
        logits = logits.astype(jnp.float32)
        mask = labels >= 0
        lab = jnp.clip(labels, 0)
        logz = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, lab[..., None], -1)[..., 0]
        nll = (logz - ll) * mask
        loss = nll.sum() / jnp.clip(mask.sum(), 1)
        return loss, {"loss": loss, "tokens": mask.sum()}

    # ------------------------------------------------------------------
    # Decode
    # ------------------------------------------------------------------
    def _all_layers(self):
        seq = [(l.name, l, "plain") for l in self.head_layers]
        if self.scan_layers:
            seq += [(l.name, l, "scanned") for l in self.unit_layers]
        else:
            for r in range(self.cfg.n_repeats):
                seq += [(f"{l.name}_r{r}", l, "plain")
                        for l in self.unit_layers]
        seq += [(l.name, l, "plain") for l in self.tail_layers]
        return seq

    def cache_spec(self, batch, length, dtype=jnp.bfloat16):
        spec = {}
        for name, l, mode in self._all_layers():
            s = l.cache_spec(batch, length, dtype)
            if mode == "scanned":
                s = jax.tree_util.tree_map(
                    lambda t: jax.ShapeDtypeStruct(
                        (self.cfg.n_repeats,) + t.shape, t.dtype), s)
            spec[name] = s
        return spec

    def cache_axes(self):
        axes = {}
        for name, l, mode in self._all_layers():
            a = l.cache_axes()
            if mode == "scanned":
                a = jax.tree_util.tree_map(
                    lambda t: ("layers",) + tuple(t), a,
                    is_leaf=lambda x: isinstance(x, tuple))
            axes[name] = a
        return axes

    def init_cache(self, batch, length, dtype=jnp.bfloat16):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, length, dtype))

    def paged_cache_spec(self, batch, length, num_pages, page_size,
                         dtype=jnp.bfloat16):
        """Decode-cache spec under the PAGED KV layout: attention layers
        hold shared page pools (num_pages, page_size, ...) — no slot
        axis, pages are handed out by the serving engine's allocator —
        while O(1)-state mixers keep their per-slot leaves exactly as in
        the dense layout.  Scanned pattern units stack the layer-repeat
        axis first, as everywhere else."""
        spec = {}
        for name, l, mode in self._all_layers():
            s = l.paged_cache_spec(batch, length, num_pages, page_size,
                                   dtype)
            if mode == "scanned":
                s = jax.tree_util.tree_map(
                    lambda t: jax.ShapeDtypeStruct(
                        (self.cfg.n_repeats,) + t.shape, t.dtype), s)
            spec[name] = s
        return spec

    def paged_cache_axes(self):
        axes = {}
        for name, l, mode in self._all_layers():
            a = l.paged_cache_axes()
            if mode == "scanned":
                a = jax.tree_util.tree_map(
                    lambda t: ("layers",) + tuple(t), a,
                    is_leaf=lambda x: isinstance(x, tuple))
            axes[name] = a
        return axes

    def paged_layer_names(self):
        """Names of layers whose cache lives in the page pool."""
        return {name for name, l, _m in self._all_layers() if l.paged()}

    def decode_step_paged(self, params, tokens, cache, pos, bt, active,
                          length):
        """One slot-batched decode step under the paged KV layout.

        tokens: (B, 1); pos/active: (B,) per-slot vectors; bt:
        (B, max_pages) block table shared by every attention layer (each
        layer indexes its OWN pool with the same page ids); ``length`` =
        the engine max_len.  Unlike ``decode_step`` (scalar pos, vmapped
        over slots by the serving adapter), this runs the whole slot
        batch natively — the page pools are shared state that a per-slot
        vmap could not thread.  Attention writes from inactive slots are
        dropped in-layer (out-of-bounds page); the caller masks the
        per-slot leaves."""
        x = self.embed(params["embed"], tokens).astype(self.compute_dtype())
        new_cache = dict(cache)
        for l in self.head_layers:
            x, new_cache[l.name] = l.decode_paged(
                params[l.name], x, cache[l.name], pos, bt, active, length)
        if self.scan_layers:
            def body(carry, rep):
                h = carry
                rep_params, rep_cache = rep
                out_cache = {}
                for l in self.unit_layers:
                    h, out_cache[l.name] = l.decode_paged(
                        rep_params[l.name], h, rep_cache[l.name], pos, bt,
                        active, length)
                return h, out_cache

            stacked_p = {l.name: params[l.name] for l in self.unit_layers}
            stacked_c = {l.name: cache[l.name] for l in self.unit_layers}
            x, updated = jax.lax.scan(body, x, (stacked_p, stacked_c))
            for l in self.unit_layers:
                new_cache[l.name] = updated[l.name]
        else:
            for r in range(self.cfg.n_repeats):
                for l in self.unit_layers:
                    nm = f"{l.name}_r{r}"
                    x, new_cache[nm] = l.decode_paged(
                        params[nm], x, cache[nm], pos, bt, active, length)
        for l in self.tail_layers:
            x, new_cache[l.name] = l.decode_paged(
                params[l.name], x, cache[l.name], pos, bt, active, length)
        x = self.final_norm(params["final_norm"], x)
        head = params["embed"] if self.cfg.tie_embeddings \
            else params["lm_head"]
        return self.embed.attend(head, x), new_cache

    def verify_step_paged(self, params, tokens, cache, pos, bt, active,
                          length):
        """Score K speculative tokens per slot against the paged caches
        WITHOUT writing them.  tokens: (B, K) — the current token plus
        K-1 drafts at positions ``pos .. pos+K-1``.  Returns
        ``(logits (B, K, V_pad), blocks)``: row j of the logits is the
        target's next-token distribution for stream position
        ``pos+1+j``, and ``blocks`` maps layer name -> the cache-dtype
        K/V block of the K tokens (scanned units stack the repeat axis
        first), ready for :meth:`commit_step_paged` once the verifier
        decides how many to keep.  The pool is untouched until then —
        rejection costs nothing."""
        x = self.embed(params["embed"], tokens).astype(self.compute_dtype())
        blocks = {}
        for l in self.head_layers:
            x, blocks[l.name] = l.verify_paged(
                params[l.name], x, cache[l.name], pos, bt, active, length)
        if self.scan_layers:
            def body(carry, rep):
                h = carry
                rep_params, rep_cache = rep
                out = {}
                for l in self.unit_layers:
                    h, out[l.name] = l.verify_paged(
                        rep_params[l.name], h, rep_cache[l.name], pos, bt,
                        active, length)
                return h, out

            stacked_p = {l.name: params[l.name] for l in self.unit_layers}
            stacked_c = {l.name: cache[l.name] for l in self.unit_layers}
            x, stacked_b = jax.lax.scan(body, x, (stacked_p, stacked_c))
            for l in self.unit_layers:
                blocks[l.name] = stacked_b[l.name]
        else:
            for r in range(self.cfg.n_repeats):
                for l in self.unit_layers:
                    nm = f"{l.name}_r{r}"
                    x, blocks[nm] = l.verify_paged(
                        params[nm], x, cache[nm], pos, bt, active, length)
        for l in self.tail_layers:
            x, blocks[l.name] = l.verify_paged(
                params[l.name], x, cache[l.name], pos, bt, active, length)
        x = self.final_norm(params["final_norm"], x)
        head = params["embed"] if self.cfg.tie_embeddings \
            else params["lm_head"]
        return self.embed.attend(head, x), blocks

    def commit_step_paged(self, cache, blocks, pos, bt, n_commit, active,
                          length):
        """Commit the first ``n_commit[b]`` verified tokens of every
        layer's block (from :meth:`verify_step_paged`) into the page
        pools.  Scanned units commit per repeat under vmap — the commit
        is a pure scatter, so stacking is free."""
        new_cache = dict(cache)
        for name, l, mode in self._all_layers():
            if mode == "scanned":
                new_cache[name] = jax.vmap(
                    lambda c, b, _l=l: _l.commit_paged(
                        c, b, pos, bt, n_commit, active, length)
                )(cache[name], blocks[name])
            else:
                new_cache[name] = l.commit_paged(
                    cache[name], blocks[name], pos, bt, n_commit, active,
                    length)
        return new_cache

    def supports_prefill(self) -> bool:
        """True when every layer can consume whole chunks against its cache
        (the serving engine falls back to a scanned per-token prefill
        otherwise — e.g. sliding-window or MLA attention stacks)."""
        return all(l.can_prefill() for _, l, _ in self._all_layers())

    def prefill(self, params, tokens, cache, pos0, length=None):
        """Consume a prompt chunk. tokens: (B, S); pos0: scalar int (first
        absolute position of the chunk); length: number of valid leading
        tokens (None = all S; the rest are grid padding that every layer
        masks out of its cache update). Returns (logits at the last VALID
        token (B, 1, V), new cache) — the cache carry feeds decode_step
        (or the next chunk)."""
        x = self.embed(params["embed"], tokens).astype(self.compute_dtype())
        new_cache = dict(cache)
        for l in self.head_layers:
            x, new_cache[l.name] = l.prefill(params[l.name], x,
                                             cache[l.name], pos0,
                                             length=length)
        if self.scan_layers:
            def body(carry, rep):
                h = carry
                rep_params, rep_cache = rep
                out_cache = {}
                for l in self.unit_layers:
                    h, out_cache[l.name] = l.prefill(
                        rep_params[l.name], h, rep_cache[l.name], pos0,
                        length=length)
                return h, out_cache

            stacked_p = {l.name: params[l.name] for l in self.unit_layers}
            stacked_c = {l.name: cache[l.name] for l in self.unit_layers}
            x, updated = jax.lax.scan(body, x, (stacked_p, stacked_c))
            for l in self.unit_layers:
                new_cache[l.name] = updated[l.name]
        else:
            for r in range(self.cfg.n_repeats):
                for l in self.unit_layers:
                    nm = f"{l.name}_r{r}"
                    x, new_cache[nm] = l.prefill(params[nm], x,
                                                 cache[nm], pos0,
                                                 length=length)
        for l in self.tail_layers:
            x, new_cache[l.name] = l.prefill(params[l.name], x,
                                             cache[l.name], pos0,
                                             length=length)
        if length is None:
            x = x[:, -1:, :]
        else:
            x = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
        x = self.final_norm(params["final_norm"], x)
        head = params["embed"] if self.cfg.tie_embeddings else params["lm_head"]
        return self.embed.attend(head, x), new_cache

    def decode_step(self, params, tokens, cache, pos):
        """tokens: (B, 1); pos: scalar int. Returns (logits, new cache)."""
        cfg = self.cfg
        x = self.embed(params["embed"], tokens).astype(self.compute_dtype())
        new_cache = dict(cache)
        # head layers
        for l in self.head_layers:
            x, new_cache[l.name] = l.decode(params[l.name], x,
                                            cache[l.name], pos)
        # scanned unit: lax.scan over repeats, cache as scanned xs/ys
        if self.scan_layers:
            def body(carry, rep):
                h = carry
                rep_params, rep_cache = rep
                out_cache = {}
                for l in self.unit_layers:
                    h, out_cache[l.name] = l.decode(
                        rep_params[l.name], h, rep_cache[l.name], pos)
                return h, out_cache

            stacked_p = {l.name: params[l.name] for l in self.unit_layers}
            stacked_c = {l.name: cache[l.name] for l in self.unit_layers}
            x, updated = jax.lax.scan(body, x, (stacked_p, stacked_c))
            for l in self.unit_layers:
                new_cache[l.name] = updated[l.name]
        else:
            for r in range(cfg.n_repeats):
                for l in self.unit_layers:
                    nm = f"{l.name}_r{r}"
                    x, new_cache[nm] = l.decode(params[nm], x, cache[nm], pos)
        for l in self.tail_layers:
            x, new_cache[l.name] = l.decode(params[l.name], x,
                                            cache[l.name], pos)
        x = self.final_norm(params["final_norm"], x)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = self.embed.attend(head, x)
        return logits, new_cache
