"""Mamba-1 selective SSM block (falcon-mamba / jamba layers).

The SSM recurrence  h_t = Ā_t ⊙ h_{t-1} + B̄_t x_t  is, per (channel, state)
pair, the same diagonal linear recurrence as the paper's minGRU state update
— it is served by the same scan engine (repro.kernels.linear_scan), with the
channel axis flattened to d_inner·d_state (DESIGN.md §4: the paper's scan
technique applies directly to this architecture family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MambaConfig
from repro.kernels.linear_scan import ops as scan_ops
from repro.models.module import Module, fan_in_init


class MambaBlock(Module):
    def __init__(self, cfg: ModelConfig, *, scan_backend="xla",
                 dtype=jnp.float32, name="mamba"):
        assert cfg.mamba is not None
        self.cfg = cfg
        self.mc: MambaConfig = cfg.mamba
        self.d_inner = self.mc.d_inner(cfg.d_model)
        self.scan_backend = scan_backend
        self.dtype, self.name = dtype, name

    def init(self, key):
        c, mc, di = self.cfg, self.mc, self.d_inner
        d = c.d_model
        ks = jax.random.split(key, 6)
        dt_rank = max(1, d // 16)
        # S4D-real initialization for A
        a_init = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=self.dtype),
                          (di, 1))
        return {
            "w_in": fan_in_init(ks[0], (d, 2 * di), self.dtype),
            "conv": 0.1 * jax.random.normal(ks[1], (mc.d_conv, di), self.dtype),
            "conv_b": jnp.zeros((di,), self.dtype),
            "w_bcdt": fan_in_init(ks[2], (di, 2 * mc.d_state + dt_rank),
                                  self.dtype),
            "w_dt": fan_in_init(ks[3], (dt_rank, di), self.dtype),
            "dt_bias": jnp.log(jnp.exp(
                jnp.exp(jax.random.uniform(ks[4], (di,), self.dtype)
                        * 2.0 - 6.0)) - 1.0 + 1e-6),  # softplus-inv of dt
            "a_log": jnp.log(a_init),
            "d_skip": jnp.ones((di,), self.dtype),
            "w_out": fan_in_init(ks[5], (di, d), self.dtype),
        }

    def axes(self):
        return {"w_in": ("embed", "d_inner"), "conv": (None, "d_inner"),
                "conv_b": ("d_inner",),
                "w_bcdt": ("d_inner", None), "w_dt": (None, "d_inner"),
                "dt_bias": ("d_inner",), "a_log": ("d_inner", None),
                "d_skip": ("d_inner",), "w_out": ("d_inner", "embed")}

    def _conv(self, params, x):
        """Causal depthwise conv over time. x: (B, T, di)."""
        mc = self.mc
        pad = jnp.pad(x, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + x.shape[1], :] * params["conv"][i]
                  for i in range(mc.d_conv))
        return out + params["conv_b"]

    def _ssm_raw(self, params, xc):
        """Raw SSM quantities (dt, B, C, A). xc: (B, T, di) post-conv+silu."""
        n = self.mc.d_state
        bcdt = xc @ params["w_bcdt"].astype(xc.dtype)
        Bm, Cm, dt_in = jnp.split(bcdt, [n, 2 * n], axis=-1)
        dt = jax.nn.softplus(dt_in @ params["w_dt"].astype(xc.dtype)
                             + params["dt_bias"].astype(xc.dtype))  # (B,T,di)
        A = -jnp.exp(params["a_log"].astype(jnp.float32))    # (di, n)
        return dt, Bm, Cm, A

    def _ssm_terms(self, params, xc):
        """Discretized terms (materializing path)."""
        dt, Bm, Cm, A = self._ssm_raw(params, xc)
        a_bar = jnp.exp(dt[..., None].astype(jnp.float32) * A)  # (B,T,di,n)
        b_bar = dt[..., None] * Bm[:, :, None, :] * xc[..., None]
        return a_bar, b_bar, Cm

    def __call__(self, params, x, positions=None):
        """x: (B, T, D) -> (B, T, D)."""
        del positions
        B = x.shape[0]
        impl = self.cfg.ssm_impl
        if impl == "xla":
            # full-sequence eval == prefill from a blank carry; keeping one
            # implementation keeps training and serving on the same math
            y, _ = self.prefill(params, x, self.init_cache(B, dtype=x.dtype),
                                0)
            return y
        xz = x @ params["w_in"].astype(x.dtype)
        xr, z = jnp.split(xz, 2, axis=-1)
        xc = jax.nn.silu(self._conv(params, xr))
        if impl == "fused":
            from repro.kernels.fused_ssm.ops import selective_scan
            dt, Bm, Cm, A = self._ssm_raw(params, xc)
            y = selective_scan(dt, xc, Bm, Cm, A, "pallas")
        else:
            assert impl == "stub", impl
            # dry-run stand-in: O(B·T·di) with grads to dt/xc/B/C/A; the
            # fused kernel's cost is added analytically by launch.dryrun
            dt, Bm, Cm, A = self._ssm_raw(params, xc)
            y = ((dt * xc) * Bm.sum(-1, keepdims=True)
                 + xc * Cm.sum(-1, keepdims=True)
                 + xc * A.sum(1)[None, None, :].astype(x.dtype))
        y = y + params["d_skip"].astype(x.dtype) * xc
        y = y * jax.nn.silu(z)
        return (y @ params["w_out"].astype(x.dtype)).astype(x.dtype)

    # --- prefill: whole chunk against the O(1) carry ---
    can_prefill = True

    def prefill(self, params, x, cache, pos0, length=None):
        """x: (B, S, D); cache {"ssm": (B,di,n), "conv": (B,d_conv-1,di)}.
        One linear_scan over the chunk, conv warmed from the cached tail.
        ``length`` selects the carries at the last VALID token when the
        chunk tail is grid padding (scan and conv are causal, so padded
        inputs never contaminate the selected carry)."""
        del pos0
        B, T, _ = x.shape
        mc, di, n = self.mc, self.d_inner, self.mc.d_state
        xz = x @ params["w_in"].astype(x.dtype)
        xr, z = jnp.split(xz, 2, axis=-1)
        hist = jnp.concatenate([cache["conv"].astype(x.dtype), xr], axis=1)
        # conv weights stay f32 (promoting xc) — matches the historical
        # full-sequence path bit-for-bit under bf16 compute
        out = sum(hist[:, i:i + T, :] * params["conv"][i]
                  for i in range(mc.d_conv))
        xc = jax.nn.silu(out + params["conv_b"])
        a_bar, b_bar, Cm = self._ssm_terms(params, xc)
        h = scan_ops.linear_scan(
            a_bar.reshape(B, T, di * n).astype(x.dtype),
            b_bar.reshape(B, T, di * n).astype(x.dtype),
            cache["ssm"].reshape(B, di * n).astype(x.dtype),
            self.scan_backend)
        y = jnp.einsum("btdn,btn->btd", h.reshape(B, T, di, n), Cm)
        y = y + params["d_skip"].astype(x.dtype) * xc
        y = y * jax.nn.silu(z)
        y = (y @ params["w_out"].astype(x.dtype)).astype(x.dtype)
        if length is None:
            # hist is (B, T + d_conv - 1, di); keep the LAST d_conv-1 rows
            # (start index T, so d_conv == 1 yields an empty slice, not -0)
            ssm_c, conv_c = h[:, -1], hist[:, T:, :]
        else:
            # carries at the last valid token: ssm state after position
            # length-1, conv tail = the d_conv-1 inputs before `length`
            # (hist rows [length, length + d_conv - 1))
            ssm_c = jax.lax.dynamic_index_in_dim(h, length - 1, axis=1,
                                                 keepdims=False)
            conv_c = jax.lax.dynamic_slice_in_dim(hist, length,
                                                  mc.d_conv - 1, axis=1)
        new_cache = {
            "ssm": ssm_c.reshape(B, di, n).astype(cache["ssm"].dtype),
            "conv": conv_c.astype(cache["conv"].dtype),
        }
        return y, new_cache

    # --- decode: O(1) state ---
    def cache_spec(self, batch, length, dtype=jnp.float32):
        del length
        mc, di = self.mc, self.d_inner
        return {
            "ssm": jax.ShapeDtypeStruct((batch, di, mc.d_state), dtype),
            "conv": jax.ShapeDtypeStruct((batch, mc.d_conv - 1, di), dtype),
        }

    def cache_axes(self):
        return {"ssm": ("batch", "d_inner", "state"),
                "conv": ("batch", "conv", "d_inner")}

    def init_cache(self, batch, length=0, dtype=jnp.float32):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, length, dtype))

    def decode(self, params, x, cache, pos):
        """x: (B, 1, D) -> (B, 1, D), updated cache."""
        del pos
        B = x.shape[0]
        mc, di, n = self.mc, self.d_inner, self.mc.d_state
        xz = x[:, 0] @ params["w_in"].astype(x.dtype)
        xr, z = jnp.split(xz, 2, axis=-1)
        # conv over (cached d_conv-1 inputs, current)
        window = jnp.concatenate([cache["conv"].astype(x.dtype),
                                  xr[:, None, :]], axis=1)   # (B, d_conv, di)
        xc = jnp.einsum("bkd,kd->bd", window, params["conv"].astype(x.dtype))
        xc = jax.nn.silu(xc + params["conv_b"])
        a_bar, b_bar, Cm = self._ssm_terms(params, xc[:, None, :])
        h = (a_bar[:, 0] * cache["ssm"].astype(a_bar.dtype)
             + b_bar[:, 0])                                   # (B, di, n)
        y = jnp.einsum("bdn,bn->bd", h.astype(x.dtype), Cm[:, 0])
        y = y + params["d_skip"].astype(x.dtype) * xc
        y = y * jax.nn.silu(z)
        y = (y @ params["w_out"].astype(x.dtype)).astype(x.dtype)[:, None, :]
        new_cache = {"ssm": h.astype(cache["ssm"].dtype),
                     "conv": window[:, 1:].astype(cache["conv"].dtype)}
        return y, new_cache
