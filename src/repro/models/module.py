"""Minimal functional module system (flax-free).

Modules are stateless Python objects holding configuration.  Parameters are
plain nested dicts of arrays; every module exposes:

  * ``init(key) -> params``      — build a param pytree (jit/eval_shape safe)
  * ``axes() -> axes_pytree``    — same structure, leaves are tuples of
                                   *logical* axis names (or None) used by the
                                   sharding layer (repro.parallel.sharding)
  * ``__call__(params, *a, **k)``— the forward function

Design notes
------------
* ``init`` is pure (jax.random only) so the full-size configs can be
  materialized abstractly via ``jax.eval_shape`` for the multi-pod dry-run —
  no host allocation ever happens for the 671B-parameter configs.
* Logical axis names ("embed", "heads", "mlp", "experts", "vocab", ...) are
  mapped to physical mesh axes by rule tables; this mirrors the
  MaxText/Flax ``logical_axis_rules`` pattern without the dependency.
* Layer stacks are built with ``stacked_init`` (vmapped init over a leading
  "layers" axis) and consumed with ``jax.lax.scan`` so HLO size stays O(1)
  in depth — essential for compiling 61–88 layer configs in the dry-run.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Any
Axes = Any


class Module:
    """Base class; subclasses set config in __init__ and implement the API."""

    def init(self, key: jax.Array) -> Params:
        raise NotImplementedError

    def axes(self) -> Axes:
        raise NotImplementedError

    def __call__(self, params: Params, *args, **kwargs):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _trunc_normal(key, shape, dtype, stddev):
    # 2-sigma truncated normal, the standard transformer init.
    u = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (u * stddev).astype(dtype)


def fan_in_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return _trunc_normal(key, shape, dtype, stddev=1.0 / np.sqrt(max(fan_in, 1)))


def embed_init(key, shape, dtype):
    return _trunc_normal(key, shape, dtype, stddev=1.0)


# ---------------------------------------------------------------------------
# Core layers
# ---------------------------------------------------------------------------

class Dense(Module):
    """y = x @ W (+ b).  ``kernel_axes`` are logical names per kernel dim."""

    def __init__(self, in_dim, out_dim, *, use_bias=False,
                 kernel_axes=("embed", "mlp"), dtype=jnp.float32,
                 init=fan_in_init, name="dense"):
        self.in_dim, self.out_dim = int(in_dim), int(out_dim)
        self.use_bias = use_bias
        self.kernel_axes = tuple(kernel_axes)
        self.dtype = dtype
        self._init = init
        self.name = name

    def init(self, key):
        p = {"kernel": self._init(key, (self.in_dim, self.out_dim), self.dtype)}
        if self.use_bias:
            p["bias"] = jnp.zeros((self.out_dim,), self.dtype)
        return p

    def axes(self):
        a = {"kernel": self.kernel_axes}
        if self.use_bias:
            a["bias"] = (self.kernel_axes[-1],)
        return a

    def __call__(self, params, x):
        w = params["kernel"].astype(x.dtype)
        y = x @ w
        if self.use_bias:
            y = y + params["bias"].astype(x.dtype)
        return y


class Embedding(Module):
    def __init__(self, vocab, dim, *, dtype=jnp.float32, name="embed"):
        self.vocab, self.dim = int(vocab), int(dim)
        self.dtype = dtype
        self.name = name

    def init(self, key):
        return {"table": embed_init(key, (self.vocab, self.dim), self.dtype)}

    def axes(self):
        return {"table": ("vocab", "embed")}

    def __call__(self, params, ids):
        return params["table"].astype(jnp.bfloat16 if self.dtype == jnp.float32 else self.dtype)[ids]

    def attend(self, params, x):
        """Logits via tied embedding: (x @ table.T) / sqrt(dim) — the scale
        keeps initial logits O(1) under a stddev-1 table (Gemma-style)."""
        return (x @ params["table"].astype(x.dtype).T) / np.sqrt(self.dim)


class RMSNorm(Module):
    def __init__(self, dim, *, eps=1e-6, dtype=jnp.float32, name="norm"):
        self.dim, self.eps, self.dtype, self.name = int(dim), eps, dtype, name

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), self.dtype)}

    def axes(self):
        return {"scale": ("embed",)}

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


class LayerNorm(Module):
    def __init__(self, dim, *, eps=1e-5, dtype=jnp.float32, name="ln"):
        self.dim, self.eps, self.dtype, self.name = int(dim), eps, dtype, name

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), self.dtype),
                "bias": jnp.zeros((self.dim,), self.dtype)}

    def axes(self):
        return {"scale": ("embed",), "bias": ("embed",)}

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Layer stacking (scan-over-layers)
# ---------------------------------------------------------------------------

def stacked_init(module: Module, n_layers: int, key: jax.Array) -> Params:
    """vmap a module's init over a leading 'layers' axis."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(module.init)(keys)


def stacked_axes(module: Module, extra_leading: str = "layers") -> Axes:
    """Prepend the 'layers' logical axis to every leaf of module.axes()."""
    def add(leaf):
        if leaf is None:
            return (extra_leading,)
        return (extra_leading,) + tuple(leaf)

    return jax.tree_util.tree_map(
        add, module.axes(), is_leaf=lambda x: x is None or isinstance(x, tuple))


def scan_layers(body: Callable, stacked_params: Params, carry, *,
                unroll: int = 1, remat_policy: str | None = "none"):
    """Run ``carry = body(layer_params, carry)`` over the leading layer axis
    with jax.lax.scan.  ``remat_policy`` in {none, full, dots_saveable}."""
    fn = body
    if remat_policy and remat_policy != "none":
        if remat_policy == "full":
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        elif remat_policy == "dots_saveable":
            fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            raise ValueError(f"unknown remat policy {remat_policy}")

    def step(c, lp):
        return fn(lp, c), None

    carry, _ = jax.lax.scan(step, carry, stacked_params, unroll=unroll)
    return carry


def select_layer(stacked_params: Params, i):
    """Dynamic-index one layer's params out of a stacked pytree."""
    return jax.tree_util.tree_map(lambda p: jax.lax.dynamic_index_in_dim(
        p, i, axis=0, keepdims=False), stacked_params)
