"""Mixture-of-Experts MLP with sort-based (MegaBlocks-style) dispatch.

Design notes:
  * Dispatch is gather/scatter based — argsort tokens by assigned expert,
    scatter into an (E, C, D) capacity buffer, run a grouped expert GEMM,
    gather-combine.  Unlike the one-hot einsum dispatch (GShard), sorting
    adds **zero phantom FLOPs** to the compiled HLO, so the roofline's
    MODEL_FLOPS / HLO_FLOPs ratio stays honest.
  * Expert weights are stacked (E, D, F) and sharded over the "experts"
    logical axis (mapped to the mesh "model" axis = expert parallelism);
    the scatter from token-sharded to expert-sharded buffers lowers to an
    all-to-all under SPMD — exactly a production EP dispatch.
  * Capacity-factor token dropping (standard at scale); dropped tokens pass
    through the residual stream untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.module import Module, fan_in_init


class DenseMLP(Module):
    """SwiGLU MLP: down( silu(gate(x)) * up(x) )."""

    def __init__(self, d_model, d_ff, *, dtype=jnp.float32, name="mlp"):
        self.d_model, self.d_ff = int(d_model), int(d_ff)
        self.dtype, self.name = dtype, name

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        d, f = self.d_model, self.d_ff
        return {"w_gate": fan_in_init(k1, (d, f), self.dtype),
                "w_up": fan_in_init(k2, (d, f), self.dtype),
                "w_down": fan_in_init(k3, (f, d), self.dtype)}

    def axes(self):
        return {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                "w_down": ("mlp", "embed")}

    def __call__(self, params, x):
        g = x @ params["w_gate"].astype(x.dtype)
        u = x @ params["w_up"].astype(x.dtype)
        return (jax.nn.silu(g) * u) @ params["w_down"].astype(x.dtype)


def _constrain(x, *spec):
    """Best-effort sharding constraint (needs a mesh context at trace time;
    silently skipped outside one, e.g. in single-device smoke tests)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x


class MoEMLP(Module):
    """Top-k routed experts (+ optional shared experts)."""

    def __init__(self, d_model, moe: MoEConfig, *, dtype=jnp.float32,
                 name="moe", constraints=False):
        self.d_model = int(d_model)
        self.moe = moe
        self.constraints = constraints
        self.dtype, self.name = dtype, name
        self.shared = (DenseMLP(d_model, moe.d_ff_expert * moe.n_shared,
                                dtype=dtype, name="shared")
                       if moe.n_shared else None)

    def init(self, key):
        e = self.moe
        d, f = self.d_model, e.d_ff_expert
        ks = jax.random.split(key, 5)
        p = {
            "router": fan_in_init(ks[0], (d, e.n_experts), self.dtype),
            "w_gate": jax.vmap(lambda k: fan_in_init(k, (d, f), self.dtype))(
                jax.random.split(ks[1], e.n_experts)),
            "w_up": jax.vmap(lambda k: fan_in_init(k, (d, f), self.dtype))(
                jax.random.split(ks[2], e.n_experts)),
            "w_down": jax.vmap(lambda k: fan_in_init(k, (f, d), self.dtype))(
                jax.random.split(ks[3], e.n_experts)),
        }
        if self.shared:
            p["shared"] = self.shared.init(ks[4])
        return p

    def axes(self):
        a = {"router": ("embed", None),
             "w_gate": ("experts", "embed", "mlp"),
             "w_up": ("experts", "embed", "mlp"),
             "w_down": ("experts", "mlp", "embed")}
        if self.shared:
            a["shared"] = self.shared.axes()
        return a

    @staticmethod
    def capacity(NL, e):
        """Per-group expert capacity (bounded by the assignment count)."""
        return int(min(NL * e.top_k,
                       max(1, round(NL * e.top_k / e.n_experts
                                    * e.capacity_factor))))

    def _dispatch_group(self, params, xt, dtype, C):
        """Sort-based dispatch for ONE token group. xt: (NL, D)."""
        e = self.moe
        NL, D = xt.shape
        logits = (xt @ params["router"].astype(dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)                       # (NL, E)
        gate_vals, expert_ids = jax.lax.top_k(probs, e.top_k)    # (NL, k)
        gate_vals = gate_vals / jnp.clip(
            gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

        flat_e = expert_ids.reshape(-1)                          # (NL*k,)
        order = jnp.argsort(flat_e)                              # stable
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e.n_experts)
        starts = jnp.cumsum(counts) - counts                     # exclusive
        pos_in_e = jnp.arange(NL * e.top_k) - starts[sorted_e]
        token_of = order // e.top_k
        valid = pos_in_e < C
        dest = jnp.where(valid, sorted_e * C + pos_in_e, e.n_experts * C)

        buf = jnp.zeros((e.n_experts * C, D), dtype)
        buf = buf.at[dest].set(xt[token_of], mode="drop")
        xe = buf.reshape(e.n_experts, C, D)
        meta = dict(dest=dest, valid=valid, token_of=token_of, order=order,
                    gate_vals=gate_vals, probs=probs, flat_e=flat_e)
        return xe, meta

    def _combine_group(self, ye, meta, NL, D, dtype, C):
        e = self.moe
        yflat = ye.reshape(e.n_experts * C, D)
        contrib = jnp.where(
            meta["valid"][:, None],
            yflat[jnp.clip(meta["dest"], 0, e.n_experts * C - 1)], 0.0)
        gates = meta["gate_vals"].reshape(-1)[meta["order"]][:, None]
        contrib = contrib * gates.astype(dtype)
        return jnp.zeros((NL, D), dtype).at[meta["token_of"]].add(contrib)

    def __call__(self, params, x):
        """x: (B, S, D) -> (B, S, D); also returns aux losses dict.

        With ``moe.groups`` = the DP degree (and groups along the batch
        dim), the scatter/gather never cross data shards — only the expert
        GEMM's operands move over the "model" axis and the combine's
        partial sums are all-reduced (§Perf cell B).
        """
        e = self.moe
        B, S, D = x.shape
        G = e.groups if B % max(e.groups, 1) == 0 else 1
        xt = x.reshape(G, B * S // G, D)
        if self.constraints:
            xt = _constrain(xt, ("pod", "data"), None, None)

        C = self.capacity(B * S // G, e)
        xe, meta = jax.vmap(
            lambda t: self._dispatch_group(params, t, x.dtype, C))(xt)
        if self.constraints:
            xe = _constrain(xe, ("pod", "data"), "model", None, None)

        # ---- grouped expert GEMM (E-sharded) ----
        g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype))
        ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u,
                        params["w_down"].astype(x.dtype))
        if self.constraints:
            ye = _constrain(ye, ("pod", "data"), "model", None, None)

        NL = B * S // G
        out = jax.vmap(
            lambda y, m: self._combine_group(y, m, NL, D, x.dtype, C)
        )(ye, meta)
        if self.constraints:
            out = _constrain(out, ("pod", "data"), None, None)
        out = out.reshape(B, S, D)

        if self.shared:
            out = out + self.shared(params["shared"], x)

        # load-balancing aux loss (Switch-style)
        me = meta["probs"].mean((0, 1))                          # (E,)
        ce = jnp.bincount(meta["flat_e"].reshape(-1),
                          length=e.n_experts) / meta["flat_e"].size
        aux = e.n_experts * jnp.sum(me * ce)
        return out, {"aux_loss": aux,
                     "dropped_frac": 1.0 - meta["valid"].mean()}
