"""Mixture-of-Experts MLP with sort-based (MegaBlocks-style) dispatch.

Design notes:
  * Dispatch is gather/scatter based — argsort tokens by assigned expert,
    scatter into an (E, C, D) capacity buffer, run a grouped expert GEMM,
    gather-combine.  Unlike the one-hot einsum dispatch (GShard), sorting
    adds **zero phantom FLOPs** to the compiled HLO, so the roofline's
    MODEL_FLOPS / HLO_FLOPs ratio stays honest.
  * Expert weights are stacked (E, D, F) and sharded over the "experts"
    logical axis (mapped to the mesh "model" axis = expert parallelism);
    the scatter from token-sharded to expert-sharded buffers lowers to an
    all-to-all under SPMD — exactly a production EP dispatch.
  * Capacity-factor token dropping (standard at scale); dropped tokens pass
    through the residual stream untouched.

Batch-invariant serving dispatch (``MoEConfig.dispatch``): the pooled
path above makes a token's routing depend on every other token in the
call — expert capacity is a function of the pool size, and drops depend
on which neighbors compete for a full expert.  For serving that breaks
the determinism contract (outputs would vary with co-batched traffic and
prefill chunking), so two more dispatch paths exist:

  * ``per_request`` — tokens are grouped by batch row (the serving
    engine's request axis) at the drop-free capacity bound ``C = S``
    (top-k ids are distinct, so one expert receives at most S tokens
    from an S-token row): every token always reaches its top-k experts,
    so routing is pure per-token top-k and independent of neighbors AND
    of how the prompt was chunked.
  * gather-GEMM (decode) — for single-token rows the capacity buffer
    disappears entirely: each token gathers its k ``(D, F)`` expert
    weight slices and runs k small GEMMs.  FLOPs scale with ``top_k``,
    not ``n_experts``, and no cross-token structure exists at all.

``resolve_dispatch`` maps the config knob x execution route (train /
prefill / decode) to one of these paths; ``"auto"`` keeps pooled
semantics for training (Switch aux loss, EP sharding, capacity drops)
and batch-invariant paths for serving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MOE_DISPATCH_MODES, MoEConfig
from repro.models.module import Module, fan_in_init

#: Execution routes threaded from models.transformer: full-sequence
#: training/eval, chunked prompt prefill, single-token decode.
ROUTES = ("train", "prefill", "decode")


def resolve_dispatch(dispatch: str, route: str) -> str:
    """Config knob x execution route -> concrete dispatch path.

    Returns one of "pooled" | "per_request" | "gather".  ``auto`` keeps
    the training path pooled (aux loss / EP / capacity drops untouched)
    and picks the batch-invariant path per serving route.
    """
    if route not in ROUTES:
        raise ValueError(f"route must be one of {ROUTES}, got {route!r}")
    if dispatch not in MOE_DISPATCH_MODES:     # mirrors MoEConfig validation
        raise ValueError(f"dispatch must be one of {MOE_DISPATCH_MODES}, "
                         f"got {dispatch!r}")
    if dispatch in ("pooled", "per_request"):
        return dispatch
    return {"train": "pooled", "prefill": "per_request",
            "decode": "gather"}[route]


class DenseMLP(Module):
    """SwiGLU MLP: down( silu(gate(x)) * up(x) )."""

    def __init__(self, d_model, d_ff, *, dtype=jnp.float32, name="mlp"):
        self.d_model, self.d_ff = int(d_model), int(d_ff)
        self.dtype, self.name = dtype, name

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        d, f = self.d_model, self.d_ff
        return {"w_gate": fan_in_init(k1, (d, f), self.dtype),
                "w_up": fan_in_init(k2, (d, f), self.dtype),
                "w_down": fan_in_init(k3, (f, d), self.dtype)}

    def axes(self):
        return {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                "w_down": ("mlp", "embed")}

    def __call__(self, params, x):
        g = x @ params["w_gate"].astype(x.dtype)
        u = x @ params["w_up"].astype(x.dtype)
        return (jax.nn.silu(g) * u) @ params["w_down"].astype(x.dtype)


def _constrain(x, *spec):
    """Best-effort sharding constraint (needs a mesh context at trace time;
    silently skipped outside one, e.g. in single-device smoke tests)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x


class MoEMLP(Module):
    """Top-k routed experts (+ optional shared experts)."""

    def __init__(self, d_model, moe: MoEConfig, *, dtype=jnp.float32,
                 name="moe", constraints=False):
        self.d_model = int(d_model)
        self.moe = moe
        self.constraints = constraints
        self.dtype, self.name = dtype, name
        self.shared = (DenseMLP(d_model, moe.d_ff_expert * moe.n_shared,
                                dtype=dtype, name="shared")
                       if moe.n_shared else None)

    def init(self, key):
        e = self.moe
        d, f = self.d_model, e.d_ff_expert
        ks = jax.random.split(key, 5)
        p = {
            "router": fan_in_init(ks[0], (d, e.n_experts), self.dtype),
            "w_gate": jax.vmap(lambda k: fan_in_init(k, (d, f), self.dtype))(
                jax.random.split(ks[1], e.n_experts)),
            "w_up": jax.vmap(lambda k: fan_in_init(k, (d, f), self.dtype))(
                jax.random.split(ks[2], e.n_experts)),
            "w_down": jax.vmap(lambda k: fan_in_init(k, (f, d), self.dtype))(
                jax.random.split(ks[3], e.n_experts)),
        }
        if self.shared:
            p["shared"] = self.shared.init(ks[4])
        return p

    def axes(self):
        a = {"router": ("embed", None),
             "w_gate": ("experts", "embed", "mlp"),
             "w_up": ("experts", "embed", "mlp"),
             "w_down": ("experts", "mlp", "embed")}
        if self.shared:
            a["shared"] = self.shared.axes()
        return a

    @staticmethod
    def capacity(NL, e):
        """Per-group expert capacity (bounded by the assignment count)."""
        return int(min(NL * e.top_k,
                       max(1, round(NL * e.top_k / e.n_experts
                                    * e.capacity_factor))))

    def _dispatch_group(self, params, xt, dtype, C):
        """Sort-based dispatch for ONE token group. xt: (NL, D)."""
        e = self.moe
        NL, D = xt.shape
        gate_vals, expert_ids, probs = self._route(params, xt, dtype)

        flat_e = expert_ids.reshape(-1)                          # (NL*k,)
        order = jnp.argsort(flat_e)                              # stable
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e.n_experts)
        starts = jnp.cumsum(counts) - counts                     # exclusive
        pos_in_e = jnp.arange(NL * e.top_k) - starts[sorted_e]
        token_of = order // e.top_k
        valid = pos_in_e < C
        dest = jnp.where(valid, sorted_e * C + pos_in_e, e.n_experts * C)

        buf = jnp.zeros((e.n_experts * C, D), dtype)
        buf = buf.at[dest].set(xt[token_of], mode="drop")
        xe = buf.reshape(e.n_experts, C, D)
        meta = dict(dest=dest, valid=valid, token_of=token_of, order=order,
                    gate_vals=gate_vals, probs=probs, flat_e=flat_e)
        return xe, meta

    def _combine_group(self, ye, meta, NL, D, dtype, C):
        e = self.moe
        yflat = ye.reshape(e.n_experts * C, D)
        contrib = jnp.where(
            meta["valid"][:, None],
            yflat[jnp.clip(meta["dest"], 0, e.n_experts * C - 1)], 0.0)
        gates = meta["gate_vals"].reshape(-1)[meta["order"]][:, None]
        contrib = contrib * gates.astype(dtype)
        return jnp.zeros((NL, D), dtype).at[meta["token_of"]].add(contrib)

    def _route(self, params, xt, dtype):
        """Shared router head: xt (N, D) -> (renormalized top-k gate
        values (N, k), expert ids (N, k), full probs (N, E))."""
        e = self.moe
        logits = (xt @ params["router"].astype(dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)                       # (N, E)
        gate_vals, expert_ids = jax.lax.top_k(probs, e.top_k)    # (N, k)
        gate_vals = gate_vals / jnp.clip(
            gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize
        return gate_vals, expert_ids, probs

    def _gather_ffn(self, params, xt, dtype):
        """Capacity-free gather-GEMM dispatch. xt: (N, D), one token per
        row.  Each token gathers its k (D, F) expert slices and runs k
        small GEMMs — no capacity buffer, no sorting, no cross-token
        structure: a token's output depends only on its own activations,
        which is exactly the decode-step batch-invariance guarantee."""
        gate_vals, expert_ids, probs = self._route(params, xt, dtype)
        wg = params["w_gate"].astype(dtype)[expert_ids]          # (N, k, D, F)
        wu = params["w_up"].astype(dtype)[expert_ids]
        wd = params["w_down"].astype(dtype)[expert_ids]          # (N, k, F, D)
        g = jnp.einsum("nd,nkdf->nkf", xt, wg)
        u = jnp.einsum("nd,nkdf->nkf", xt, wu)
        y = jnp.einsum("nkf,nkfd->nkd", jax.nn.silu(g) * u, wd)
        out = jnp.einsum("nkd,nk->nd", y, gate_vals.astype(dtype))
        return out, probs, expert_ids

    def _grouped_ffn(self, params, x, G, C):
        """Sort-based dispatch over G token groups at capacity C.
        x: (B, S, D) reshaped to (G, B*S//G, D) groups."""
        e = self.moe
        B, S, D = x.shape
        NL = B * S // G
        xt = x.reshape(G, NL, D)
        if self.constraints:
            xt = _constrain(xt, ("pod", "data"), None, None)

        xe, meta = jax.vmap(
            lambda t: self._dispatch_group(params, t, x.dtype, C))(xt)
        if self.constraints:
            xe = _constrain(xe, ("pod", "data"), "model", None, None)

        # ---- grouped expert GEMM (E-sharded) ----
        g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype))
        ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u,
                        params["w_down"].astype(x.dtype))
        if self.constraints:
            ye = _constrain(ye, ("pod", "data"), "model", None, None)

        out = jax.vmap(
            lambda y, m: self._combine_group(y, m, NL, D, x.dtype, C)
        )(ye, meta)
        if self.constraints:
            out = _constrain(out, ("pod", "data"), None, None)
        return out.reshape(B, S, D), meta

    def __call__(self, params, x, route="train"):
        """x: (B, S, D) -> (B, S, D); also returns aux losses dict.

        ``route`` ("train" | "prefill" | "decode") and the config's
        ``dispatch`` knob select the dispatch path (see module docstring
        and :func:`resolve_dispatch`).

        Pooled path: with ``moe.groups`` = the DP degree (and groups
        along the batch dim), the scatter/gather never cross data shards
        — only the expert GEMM's operands move over the "model" axis and
        the combine's partial sums are all-reduced (§Perf cell B).

        Per-request path: G = B (one group per batch row = per serving
        request) at the drop-free capacity bound C = S — routing
        reduces to per-token top-k, invariant to co-batched rows and to
        prompt chunking.
        """
        e = self.moe
        B, S, D = x.shape
        mode = resolve_dispatch(e.dispatch, route)

        if mode == "gather":
            out, probs, expert_ids = self._gather_ffn(
                params, x.reshape(B * S, D), x.dtype)
            out = out.reshape(B, S, D)
            if self.shared:
                out = out + self.shared(params["shared"], x)
            me = probs.mean(0)                                   # (E,)
            ce = jnp.bincount(expert_ids.reshape(-1),
                              length=e.n_experts) / expert_ids.size
            return out, {"aux_loss": e.n_experts * jnp.sum(me * ce),
                         "dropped_frac": jnp.float32(0.0)}

        if mode == "per_request":
            G = B                       # one dispatch group per request row
            # drop-free bound: top_k expert ids are DISTINCT per token, so
            # any one expert receives at most S tokens from an S-token row
            C = S
        else:                           # pooled
            # groups must divide the batch; clamp guards a degenerate
            # B < groups call (and groups=0 is rejected by MoEConfig)
            G = max(1, e.groups if B % max(e.groups, 1) == 0 else 1)
            C = self.capacity(B * S // G, e)
        out, meta = self._grouped_ffn(params, x, G, C)

        if self.shared:
            out = out + self.shared(params["shared"], x)

        # load-balancing aux loss (Switch-style)
        me = meta["probs"].mean((0, 1))                          # (E,)
        ce = jnp.bincount(meta["flat_e"].reshape(-1),
                          length=e.n_experts) / meta["flat_e"].size
        aux = e.n_experts * jnp.sum(me * ce)
        return out, {"aux_loss": aux,
                     "dropped_frac": 1.0 - meta["valid"].mean()}
