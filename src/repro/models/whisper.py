"""Whisper-style encoder-decoder backbone (audio arch, conv frontend stubbed).

Per the assignment, the modality frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, T_frames, d_model) — the two strided conv
layers of Whisper are replaced by an identity on these embeddings.  The
transformer backbone (encoder self-attn, decoder self-attn + cross-attn) is
implemented in full and follows the paper-config geometry.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import GQAAttention, _sdpa, causal_mask, NEG_INF
from repro.models.moe import DenseMLP
from repro.models.module import (Embedding, Module, RMSNorm, fan_in_init,
                                 stacked_axes, stacked_init)


class CrossAttention(Module):
    def __init__(self, cfg: ModelConfig, name="xattn", dtype=jnp.float32):
        self.cfg, self.name, self.dtype = cfg, name, dtype

    def init(self, key):
        c = self.cfg
        d, H, hd = c.d_model, c.n_heads, c.head_dim
        ks = jax.random.split(key, 4)
        mk = lambda k, s, f: fan_in_init(k, s, self.dtype, fan_in=f)
        return {"wq": mk(ks[0], (d, H, hd), d),
                "wk": mk(ks[1], (d, H, hd), d),
                "wv": mk(ks[2], (d, H, hd), d),
                "wo": mk(ks[3], (H, hd, d), H * hd)}

    def axes(self):
        return {"wq": ("embed", "heads", "head_dim"),
                "wk": ("embed", "heads", "head_dim"),
                "wv": ("embed", "heads", "head_dim"),
                "wo": ("heads", "head_dim", "embed")}

    def kv(self, params, memory):
        k = jnp.einsum("bld,dhk->blhk", memory, params["wk"].astype(memory.dtype))
        v = jnp.einsum("bld,dhk->blhk", memory, params["wv"].astype(memory.dtype))
        return k, v

    def __call__(self, params, x, memory=None, kv_cache=None):
        """x: (B,S,D); memory: (B,L,D) or precomputed (k,v)."""
        k, v = kv_cache if kv_cache is not None else self.kv(params, memory)
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
        B, S = q.shape[:2]
        mask = jnp.zeros((B, 1, S, k.shape[1]), q.dtype)
        out = _sdpa(q, k, v, mask)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


class EncoderLayer(Module):
    def __init__(self, cfg: ModelConfig, name="enc", dtype=jnp.float32):
        self.cfg, self.name = cfg, name
        self.attn = GQAAttention(cfg, dtype=dtype)
        self.mlp = DenseMLP(cfg.d_model, cfg.d_ff, dtype=dtype)
        self.n1 = RMSNorm(cfg.d_model, dtype=dtype)
        self.n2 = RMSNorm(cfg.d_model, dtype=dtype)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"attn": self.attn.init(k1), "mlp": self.mlp.init(k2),
                "n1": self.n1.init(k1), "n2": self.n2.init(k2)}

    def axes(self):
        return {"attn": self.attn.axes(), "mlp": self.mlp.axes(),
                "n1": self.n1.axes(), "n2": self.n2.axes()}

    def __call__(self, params, x):
        # bidirectional self-attention: run GQA attention without causal mask
        a = self.attn
        h = self.n1(params["n1"], x)
        q, k, v = a._qkv(params["attn"], h, jnp.arange(h.shape[1]))
        mask = jnp.zeros((h.shape[0], 1, h.shape[1], h.shape[1]), h.dtype)
        o = _sdpa(q, k, v, mask)
        x = x + jnp.einsum("bshk,hkd->bsd", o,
                           params["attn"]["wo"].astype(x.dtype))
        return x + self.mlp(params["mlp"], self.n2(params["n2"], x))


class DecoderLayerED(Module):
    def __init__(self, cfg: ModelConfig, name="dec", dtype=jnp.float32):
        self.cfg, self.name = cfg, name
        self.self_attn = GQAAttention(cfg, dtype=dtype)
        self.cross = CrossAttention(cfg, dtype=dtype)
        self.mlp = DenseMLP(cfg.d_model, cfg.d_ff, dtype=dtype)
        self.n1 = RMSNorm(cfg.d_model, dtype=dtype)
        self.n2 = RMSNorm(cfg.d_model, dtype=dtype)
        self.n3 = RMSNorm(cfg.d_model, dtype=dtype)

    def init(self, key):
        ks = jax.random.split(key, 3)
        return {"self": self.self_attn.init(ks[0]),
                "cross": self.cross.init(ks[1]),
                "mlp": self.mlp.init(ks[2]),
                "n1": self.n1.init(ks[0]), "n2": self.n2.init(ks[1]),
                "n3": self.n3.init(ks[2])}

    def axes(self):
        return {"self": self.self_attn.axes(), "cross": self.cross.axes(),
                "mlp": self.mlp.axes(), "n1": self.n1.axes(),
                "n2": self.n2.axes(), "n3": self.n3.axes()}

    def __call__(self, params, x, memory):
        x = x + self.self_attn(params["self"], self.n1(params["n1"], x))
        x = x + self.cross(params["cross"], self.n2(params["n2"], x), memory)
        return x + self.mlp(params["mlp"], self.n3(params["n3"], x))

    def decode(self, params, x, cache, pos):
        h, sc = self.self_attn.decode(params["self"],
                                      self.n1(params["n1"], x),
                                      cache["self"], pos)
        x = x + h
        x = x + self.cross(params["cross"], self.n2(params["n2"], x),
                           kv_cache=(cache["xk"], cache["xv"]))
        x = x + self.mlp(params["mlp"], self.n3(params["n3"], x))
        return x, {"self": sc, "xk": cache["xk"], "xv": cache["xv"]}


class EncDecLM(Module):
    """Whisper-shaped backbone: encoder over frame embeddings, causal
    decoder over tokens with cross-attention."""

    def __init__(self, cfg: ModelConfig, *, dtype=jnp.float32,
                 scan_layers: bool = True):
        self.cfg = cfg
        self.dtype = dtype
        self.scan_layers = scan_layers
        self.embed = Embedding(cfg.vocab_padded, cfg.d_model, dtype=dtype)
        self.enc_unit = EncoderLayer(cfg, dtype=dtype)
        self.dec_unit = DecoderLayerED(cfg, dtype=dtype)
        self.n_enc = cfg.n_enc_layers or cfg.n_layers
        self.n_dec = cfg.n_layers
        self.enc_norm = RMSNorm(cfg.d_model, dtype=dtype)
        self.final_norm = RMSNorm(cfg.d_model, dtype=dtype)
        self.name = cfg.name

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"embed": self.embed.init(k1),
                "enc": stacked_init(self.enc_unit, self.n_enc, k2),
                "dec": stacked_init(self.dec_unit, self.n_dec, k3),
                "enc_norm": self.enc_norm.init(k1),
                "final_norm": self.final_norm.init(k1)}

    def axes(self):
        return {"embed": self.embed.axes(),
                "enc": stacked_axes(self.enc_unit),
                "dec": stacked_axes(self.dec_unit),
                "enc_norm": self.enc_norm.axes(),
                "final_norm": self.final_norm.axes()}

    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == jnp.float32 else self.dtype

    def encode(self, params, frame_embeds):
        x = frame_embeds.astype(self.compute_dtype())

        def body(c, lp):
            return self.enc_unit(lp, c), None

        if self.scan_layers:
            x, _ = jax.lax.scan(body, x, params["enc"])
        else:
            for i in range(self.n_enc):
                x, _ = body(x, jax.tree_util.tree_map(
                    lambda p: p[i], params["enc"]))
        return self.enc_norm(params["enc_norm"], x)

    def __call__(self, params, tokens=None, embeds=None, positions=None):
        """embeds: (B, T_frames, D) stub frame embeddings; tokens: (B, S)."""
        del positions
        memory = self.encode(params, embeds)
        if tokens is None:   # encoder-only regime (prefill benchmark)
            return memory
        x = self.embed(params["embed"], tokens).astype(self.compute_dtype())

        def body(c, lp):
            return self.dec_unit(lp, c, memory), None

        if self.scan_layers:
            x, _ = jax.lax.scan(body, x, params["dec"])
        else:
            for i in range(self.n_dec):
                x, _ = body(x, jax.tree_util.tree_map(
                    lambda p: p[i], params["dec"]))
        x = self.final_norm(params["final_norm"], x)
        return self.embed.attend(params["embed"], x)

    def loss(self, params, batch):
        logits = self(params, tokens=batch["tokens"],
                      embeds=batch["embeds"]).astype(jnp.float32)
        labels = batch["labels"]
        mask = labels >= 0
        lab = jnp.clip(labels, 0)
        logz = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, lab[..., None], -1)[..., 0]
        loss = ((logz - ll) * mask).sum() / jnp.clip(mask.sum(), 1)
        return loss, {"loss": loss}

    # --- decode ---
    def cache_spec(self, batch, length, dtype=jnp.bfloat16):
        c = self.cfg
        self_spec = self.dec_unit.self_attn.cache_spec(batch, length, dtype)
        xk = jax.ShapeDtypeStruct(
            (self.n_dec, batch, c.frontend_seq, c.n_heads, c.head_dim), dtype)
        return {"dec": {
            "self": jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((self.n_dec,) + s.shape,
                                               s.dtype), self_spec),
            "xk": xk, "xv": xk}}

    def cache_axes(self):
        self_axes = jax.tree_util.tree_map(
            lambda a: ("layers",) + tuple(a),
            self.dec_unit.self_attn.cache_axes(),
            is_leaf=lambda x: isinstance(x, tuple))
        xa = ("layers", "batch", "frames", "heads", "head_dim")
        return {"dec": {"self": self_axes, "xk": xa, "xv": xa}}

    def init_cache(self, batch, length, dtype=jnp.bfloat16, params=None,
                   frame_embeds=None):
        cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, length, dtype))
        if params is not None and frame_embeds is not None:
            memory = self.encode(params, frame_embeds)
            ks, vs = jax.vmap(
                lambda lp: self.dec_unit.cross.kv(lp["cross"], memory)
            )(params["dec"])
            cache["dec"]["xk"] = ks.astype(dtype)
            cache["dec"]["xv"] = vs.astype(dtype)
        return cache

    def decode_step(self, params, tokens, cache, pos):
        x = self.embed(params["embed"], tokens).astype(self.compute_dtype())

        def body(carry, rep):
            lp, lc = rep
            h, nc = self.dec_unit.decode(lp, carry, lc, pos)
            return h, nc

        if self.scan_layers:
            x, new_dec = jax.lax.scan(body, x, (params["dec"], cache["dec"]))
        else:
            ncs = []
            for i in range(self.n_dec):
                sel = lambda t: jax.tree_util.tree_map(lambda p: p[i], t)
                x, nc = body(x, (sel(params["dec"]), sel(cache["dec"])))
                ncs.append(nc)
            new_dec = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *ncs)
        x = self.final_norm(params["final_norm"], x)
        logits = self.embed.attend(params["embed"], x)
        return logits, {"dec": new_dec}
