"""Attention blocks: GQA (global + sliding window) and DeepSeek MLA.

All variants support the three execution regimes of the assignment:
  * train/prefill  — full-sequence causal attention
  * decode         — single new token against a KV cache
    (GQA: ring-buffer cache for local layers; MLA: compressed latent cache
    with the weight-absorption trick, which is what makes MLA's small cache
    pay off at decode time)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MLAConfig
from repro.kernels.paged_attention import quant as kvq
from repro.kernels.paged_attention.ref import (gather_dequant, gather_pages,
                                               paged_positions)
from repro.models.module import Module, RMSNorm, fan_in_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(q_pos, k_pos, window: int | None = None):
    """(…, Sq, Sk) additive mask: causal, optionally banded (sliding)."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return jnp.where(m, 0.0, NEG_INF)


def _paged_write_q8(pool, scale, wpage, in_page, fresh, tok):
    """Quantized page-granular decode write (kv_dtype="int8"): read the
    slot's live page row, grow its scale monotonically to admit the new
    token, rescale the existing codes, scatter the token's codes, write
    the row and scale back.

    In the steady state the scale is unchanged, the rescale ratio is
    exactly 1.0, and round(c * 1.0) == c — repeated decode writes never
    perturb stored codes.  ``fresh`` marks writes that START a new page:
    the previous tenant's scale is reset to 0 there, which also zeroes
    its stale codes through the rescale.  (A sliding-window ring recycles
    pages in place, so its pages are fresh only on the first lap and the
    scale grows monotonically over the window's history — conservative,
    never wrong.)

    pool: (P, ps, *feat, d) int8; scale: (P, *feat) f32; wpage: (B,)
    page ids (out of bounds for inactive slots — reads clamp, writes
    drop, which IS the frozen-slot merge); in_page: (B,) in-page index;
    tok: (B, *feat, d) this step's values."""
    B = tok.shape[0]
    rows = pool[wpage]                            # (B, ps, *feat, d)
    old_s = scale[wpage]                          # (B, *feat)
    f = fresh.reshape((B,) + (1,) * (old_s.ndim - 1))
    old_s = jnp.where(f, 0.0, old_s)
    tok_s = jnp.max(jnp.abs(tok.astype(jnp.float32)), axis=-1) / kvq.QMAX
    new_s = jnp.maximum(jnp.maximum(old_s, tok_s), kvq.MIN_SCALE)
    rows = kvq.rescale_codes(rows, old_s, new_s)
    code = jnp.clip(jnp.round(tok.astype(jnp.float32) / new_s[..., None]),
                    -kvq.QMAX, kvq.QMAX).astype(jnp.int8)
    rows = rows.at[jnp.arange(B), in_page].set(code)
    return pool.at[wpage].set(rows), scale.at[wpage].set(new_s)


def _sdpa(q, k, v, mask):
    """q: (B,S,H,hd), k/v: (B,L,KV,hd) with H = KV*G. mask: (B,1,S,L)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgd,blkd->bkgsl", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd) + mask[:, :, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgsl,blkd->bskgd", w, v)
    return out.reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

class GQAAttention(Module):
    def __init__(self, cfg: ModelConfig, *, local: bool = False, name="attn",
                 dtype=jnp.float32):
        self.cfg = cfg
        self.local = local
        self.window = cfg.sliding_window if local else None
        self.name = name
        self.dtype = dtype

    def init(self, key):
        c = self.cfg
        ks = jax.random.split(key, 4)
        shp = dict(dtype=self.dtype)
        d, H, KV, hd = c.d_model, c.n_heads, c.n_kv_heads, c.head_dim
        mk = lambda k, s: fan_in_init(k, s, self.dtype, fan_in=s[0])
        return {
            "wq": mk(ks[0], (d, H * hd)).reshape(d, H, hd),
            "wk": mk(ks[1], (d, KV * hd)).reshape(d, KV, hd),
            "wv": mk(ks[2], (d, KV * hd)).reshape(d, KV, hd),
            "wo": fan_in_init(ks[3], (H * hd, d), self.dtype).reshape(H, hd, d),
        }

    def axes(self):
        return {"wq": ("embed", "heads", "head_dim"),
                "wk": ("embed", "kv_heads", "head_dim"),
                "wv": ("embed", "kv_heads", "head_dim"),
                "wo": ("heads", "head_dim", "embed")}

    def _qkv(self, params, x, positions):
        c = self.cfg
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
        return q, k, v

    def __call__(self, params, x, positions=None):
        """Full-sequence causal attention. x: (B, S, D)."""
        B, S, _ = x.shape
        impl = self.cfg.attention_impl
        if positions is None:
            positions = jnp.arange(S)
        q, k, v = self._qkv(params, x, positions)
        if impl == "stub":
            # dry-run stand-in: O(S·d) op with grads to q/k/v; the real
            # kernel's cost is added analytically by launch.dryrun
            out = q + (k.mean(1, keepdims=True) + v.mean(1, keepdims=True)
                       ).mean(2, keepdims=True)
        elif impl == "flash":
            from repro.kernels.flash_attention.ops import flash_attention
            out = flash_attention(
                jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                jnp.moveaxis(v, 2, 1), True, self.window, "pallas")
            out = jnp.moveaxis(out, 1, 2)
        else:
            pos = jnp.broadcast_to(positions, (B, S)) \
                if positions.ndim == 1 else positions
            mask = causal_mask(pos, pos, self.window)[:, None]
            out = _sdpa(q, k, v, mask)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))

    # --- decode ---
    def init_cache(self, batch, length, dtype=jnp.bfloat16):
        c = self.cfg
        L = min(length, self.window) if self.window else length
        return {
            "k": jnp.zeros((batch, L, c.n_kv_heads, c.head_dim), dtype),
            "v": jnp.zeros((batch, L, c.n_kv_heads, c.head_dim), dtype),
        }

    def cache_spec(self, batch, length, dtype=jnp.bfloat16):
        c = self.cfg
        L = min(length, self.window) if self.window else length
        s = jax.ShapeDtypeStruct((batch, L, c.n_kv_heads, c.head_dim), dtype)
        return {"k": s, "v": s}

    def cache_axes(self):
        a = ("batch", "kv_len", "kv_heads", "head_dim")
        return {"k": a, "v": a}

    def can_prefill(self):
        return True

    def prefill(self, params, x, cache, pos0, length=None):
        """Chunk prefill.  Tokens at in-chunk index >= ``length`` are grid
        padding: masked out of attention and never written to the cache
        (``length=None`` means the whole chunk is valid).

        Global: scatter K/V at absolute positions [pos0, pos0+length) and
        attend causally against the whole cache.  Sliding-window: attend
        each query against (ring snapshot ++ in-chunk K/V), then perform a
        wrap-aware masked ring scatter of the last min(L, length) valid
        tokens — exactly one writer per ring slot, so chunk writes that
        cross the ring boundary neither clobber live entries nor skip
        slots (the ROADMAP wrap bug)."""
        B, S, _ = x.shape
        if length is None:
            length = jnp.int32(S)
        positions = pos0 + jnp.arange(S)
        q, k, v = self._qkv(params, x,
                            jnp.broadcast_to(positions, (B, S)))
        L = cache["k"].shape[1]
        i = jnp.arange(S)
        valid = i < length
        if not self.local:
            # index L is out of bounds -> the scatter drops padding writes
            idx = jnp.where(valid & (positions < L), positions, L)
            ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
            k_pos = jnp.arange(L)
            mask = jnp.where(k_pos[None, :] <= positions[:, None], 0.0,
                             NEG_INF)[None, None]        # (1, 1, S, L)
            mask = jnp.broadcast_to(mask, (B, 1, S, L))
            out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
        else:
            # the ring retains only L positions, so the effective window
            # matches the scanned decode path: min(window, L)
            W = min(self.window, L)
            # ring snapshot: entry j holds the latest position <= pos0-1
            # congruent to j (mod L); queries may not see entries this
            # chunk is about to overwrite, hence snapshot-then-write
            j = jnp.arange(L)
            ring_pos = (pos0 - 1) - ((pos0 - 1 - j) % L)
            ring_m = ((ring_pos >= 0) & (pos0 >= 1))[None, :] \
                & (positions[:, None] - ring_pos[None, :] < W)
            in_m = ((positions[None, :] <= positions[:, None])
                    & (positions[:, None] - positions[None, :] < W)
                    & valid[None, :])
            mask = jnp.where(jnp.concatenate([ring_m, in_m], axis=1),
                             0.0, NEG_INF)               # (S, L+S)
            mask = jnp.broadcast_to(mask[None, None], (B, 1, S, L + S))
            kk = jnp.concatenate([cache["k"].astype(q.dtype), k], axis=1)
            vv = jnp.concatenate([cache["v"].astype(q.dtype), v], axis=1)
            out = _sdpa(q, kk, vv, mask)
            # ring write: of the valid tokens, only the last L survive a
            # wrap — dropping the aliased older ones keeps one writer per
            # slot (duplicate-index scatter order is unspecified)
            wmask = valid & (i >= length - L)
            idx = jnp.where(wmask, (pos0 + i) % L, L)
            ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, {"k": ck, "v": cv}

    # --- paged decode (shared page pool + per-request block tables) ---
    def paged_cache_spec(self, num_pages, page_size, dtype=jnp.bfloat16):
        c = self.cfg
        if c.kv_dtype == "int8":
            s = jax.ShapeDtypeStruct(
                (num_pages, page_size, c.n_kv_heads, c.head_dim), jnp.int8)
            sc = jax.ShapeDtypeStruct((num_pages, c.n_kv_heads),
                                      jnp.float32)
            return {"k": s, "v": s, "k_scale": sc, "v_scale": sc}
        s = jax.ShapeDtypeStruct(
            (num_pages, page_size, c.n_kv_heads, c.head_dim), dtype)
        return {"k": s, "v": s}

    def paged_cache_axes(self):
        a = ("pages", "page", "kv_heads", "head_dim")
        if self.cfg.kv_dtype == "int8":
            sc = ("pages", "kv_heads")
            return {"k": a, "v": a, "k_scale": sc, "v_scale": sc}
        return {"k": a, "v": a}

    def ring_length(self, length):
        """Dense in-cache length this layer emulates at engine max_len
        ``length`` (the sliding-window ring retains only the window)."""
        return min(length, self.window) if self.window else length

    def decode_paged(self, params, x, cache, pos, bt, active, length):
        """One slot-batched decode step against the page pool.

        x: (B, 1, D); pos/active: (B,); bt: (B, max_pages) page ids;
        cache: {"k","v"} pools (P, page, KV, hd); ``length`` = the
        engine's max_len.  The current token's K/V is scattered into the
        slot's live page (inactive slots write out of bounds — dropped,
        which IS the frozen-slot merge for pool state), then attention
        reads the chain back.  The default "gather" impl reconstructs the
        dense in-cache view and runs EXACTLY the dense ``decode`` math —
        entry j of the view equals dense cache entry j bitwise wherever
        the causal/window mask can see it, so paged == dense bitwise
        (bf16 pools).  "pallas" (the default) / "pallas_tpu" route the
        read through the page-indirect kernel instead (fp32 online
        softmax; no dense view is built).  With kv_dtype="int8" the
        write quantizes into the slot's live page (``_paged_write_q8``)
        and the read dequantizes per page — in-register in the kernel,
        via the scale gather on the oracle path."""
        B = x.shape[0]
        q, k, v = self._qkv(params, x, pos[:, None])
        Pp, ps = cache["k"].shape[0], cache["k"].shape[1]
        L = self.ring_length(length)
        slot = (pos % L) if self.window else pos          # in-cache index
        wpage = jnp.where(active, bt[jnp.arange(B), slot // ps], Pp)
        q8 = self.cfg.kv_dtype == "int8"
        if q8:
            # a page is brand-new only when the write lands on its first
            # entry at an unwrapped position (ring laps recycle in place)
            fresh = ((slot % ps) == 0) & (pos == slot)
            ck, cks = _paged_write_q8(cache["k"], cache["k_scale"],
                                      wpage, slot % ps, fresh, k[:, 0])
            cv, cvs = _paged_write_q8(cache["v"], cache["v_scale"],
                                      wpage, slot % ps, fresh, v[:, 0])
            new = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            cks = cvs = None
            ck = cache["k"].at[wpage, slot % ps].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[wpage, slot % ps].set(
                v[:, 0].astype(cache["v"].dtype))
            new = {"k": ck, "v": cv}
        impl = self.cfg.paged_impl
        if impl != "gather":
            from repro.kernels.paged_attention.ops import paged_gqa_attention
            out = paged_gqa_attention(
                q[:, 0], ck, cv, bt, pos, length=L, window=self.window,
                backend=impl, k_scale=cks, v_scale=cvs)[:, None]
        else:
            if q8:
                kd = gather_dequant(ck, cks, bt, L, q.dtype)
                vd = gather_dequant(cv, cvs, bt, L, q.dtype)
            else:
                kd = gather_pages(ck, bt, L).astype(q.dtype)
                vd = gather_pages(cv, bt, L).astype(q.dtype)
            _k_pos, valid = paged_positions(pos, L, self.window)
            mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
            out = _sdpa(q, kd, vd, mask)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, new

    # --- speculative k-token verify (read-only; commit is separate) ---
    def verify_paged(self, params, x, cache, pos, bt, active, length):
        """Score K speculative tokens against the page pool WITHOUT
        writing it.  x: (B, K, D) holds the current token plus K-1
        drafts at positions ``pos .. pos+K-1``.

        Each query i attends through a per-query dense view: the
        gathered pool snapshot with the in-flight K/V of tokens m <= i
        overlaid at their native in-cache indices — exactly the view a
        sequential ``decode_paged`` at ``pos+i`` would read (the write-
        then-gather order, the ring eviction of entries more than L back,
        and the position mask all match by construction), and each query
        runs the identical S=1 ``_sdpa`` program, so greedy verify logits
        are bitwise the sequential gather-path logits.  Requires
        K <= ring length (the engine validates ``spec_k`` against it).

        Returns ``(y (B, K, D), block)`` where ``block`` holds the
        cache-dtype K/V of all K tokens for a later ``commit_paged`` of
        however many the verifier accepts — the pool never holds a
        speculative byte, so rollback is simply not committing."""
        if self.cfg.kv_dtype == "int8":
            raise NotImplementedError(
                "speculative verify requires bf16 pools (int8 page "
                "rescale is not replayable per accepted prefix)")
        B, K, _ = x.shape
        positions = pos[:, None] + jnp.arange(K)[None, :]
        q, k, v = self._qkv(params, x, positions)
        L = self.ring_length(length)
        slot = (positions % L) if self.window else positions   # (B, K)
        kd0 = gather_pages(cache["k"], bt, L)      # snapshot, pool dtype
        vd0 = gather_pages(cache["v"], bt, L)
        kc = k.astype(cache["k"].dtype)
        vc = v.astype(cache["v"].dtype)
        rows = jnp.arange(B)[:, None]
        outs = []
        for i in range(K):
            ki = kd0.at[rows, slot[:, :i + 1]].set(
                kc[:, :i + 1]).astype(q.dtype)
            vi = vd0.at[rows, slot[:, :i + 1]].set(
                vc[:, :i + 1]).astype(q.dtype)
            _k_pos, valid = paged_positions(pos + i, L, self.window)
            mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
            outs.append(_sdpa(q[:, i:i + 1], ki, vi, mask))
        out = jnp.concatenate(outs, axis=1)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, {"k": kc, "v": vc}

    def commit_paged(self, cache, block, pos, bt, n_commit, active,
                     length):
        """Scatter the first ``n_commit[b]`` verified tokens of
        ``block`` (from :meth:`verify_paged`) into the pool at positions
        ``pos[b] .. pos[b]+n_commit[b]-1``.  Rejected/invalid entries
        write out of bounds — dropped, like every frozen-slot write."""
        B, K = block["k"].shape[:2]
        Pp, ps = cache["k"].shape[0], cache["k"].shape[1]
        L = self.ring_length(length)
        j = jnp.arange(K)
        p = pos[:, None] + j[None, :]
        slot = (p % L) if self.window else p
        ok = (j[None, :] < n_commit[:, None]) & active[:, None]
        wpage = jnp.where(ok, bt[jnp.arange(B)[:, None], slot // ps], Pp)
        return {"k": cache["k"].at[wpage, slot % ps].set(block["k"]),
                "v": cache["v"].at[wpage, slot % ps].set(block["v"])}

    def decode(self, params, x, cache, pos):
        """One-step decode. x: (B, 1, D); pos: scalar current position."""
        B = x.shape[0]
        q, k, v = self._qkv(params, x, jnp.full((B, 1), pos))
        L = cache["k"].shape[1]
        slot = (pos % L) if self.window else pos
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        # key positions: ring buffer for local, linear for global
        idx = jnp.arange(L)
        if self.window:
            # entry i holds position: the largest p ≤ pos with p % L == i
            k_pos = pos - ((pos - idx) % L)
        else:
            k_pos = idx
        valid = (k_pos <= pos) & (k_pos >= 0)
        if self.window:
            valid &= (pos - k_pos) < self.window
        mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
        mask = jnp.broadcast_to(mask, (B, 1, 1, L))
        out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# DeepSeek Multi-head Latent Attention
# ---------------------------------------------------------------------------

class MLAAttention(Module):
    def __init__(self, cfg: ModelConfig, name="mla", dtype=jnp.float32):
        assert cfg.mla is not None
        self.cfg = cfg
        self.m: MLAConfig = cfg.mla
        self.name = name
        self.dtype = dtype

    def init(self, key):
        c, m = self.cfg, self.m
        d, H = c.d_model, c.n_heads
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        ks = jax.random.split(key, 6)
        mk = lambda k, s, f: fan_in_init(k, s, self.dtype, fan_in=f)
        return {
            "w_dq": mk(ks[0], (d, m.q_lora_rank), d),
            "w_uq": mk(ks[1], (m.q_lora_rank, H, qk_head), m.q_lora_rank),
            "w_dkv": mk(ks[2], (d, m.kv_lora_rank), d),
            "w_kr": mk(ks[3], (d, m.qk_rope_head_dim), d),
            "w_ukv": mk(ks[4], (m.kv_lora_rank, H,
                                m.qk_nope_head_dim + m.v_head_dim),
                        m.kv_lora_rank),
            "wo": mk(ks[5], (H, m.v_head_dim, d), H * m.v_head_dim),
            "q_norm": jnp.ones((m.q_lora_rank,), self.dtype),
            "kv_norm": jnp.ones((m.kv_lora_rank,), self.dtype),
        }

    def axes(self):
        return {"w_dq": ("embed", "q_lora"),
                "w_uq": ("q_lora", "heads", "head_dim"),
                "w_dkv": ("embed", "kv_lora"),
                "w_kr": ("embed", "head_dim"),
                "w_ukv": ("kv_lora", "heads", "head_dim"),
                "wo": ("heads", "head_dim", "embed"),
                "q_norm": ("q_lora",), "kv_norm": ("kv_lora",)}

    @staticmethod
    def _rms(x, scale, eps=1e-6):
        v = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
        return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps)
                * scale.astype(jnp.float32)).astype(x.dtype)

    def _latents(self, params, x, positions):
        c, m = self.cfg, self.m
        cq = self._rms(x @ params["w_dq"].astype(x.dtype), params["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(x.dtype))
        q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
        q_rope = apply_rope(q_rope, positions, c.rope_theta)
        ckv = self._rms(x @ params["w_dkv"].astype(x.dtype), params["kv_norm"])
        k_rope = (x @ params["w_kr"].astype(x.dtype))[:, :, None, :]  # 1 shared head
        k_rope = apply_rope(k_rope, positions, c.rope_theta)[:, :, 0, :]
        return q_nope, q_rope, ckv, k_rope

    def __call__(self, params, x, positions=None):
        c, m = self.cfg, self.m
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.arange(S)
        q_nope, q_rope, ckv, k_rope = self._latents(params, x, positions)
        kv = jnp.einsum("bsr,rhk->bshk", ckv, params["w_ukv"].astype(x.dtype))
        k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
        if self.cfg.attention_impl == "stub":
            # dry-run stand-in (see GQAAttention.__call__)
            out = (v + q_nope.mean(-1, keepdims=True)
                   + q_rope.mean(-1, keepdims=True)
                   + k_nope.mean(-1, keepdims=True)
                   + k_rope.mean(-1, keepdims=True)[:, :, None, :])
        else:
            scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
            pos = jnp.broadcast_to(positions, (B, S)) \
                if positions.ndim == 1 else positions
            mask = causal_mask(pos, pos)[:, None]
            scores = (jnp.einsum("bshk,blhk->bhsl", q_nope, k_nope)
                      + jnp.einsum("bshk,blk->bhsl", q_rope, k_rope))
            scores = scores.astype(jnp.float32) * scale + mask
            w = jax.nn.softmax(scores, -1).astype(x.dtype)
            out = jnp.einsum("bhsl,blhk->bshk", w, v)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))

    # --- decode with compressed latent cache + weight absorption ---
    def cache_spec(self, batch, length, dtype=jnp.bfloat16):
        m = self.m
        return {
            "ckv": jax.ShapeDtypeStruct((batch, length, m.kv_lora_rank), dtype),
            "krope": jax.ShapeDtypeStruct((batch, length, m.qk_rope_head_dim), dtype),
        }

    def cache_axes(self):
        return {"ckv": ("batch", "kv_len", "kv_lora"),
                "krope": ("batch", "kv_len", "head_dim")}

    def init_cache(self, batch, length, dtype=jnp.bfloat16):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, length, dtype))

    def can_prefill(self):
        return True

    def prefill(self, params, x, cache, pos0, length=None):
        """Chunk prefill with the compressed latent cache: scatter the
        chunk's latents at absolute positions [pos0, pos0+length), then run
        the same weight-absorbed attention as ``decode`` for all S queries
        at once (identical math, batched over the chunk).  Tokens at
        in-chunk index >= ``length`` are grid padding — never written, and
        causally masked for every valid query."""
        c, m = self.cfg, self.m
        B, S, _ = x.shape
        if length is None:
            length = jnp.int32(S)
        positions = pos0 + jnp.arange(S)
        q_nope, q_rope, ckv, k_rope = self._latents(
            params, x, jnp.broadcast_to(positions, (B, S)))
        L = cache["ckv"].shape[1]
        i = jnp.arange(S)
        # index L is out of bounds -> the scatter drops padding writes
        idx = jnp.where((i < length) & (positions < L), positions, L)
        cc = cache["ckv"].at[:, idx].set(ckv.astype(cache["ckv"].dtype))
        cr = cache["krope"].at[:, idx].set(
            k_rope.astype(cache["krope"].dtype))
        w_uk = params["w_ukv"][:, :, :m.qk_nope_head_dim].astype(x.dtype)
        w_uv = params["w_ukv"][:, :, m.qk_nope_head_dim:].astype(x.dtype)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        scores = (jnp.einsum("bshr,blr->bhsl", q_abs, cc.astype(x.dtype))
                  + jnp.einsum("bshk,blk->bhsl", q_rope,
                               cr.astype(x.dtype)))
        mask = jnp.where(jnp.arange(L)[None, :] <= positions[:, None],
                         0.0, NEG_INF)[None, None]       # (1, 1, S, L)
        w = jax.nn.softmax(scores.astype(jnp.float32) * scale + mask,
                           -1).astype(x.dtype)
        o_latent = jnp.einsum("bhsl,blr->bshr", w, cc.astype(x.dtype))
        out = jnp.einsum("bshr,rhk->bshk", o_latent, w_uv)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, {"ckv": cc, "krope": cr}

    # --- paged decode over latent pages ---
    def paged_cache_spec(self, num_pages, page_size, dtype=jnp.bfloat16):
        m = self.m
        if self.cfg.kv_dtype == "int8":
            dtype = jnp.int8
        spec = {
            "ckv": jax.ShapeDtypeStruct(
                (num_pages, page_size, m.kv_lora_rank), dtype),
            "krope": jax.ShapeDtypeStruct(
                (num_pages, page_size, m.qk_rope_head_dim), dtype),
        }
        if self.cfg.kv_dtype == "int8":
            sc = jax.ShapeDtypeStruct((num_pages,), jnp.float32)
            spec.update(ckv_scale=sc, krope_scale=sc)
        return spec

    def paged_cache_axes(self):
        a = {"ckv": ("pages", "page", "kv_lora"),
             "krope": ("pages", "page", "head_dim")}
        if self.cfg.kv_dtype == "int8":
            a.update(ckv_scale=("pages",), krope_scale=("pages",))
        return a

    def ring_length(self, length):
        return length

    def decode_paged(self, params, x, cache, pos, bt, active, length):
        """Slot-batched weight-absorbed decode against latent page pools
        (see GQAAttention.decode_paged for the contract).  The compressed
        (ckv, k_rope) latents page exactly like K/V — this is what makes
        MLA's small cache pay off twice at serve time: fewer bytes per
        position AND pages allocated only for live positions."""
        c, m = self.cfg, self.m
        B = x.shape[0]
        q_nope, q_rope, ckv, k_rope = self._latents(params, x, pos[:, None])
        Pp, ps = cache["ckv"].shape[0], cache["ckv"].shape[1]
        wpage = jnp.where(active, bt[jnp.arange(B), pos // ps], Pp)
        q8 = self.cfg.kv_dtype == "int8"
        if q8:
            fresh = (pos % ps) == 0          # latent pages index globally
            cc, ccs = _paged_write_q8(cache["ckv"], cache["ckv_scale"],
                                      wpage, pos % ps, fresh, ckv[:, 0])
            cr, crs = _paged_write_q8(cache["krope"],
                                      cache["krope_scale"], wpage,
                                      pos % ps, fresh, k_rope[:, 0])
            new = {"ckv": cc, "krope": cr, "ckv_scale": ccs,
                   "krope_scale": crs}
        else:
            ccs = crs = None
            cc = cache["ckv"].at[wpage, pos % ps].set(
                ckv[:, 0].astype(cache["ckv"].dtype))
            cr = cache["krope"].at[wpage, pos % ps].set(
                k_rope[:, 0].astype(cache["krope"].dtype))
            new = {"ckv": cc, "krope": cr}
        w_uk = params["w_ukv"][:, :, :m.qk_nope_head_dim].astype(x.dtype)
        w_uv = params["w_ukv"][:, :, m.qk_nope_head_dim:].astype(x.dtype)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        impl = self.cfg.paged_impl
        if impl != "gather":
            from repro.kernels.paged_attention.ops import paged_mla_attention
            o_latent = paged_mla_attention(
                q_abs[:, 0], q_rope[:, 0], cc, cr, bt, pos, length=length,
                scale=scale, backend=impl, ckv_scale=ccs,
                krope_scale=crs)[:, None]
            o_latent = o_latent.astype(x.dtype)
        else:
            if q8:
                ccd = gather_dequant(cc, ccs, bt, length, x.dtype)
                crd = gather_dequant(cr, crs, bt, length, x.dtype)
            else:
                ccd = gather_pages(cc, bt, length).astype(x.dtype)
                crd = gather_pages(cr, bt, length).astype(x.dtype)
            scores = (jnp.einsum("bshr,blr->bhsl", q_abs, ccd)
                      + jnp.einsum("bshk,blk->bhsl", q_rope, crd))
            _k_pos, valid = paged_positions(pos, length, None)
            mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
            w = jax.nn.softmax(scores.astype(jnp.float32) * scale + mask,
                               -1).astype(x.dtype)
            o_latent = jnp.einsum("bhsl,blr->bshr", w, ccd)
        out = jnp.einsum("bshr,rhk->bshk", o_latent, w_uv)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, new

    # --- speculative k-token verify (read-only; commit is separate) ---
    def verify_paged(self, params, x, cache, pos, bt, active, length):
        """MLA k-token verify over latent pages — the same per-query
        overlaid-snapshot construction as GQAAttention.verify_paged, on
        the compressed (ckv, k_rope) latents (see that docstring for the
        bitwise contract)."""
        if self.cfg.kv_dtype == "int8":
            raise NotImplementedError(
                "speculative verify requires bf16 pools (int8 page "
                "rescale is not replayable per accepted prefix)")
        c, m = self.cfg, self.m
        B, K, _ = x.shape
        positions = pos[:, None] + jnp.arange(K)[None, :]
        q_nope, q_rope, ckv, k_rope = self._latents(params, x, positions)
        ccd0 = gather_pages(cache["ckv"], bt, length)
        crd0 = gather_pages(cache["krope"], bt, length)
        cc_b = ckv.astype(cache["ckv"].dtype)
        cr_b = k_rope.astype(cache["krope"].dtype)
        rows = jnp.arange(B)[:, None]
        w_uk = params["w_ukv"][:, :, :m.qk_nope_head_dim].astype(x.dtype)
        w_uv = params["w_ukv"][:, :, m.qk_nope_head_dim:].astype(x.dtype)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        outs = []
        for i in range(K):
            ccd = ccd0.at[rows, positions[:, :i + 1]].set(
                cc_b[:, :i + 1]).astype(x.dtype)
            crd = crd0.at[rows, positions[:, :i + 1]].set(
                cr_b[:, :i + 1]).astype(x.dtype)
            scores = (jnp.einsum("bshr,blr->bhsl", q_abs[:, i:i + 1], ccd)
                      + jnp.einsum("bshk,blk->bhsl", q_rope[:, i:i + 1],
                                   crd))
            _k_pos, valid = paged_positions(pos + i, length, None)
            mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
            w = jax.nn.softmax(scores.astype(jnp.float32) * scale + mask,
                               -1).astype(x.dtype)
            outs.append(jnp.einsum("bhsl,blr->bshr", w, ccd))
        o_latent = jnp.concatenate(outs, axis=1)
        out = jnp.einsum("bshr,rhk->bshk", o_latent, w_uv)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, {"ckv": cc_b, "krope": cr_b}

    def commit_paged(self, cache, block, pos, bt, n_commit, active,
                     length):
        """Commit the first ``n_commit[b]`` verified latents (see
        GQAAttention.commit_paged)."""
        B, K = block["ckv"].shape[:2]
        Pp, ps = cache["ckv"].shape[0], cache["ckv"].shape[1]
        j = jnp.arange(K)
        p = pos[:, None] + j[None, :]
        ok = (j[None, :] < n_commit[:, None]) & active[:, None]
        wpage = jnp.where(ok, bt[jnp.arange(B)[:, None], p // ps], Pp)
        return {"ckv": cache["ckv"].at[wpage, p % ps].set(block["ckv"]),
                "krope": cache["krope"].at[wpage, p % ps].set(
                    block["krope"])}

    def decode(self, params, x, cache, pos):
        c, m = self.cfg, self.m
        B = x.shape[0]
        q_nope, q_rope, ckv, k_rope = self._latents(
            params, x, jnp.full((B, 1), pos))
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), pos, axis=1)
        # absorb W^{UK} into the query:  q_abs = q_nope @ W^{UK}ᵀ  (per head)
        w_uk = params["w_ukv"][:, :, :m.qk_nope_head_dim].astype(x.dtype)
        w_uv = params["w_ukv"][:, :, m.qk_nope_head_dim:].astype(x.dtype)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)
        L = cc.shape[1]
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        scores = (jnp.einsum("bshr,blr->bhsl", q_abs, cc.astype(x.dtype))
                  + jnp.einsum("bshk,blk->bhsl", q_rope, cr.astype(x.dtype)))
        mask = jnp.where(jnp.arange(L) <= pos, 0.0, NEG_INF)[None, None, None]
        w = jax.nn.softmax(scores.astype(jnp.float32) * scale + mask,
                           -1).astype(x.dtype)
        o_latent = jnp.einsum("bhsl,blr->bshr", w, cc.astype(x.dtype))
        out = jnp.einsum("bshr,rhk->bshk", o_latent, w_uv)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return y, {"ckv": cc, "krope": cr}
