"""Sharded, atomic, async checkpointing (orbax-free).

Layout (one directory per step):

    <root>/step_000123.tmp/...   — written first
    <root>/step_000123/          — atomic rename on completion
        MANIFEST.json            — leaf paths, shapes, dtypes
        <escaped.leaf.path>.npy  — one file per pytree leaf

Production behaviors implemented:
  * atomic commit (rename) — a crash mid-write never corrupts the latest
    checkpoint; restore scans for the newest *committed* step
  * async save (background thread) — training continues while the previous
    step serializes; ``wait()`` joins before the next save or at exit
  * resharding restore — leaves are ``jax.device_put`` onto the current
    mesh/shardings, so a checkpoint written on one mesh restores onto a
    different one (elastic scaling / failure recovery path)
  * retention (keep_n) with garbage collection
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return root


def _esc(path: str) -> str:
    return path.replace("/", "%2F")


class Checkpointer:
    def __init__(self, root: str, keep_n: int = 3):
        self.root = root
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # ---------------- save ----------------
    def save(self, step: int, tree, *, blocking: bool = False):
        self.wait()
        flat = _flatten(tree)
        # materialize to host memory on the caller thread (device buffers
        # may be donated/overwritten by the next step)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def _write():
            tmp = os.path.join(self.root, f"step_{step:08d}.tmp")
            final = os.path.join(self.root, f"step_{step:08d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {}
            for k, v in host.items():
                np.save(os.path.join(tmp, _esc(k) + ".npy"), v)
                manifest[k] = {"shape": list(v.shape), "dtype": str(v.dtype)}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------- restore ----------------
    def steps(self):
        out = []
        for d in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.root, d,
                                                 "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int | None = None, shardings=None):
        """Returns the pytree; if `shardings` (pytree of NamedSharding) is
        given, leaves are device_put onto it (reshard-on-restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = os.path.join(self.root, f"step_{step:08d}")
        manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
        flat = {}
        for k, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, _esc(k) + ".npy"))
            flat[k] = arr
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            flat = {k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                    for k, v in _flatten(tree).items()}
            tree = _unflatten(flat)
        return tree

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)
