from repro.train.loop import Trainer, TrainConfig
from repro.train.fault_tolerance import StragglerMonitor, PreemptionHandler
