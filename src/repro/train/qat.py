"""Multi-stage quantization-aware training (paper §4.1).

"This, however, requires the extension of the network training to a
multistage process of 4 gradual phases of quantization-aware training."

Phases (quant.QAT_PHASES):
  0. fp32 baseline (original minGRU activations)
  1. + 2 b weights, 6 b biases
  2. + binary output activations (Θ with boxcar STE)
  3. + hard-sigmoid gate quantized to 6 b  (fully hardware-compatible)

Each phase rebuilds the network with the next QuantConfig and continues
from the previous phase's parameters (quantizers are STE wrappers around
the same latent fp32 weights, so the param pytree carries over 1:1).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mingru import MinimalistNetwork
from repro.core.quant import QAT_PHASES, QuantConfig
from repro.optim import AdamW, cosine_schedule


@dataclasses.dataclass
class QATConfig:
    dims: Sequence[int]
    phase_epochs: Sequence[int] = (12, 8, 8, 8)
    batch: int = 128
    lr: float = 2e-3
    seed: int = 0


def _batches(x, y, batch, key):
    n = x.shape[0]
    idx = np.asarray(jax.random.permutation(key, n))
    for i in range(0, n - batch + 1, batch):
        sel = idx[i:i + batch]
        yield x[sel], y[sel]


def accuracy(net, params, x, y, batch=256):
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = net(params, jnp.asarray(x[i:i + batch]))
        correct += int((np.argmax(np.asarray(logits), -1)
                        == y[i:i + batch]).sum())
    return correct / x.shape[0]


def train_qat(train_set, test_set, cfg: QATConfig,
              phases=QAT_PHASES, verbose=True):
    """Runs the gradual QAT ladder; returns (params, per-phase results)."""
    (xtr, ytr), (xte, yte) = train_set, test_set
    key = jax.random.PRNGKey(cfg.seed)
    params = None
    results = []
    for phase_i, (qcfg, epochs) in enumerate(zip(phases, cfg.phase_epochs)):
        net = MinimalistNetwork(cfg.dims, qcfg=qcfg)
        if params is None:
            params = net.init(jax.random.fold_in(key, 7))
        total_steps = max(1, epochs * (xtr.shape[0] // cfg.batch))
        opt = AdamW(lr=cosine_schedule(cfg.lr * (0.5 ** phase_i),
                                       warmup=total_steps // 20,
                                       total=total_steps),
                    weight_decay=0.0)
        opt_state = opt.init(params)

        @jax.jit
        def step(params, opt_state, xb, yb):
            def loss_fn(p):
                logits = net(p, xb)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32))
                nll = -jnp.take_along_axis(logp, yb[:, None], -1).mean()
                return nll

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state, _ = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        for ep in range(epochs):
            ek = jax.random.fold_in(key, phase_i * 1000 + ep)
            for xb, yb in _batches(xtr, ytr, cfg.batch, ek):
                params, opt_state, loss = step(
                    params, opt_state, jnp.asarray(xb), jnp.asarray(yb))
        acc = accuracy(net, params, xte, yte)
        results.append({"phase": phase_i, "quant": dataclasses.asdict(qcfg),
                        "test_acc": acc})
        if verbose:
            print(f"QAT phase {phase_i}: test acc {acc:.4f}", flush=True)
    return params, results
