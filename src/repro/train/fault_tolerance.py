"""Fault-tolerance utilities: preemption handling, straggler detection,
simulated failure injection for tests.

At 1000+-node scale the failure model is: (a) planned preemptions (SIGTERM
with a grace period), (b) hard node loss (step crashes / collective
timeout), (c) stragglers (one host slows the synchronous step).  The
corresponding mechanisms here:

  * PreemptionHandler — catches SIGTERM/SIGINT, requests a final checkpoint
    and clean exit at the next step boundary (the JAX runtime cannot be
    safely interrupted mid-collective).
  * StragglerMonitor — rolling-median step timing; flags steps slower than
    ``threshold ×`` the median.  On a real fleet the per-host heartbeats
    feed the same interface; the mitigation hook (``on_straggler``) is where
    a production deployment triggers hot-spare swap / re-mesh (see
    train.elastic for the re-mesh path this framework implements).
  * FailureInjector — deterministic fault injection for integration tests
    (raise at step k), proving the restore-and-continue path end to end.
"""
from __future__ import annotations

import signal
import time
from collections import deque
from typing import Callable, Optional


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._orig = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._orig[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for s, h in self._orig.items():
            signal.signal(s, h)
        return False


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 3.0,
                 on_straggler: Optional[Callable] = None):
        self.times = deque(maxlen=window)
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.flagged = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.threshold * med:
                is_straggler = True
                self.flagged.append((step, dt, med))
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler

    @property
    def median(self):
        if not self.times:
            return None
        return sorted(self.times)[len(self.times) // 2]


class FailureInjector:
    """Raise RuntimeError at the given steps (once each) — test harness."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")
