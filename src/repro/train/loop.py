"""Fault-tolerant training loop.

Production behaviors (exercised by tests/test_train_loop.py):
  * restore-from-latest on start; periodic async checkpoints
  * step-crash recovery: a failing step restores the last committed
    checkpoint and continues (data order is step-keyed, so the stream
    resumes exactly — no skipped or doubled batches)
  * preemption: SIGTERM triggers checkpoint + clean exit at step boundary
  * straggler monitoring with a pluggable mitigation hook
  * microbatch gradient accumulation (jax.lax.scan over microbatches)
  * optional int8 error-feedback gradient compression on the DP all-reduce
  * mixed-precision policy, grad clipping, cosine schedule
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.optim import AdamW
from repro.optim.compress import compress_grads, init_error
from repro.train.fault_tolerance import (FailureInjector, PreemptionHandler,
                                         StragglerMonitor)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_n: int = 3
    log_every: int = 10
    microbatch: Optional[int] = None     # grad accumulation chunk (per host)
    grad_compress: bool = False
    max_failures: int = 3


def build_train_step(model, opt: AdamW, *, microbatch=None,
                     grad_compress=False):
    """Returns train_step(params, opt_state, aux_state, batch)."""

    def loss_fn(p, b):
        loss, metrics = model.loss(p, b)
        return loss, metrics

    def grads_of(params, batch):
        if microbatch is None:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        # gradient accumulation: reshape leading dim into (k, microbatch)
        def reshape(x):
            k = x.shape[0] // microbatch
            return x.reshape((k, microbatch) + x.shape[1:])

        mb = jax.tree_util.tree_map(reshape, batch)

        def body(acc, b):
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b)
            acc = jax.tree_util.tree_map(jnp.add, acc, g)
            return acc, (loss, metrics)

        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, (losses, metricss) = jax.lax.scan(body, zero, mb)
        k = jax.tree_util.tree_leaves(mb)[0].shape[0]
        grads = jax.tree_util.tree_map(lambda g: g / k, gsum)
        metrics = jax.tree_util.tree_map(lambda m: m.mean(), metricss)
        return losses.mean(), metrics, grads

    def train_step(params, opt_state, aux_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if grad_compress:
            grads, new_err = compress_grads(grads, aux_state["ef_error"])
            aux_state = dict(aux_state, ef_error=new_err)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        return params, opt_state, aux_state, dict(metrics, **opt_metrics)

    return train_step


class Trainer:
    def __init__(self, model, opt: AdamW, cfg: TrainConfig, *,
                 loader, jit_kwargs=None, failure_injector=None):
        self.model, self.opt, self.cfg, self.loader = model, opt, cfg, loader
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep_n=cfg.keep_n)
        self.monitor = StragglerMonitor()
        self.injector = failure_injector or FailureInjector()
        step_fn = build_train_step(model, opt, microbatch=cfg.microbatch,
                                   grad_compress=cfg.grad_compress)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1, 2),
                               **(jit_kwargs or {}))
        self.history = []

    def _init_state(self, key):
        params = self.model.init(key)
        opt_state = self.opt.init(params)
        aux = {"ef_error": init_error(params)} if self.cfg.grad_compress \
            else {"ef_error": {}}
        return params, opt_state, aux

    def _restore_or_init(self, key):
        step = self.ckpt.latest_step()
        if step is not None:
            state = self.ckpt.restore(step)
            # numpy trees -> device
            state = jax.tree_util.tree_map(jnp.asarray, state)
            # empty subtrees (e.g. aux without compression) have no leaves
            # and are dropped by serialization — rebuild them
            aux = state.get("aux") or {"ef_error": {}}
            if self.cfg.grad_compress and not aux.get("ef_error"):
                aux = {"ef_error": init_error(state["params"])}
            return state["params"], state["opt"], aux, int(step)
        p, o, a = self._init_state(key)
        return p, o, a, 0

    def _save(self, step, params, opt_state, aux, blocking=False):
        self.ckpt.save(step, {"params": params, "opt": opt_state,
                              "aux": aux}, blocking=blocking)

    def run(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        params, opt_state, aux, start = self._restore_or_init(key)
        step = start
        failures = 0
        with PreemptionHandler() as preempt:
            while step < self.cfg.steps:
                try:
                    self.injector.maybe_fail(step)
                    t0 = time.time()
                    batch = jax.tree_util.tree_map(
                        jnp.asarray, self.loader.batch_at(step))
                    params, opt_state, aux, metrics = self.step_fn(
                        params, opt_state, aux, batch)
                    loss = float(metrics["loss"])
                    dt = time.time() - t0
                    self.monitor.record(step, dt)
                    self.history.append({"step": step, "loss": loss,
                                         "dt": dt})
                    if step % self.cfg.log_every == 0:
                        print(f"step {step:6d} loss {loss:.4f} "
                              f"({dt*1e3:.0f} ms)", flush=True)
                    step += 1
                    if step % self.cfg.ckpt_every == 0:
                        self._save(step, params, opt_state, aux)
                    if preempt.requested:
                        print("preemption requested — checkpointing")
                        self._save(step, params, opt_state, aux,
                                   blocking=True)
                        return params, step
                except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                    failures += 1
                    if failures > self.cfg.max_failures:
                        raise
                    print(f"step {step} failed ({e}); restoring last "
                          f"checkpoint", flush=True)
                    self.ckpt.wait()
                    params, opt_state, aux, step = self._restore_or_init(key)
        self.ckpt.wait()
        self._save(step, params, opt_state, aux, blocking=True)
        return params, step
