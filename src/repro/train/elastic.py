"""Elastic scaling: re-mesh and reshard a running job's state.

When nodes are lost (or added), the job rebuilds a smaller/larger mesh and
re-lays-out params + optimizer state.  With jax.sharding this is a
``device_put`` of every leaf onto the new NamedSharding — the checkpointing
layer supports the same path across restarts (Checkpointer.restore with new
shardings).  The policy implemented here:

  * the "model" axis is preserved (TP degree is architecture-bound:
    re-sharding TP changes per-op tile shapes and is rarely worth it live);
  * the "data"/"pod" product shrinks to the largest size that divides the
    remaining device count — DP is the elastic axis;
  * the global batch is kept constant by raising gradient-accumulation
    steps on the surviving hosts (tokens/step invariant ⇒ loss curves are
    comparable across the resize).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.parallel import sharding as shd


@dataclasses.dataclass
class ElasticPlan:
    new_data: int
    new_model: int
    accum_multiplier: int


def plan_remesh(n_devices_left: int, model_size: int,
                old_data: int) -> ElasticPlan:
    """Largest DP degree that fits the surviving devices (TP preserved)."""
    assert n_devices_left >= model_size, "cannot keep TP degree"
    new_data = n_devices_left // model_size
    # keep global batch: accumulate more on the fewer replicas
    mult = int(np.ceil(old_data / new_data))
    return ElasticPlan(new_data=new_data, new_model=model_size,
                       accum_multiplier=mult)


def make_elastic_mesh(plan: ElasticPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    n = plan.new_data * plan.new_model
    dev = np.asarray(devices[:n]).reshape(plan.new_data, plan.new_model)
    return jax.sharding.Mesh(dev, ("data", "model"))


def reshard_tree(tree, spec_tree, new_mesh):
    """device_put every leaf onto the new mesh (the live re-mesh path)."""
    sh = shd.named_sharding_tree(spec_tree, new_mesh)
    flat_t, td = jax.tree_util.tree_flatten(tree)
    flat_s = td.flatten_up_to(sh)
    return td.unflatten([jax.device_put(t, s)
                         for t, s in zip(flat_t, flat_s)])


def elastic_restart(model, params, opt_state, *, lost_devices: int,
                    mesh, rules=None):
    """Simulate losing `lost_devices` and re-laying-out the state.

    Returns (new_mesh, params, opt_state, plan). Used by the integration
    test with host devices; on a real fleet the surviving processes call
    this after the runtime re-initializes with the reduced slice.
    """
    info = dict(mesh.shape)
    model_size = info.get("model", 1)
    old_data = info.get("data", 1) * info.get("pod", 1)
    n_left = int(np.prod(list(info.values()))) - lost_devices
    plan = plan_remesh(n_left, model_size, old_data)
    new_mesh = make_elastic_mesh(plan)

    p_shapes = jax.eval_shape(lambda: params)
    p_spec = shd.param_specs(model, p_shapes, new_mesh, rules)
    params = reshard_tree(params, p_spec, new_mesh)
    o_spec = {"m": p_spec, "v": p_spec,
              "step": jax.sharding.PartitionSpec()}
    opt_state = reshard_tree(opt_state, o_spec, new_mesh)
    return new_mesh, params, opt_state, plan
