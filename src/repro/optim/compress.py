"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+-node scale the gradient all-reduce over the (pod, data) axes is
the dominant inter-pod traffic; int8 compression cuts wire bytes 4× vs
fp32.  Scheme (1-bit-Adam / EF-SGD family):

    c_t      = quantize_int8(g_t + e_{t-1})          (per-tensor scale)
    e_t      = (g_t + e_{t-1}) − dequant(c_t)        (error feedback)
    g̃_t      = all-reduce-mean(dequant(c_t))

The quantized payload is what crosses the wire (inside shard_map the
psum operand is the int8-scaled tensor reconstructed at fp32 after local
dequantization — XLA transfers the int8 buffer for the all_gather path).
Error feedback keeps the *accumulated* quantization error bounded, so
convergence matches uncompressed SGD/Adam to first order.

Used by train.loop when ``grad_compress=True``; tests verify the error
feedback invariant: sum_t dequant(c_t) == sum_t g_t + e_T (exactly, up to
float rounding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error):
    """Returns (compressed-dequantized grads, new error feedback state).

    The returned grads are the values to feed the (mean) all-reduce; the
    int8 payload is materialized so XLA can move 1-byte buffers on the
    wire when the reduce is lowered as gather+local-sum.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), target - deq

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])
