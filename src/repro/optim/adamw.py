"""AdamW with global-norm clipping and cosine schedule (optax-free).

Optimizer state mirrors the param pytree (m, v), so the sharding layer can
shard it with the same rules as the params — or, with ``zero1=True``, shard
the state additionally over the data axis (ZeRO-1 style) to cut per-device
optimizer memory by the DP degree (see parallel.sharding.opt_state_specs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: Optional[float] = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state, params):
        """Returns (new_params, new_state, metrics)."""
        gn = jnp.zeros(())
        if self.max_grad_norm is not None:
            grads, gn = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, {
            "grad_norm": gn, "lr": lr}
