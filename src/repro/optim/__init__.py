from repro.optim.adamw import AdamW, cosine_schedule, clip_by_global_norm
