"""Continuous-batching streaming inference for O(1)-state recurrent stacks.

The paper's central serving property — the minGRU collapses to a single
constant-memory recurrent step — is what makes slot-based continuous
batching trivial here: a slot is (hidden state, position), admission is a
state write, retirement is a state free.  No paged KV allocator needed for
the pure recurrent stacks; attention stacks ride along behind the same
StepModel protocol with per-slot position tracking.

  * :mod:`repro.serve.protocol` — the StepModel contract + adapters for
    DecoderLM (LM generation) and MinimalistNetwork (frame streaming)
  * :mod:`repro.serve.prefill`  — chunked prompt prefill (one linear_scan
    per chunk instead of a per-token Python loop)
  * :mod:`repro.serve.engine`   — the fixed-capacity slot scheduler
"""
from repro.serve.engine import Request, ServeEngine
from repro.serve.prefill import chunked_prefill
from repro.serve.protocol import (DecoderStepModel, MinimalistStepModel,
                                  StepModel)

__all__ = ["Request", "ServeEngine", "chunked_prefill", "StepModel",
           "DecoderStepModel", "MinimalistStepModel"]
