"""Continuous-batching streaming inference for O(1)-state recurrent stacks.

The paper's central serving property — the minGRU collapses to a single
constant-memory recurrent step — is what makes slot-based continuous
batching trivial here: a slot is (hidden state, position), admission is a
state write, retirement is a state free.  No paged KV allocator needed for
the pure recurrent stacks; attention stacks ride along behind the same
StepModel protocol with per-slot position tracking.

The serving stack is layered (README §Scheduling & preemption):

  * :mod:`repro.serve.state`     — SlotTable/Request: host-side slot +
    request lifecycle state (the STATE layer)
  * :mod:`repro.serve.scheduler` — SchedulingPolicy (fifo / priority /
    sjf / edf): admission order + preemption victims (the SCHEDULER
    layer)
  * :mod:`repro.serve.spec`      — speculative decoding: the minGRU
    drafter proposing k-token waves the target verifies in one call
    (README §Speculative decoding)
  * :mod:`repro.serve.engine`    — the fixed-capacity engine driving
    the jitted step/write/prefill programs (the EXECUTOR layer)
  * :mod:`repro.serve.protocol`  — the StepModel contract + adapters for
    DecoderLM (LM generation) and MinimalistNetwork (frame streaming)
  * :mod:`repro.serve.sampling`  — per-request temperature/top-k/top-p
    with a counter-based PRNG (fold_in(seed, uid, pos)): reproducible
    per request, retrace-free in the slot batch
  * :mod:`repro.serve.prefill`   — grid-padded masked chunked prefill
    (one linear_scan / K-V block write per chunk; exactly one compiled
    chunk shape across ragged prompt lengths)
  * :mod:`repro.serve.paged`     — paged KV cache for the attention
    stacks: refcounted block-table page allocator + page pools, so cache
    memory scales with LIVE tokens instead of slots × max_len (the
    O(1)-state paths never needed it and are untouched), plus the
    hash-keyed prefix cache behind ``ServeEngine(prefix_cache=True)``
    and the copy-on-write page sharing behind ``ServeEngine.fork``
"""
from repro.configs.base import SamplingParams
from repro.serve.engine import EngineStats, Request, ServeEngine
from repro.serve.paged import PagedConfig, PagePool, PrefixCache
from repro.serve.prefill import chunked_prefill
from repro.serve.protocol import (DecoderStepModel, MinimalistStepModel,
                                  ServeShardings, StepModel)
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import (POLICIES, EDFPolicy, FIFOPolicy,
                                   PriorityPolicy, SchedulingPolicy,
                                   SJFPolicy, make_policy)
from repro.serve.spec import DraftStepModel
from repro.serve.state import SlotTable
from repro.serve.telemetry import (NULL_TELEMETRY, MetricsRegistry,
                                   NullTelemetry, PercentileWindow,
                                   RateWindow, StatsSink, Telemetry)

__all__ = ["Request", "SamplingParams", "ServeEngine", "ServeShardings",
           "chunked_prefill", "sample_tokens", "StepModel",
           "DecoderStepModel", "MinimalistStepModel", "DraftStepModel",
           "PagedConfig", "PagePool", "PrefixCache", "EngineStats",
           "SlotTable", "SchedulingPolicy", "FIFOPolicy",
           "PriorityPolicy", "SJFPolicy", "EDFPolicy", "POLICIES",
           "make_policy", "Telemetry", "NullTelemetry", "NULL_TELEMETRY",
           "MetricsRegistry", "RateWindow", "PercentileWindow",
           "StatsSink"]
