"""Host-side observability for the serving engine.

Three pieces, all of them OFF the device path:

  * :class:`MetricsRegistry` — counters, gauges and bounded-reservoir
    histograms (TTFT, ITL, queue wait, prefill/step wall time, ...).
    Bounded means a histogram never grows past ``reservoir`` samples —
    a week-long serving process cannot leak memory through telemetry.
  * :class:`Telemetry` — the handle the engine (and the SlotTable, page
    pool, prefix cache, scheduler policies and drafter) call into.  It
    optionally carries a :class:`~repro.common.trace.TraceRecorder`
    (Chrome trace_event JSON — request-lifecycle spans on one track per
    request, admission/decode waves on the engine track) and a
    :class:`StatsSink` (the periodic stats line).
  * :data:`NULL_TELEMETRY` — the no-op default.  Every instrumentation
    site in the engine is either a method on this object (pure ``pass``)
    or guarded by ``telemetry.enabled``; a disabled engine pays an
    attribute load and a branch per site, nothing else.

The contract that makes instrumentation safe to leave on in
production: telemetry NEVER touches the jitted programs.  Every hook
runs host-side around (never inside) device calls, so enabling a trace
cannot change a single emitted token (the bitwise determinism
contracts hold with tracing on) and cannot retrace the one compiled
decode step — ``tests/test_serve_telemetry.py`` pins both.

:class:`RateWindow` / :class:`PercentileWindow` are the bounded
rate-stream primitives behind ``EngineStats`` (tokens/s over a sliding
event window; queue-wait percentiles over a sliding sample window) —
extracted here so the autoscaling loop the ROADMAP names can consume
them directly.
"""
from __future__ import annotations

import sys
import time
from collections import deque
from typing import Any, Dict, Optional

import numpy as np

from repro.common.trace import TraceRecorder

__all__ = ["Telemetry", "NullTelemetry", "NULL_TELEMETRY",
           "MetricsRegistry", "RateWindow", "PercentileWindow",
           "StatsSink"]


class RateWindow:
    """Windowed event rate: ``push(t, n)`` records ``n`` units at
    monotonic time ``t``; ``per_s()`` is units/second over the window.

    The window is the last ``maxlen`` events.  The FIRST retained
    event only anchors the window's start time — its units predate the
    window, so they are excluded from the numerator.  Degenerate
    windows (fewer than two events, zero or negative span — a clock
    that failed monotonicity) report 0.0 rather than inf/garbage.
    """

    def __init__(self, maxlen: int = 256):
        self.events: deque = deque(maxlen=int(maxlen))

    def __len__(self):
        return len(self.events)

    def push(self, t: float, n: int):
        self.events.append((float(t), int(n)))

    def per_s(self) -> float:
        if len(self.events) < 2:
            return 0.0
        span = self.events[-1][0] - self.events[0][0]
        if span <= 0:
            return 0.0
        it = iter(self.events)
        next(it)
        return sum(n for _t, n in it) / span


class PercentileWindow:
    """Bounded sample reservoir with percentile readout (sliding window
    of the last ``maxlen`` samples; empty windows report 0.0)."""

    def __init__(self, maxlen: int = 512):
        self.values: deque = deque(maxlen=int(maxlen))
        self.n_total = 0                  # samples ever observed

    def __len__(self):
        return len(self.values)

    def push(self, v: float):
        self.values.append(float(v))
        self.n_total += 1

    def percentile(self, q: float) -> float:
        if not self.values:
            return 0.0
        return float(np.percentile(np.asarray(self.values, np.float64),
                                   q))

    def percentiles(self, qs) -> tuple:
        if not self.values:
            return tuple(0.0 for _ in qs)
        a = np.asarray(self.values, np.float64)
        return tuple(float(np.percentile(a, q)) for q in qs)

    def summary(self) -> Dict[str, float]:
        p50, p99, mx = ((*self.percentiles((50, 99)),
                         float(max(self.values)))
                        if self.values else (0.0, 0.0, 0.0))
        return {"count": self.n_total, "p50": p50, "p99": p99, "max": mx}


class MetricsRegistry:
    """Counters / gauges / bounded histograms, keyed by name.

    Names are created on first use — instrumentation sites never need
    registration boilerplate, and ``as_dict()`` returns exactly what
    was touched."""

    def __init__(self, reservoir: int = 512):
        self.reservoir = int(reservoir)
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, PercentileWindow] = {}

    def inc(self, name: str, n: int = 1):
        self.counters[name] = self.counters.get(name, 0) + int(n)

    def gauge(self, name: str, value: float):
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float):
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = PercentileWindow(self.reservoir)
        h.push(value)

    def as_dict(self) -> Dict[str, Any]:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.summary()
                               for k, h in self.histograms.items()}}


class StatsSink:
    """Periodic ``EngineStats.line()`` sink with an injectable stream.

    ``stream=None`` resolves to the CURRENT ``sys.stdout`` at emit time
    (so pytest's capsys and shell redirects both see it); ``every=N``
    prints one line per N emit calls — the periodic stats line for
    long runs.  This replaces the engine's old hardwired
    ``print(self.stats().line())``."""

    def __init__(self, stream=None, every: int = 1):
        self.stream = stream
        self.every = max(1, int(every or 1))
        self.n_calls = 0
        self.n_lines = 0

    def emit(self, stats, force: bool = False):
        self.n_calls += 1
        if not force and self.n_calls % self.every:
            return
        print(stats.line(),
              file=self.stream if self.stream is not None else sys.stdout)
        self.n_lines += 1


class _NullSpan:
    """Reusable no-op span — the disabled path allocates nothing."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """Context-managed B/E pair; ``set()`` attaches end-time args
    (counts known only when the wave finishes)."""
    __slots__ = ("_tr", "name", "tid", "args", "end_args")

    def __init__(self, tr, name, tid, args):
        self._tr = tr
        self.name = name
        self.tid = tid
        self.args = args
        self.end_args: Dict[str, Any] = {}

    def set(self, **kw):
        self.end_args.update(kw)

    def __enter__(self):
        self._tr.begin(self.name, self.tid, **self.args)
        return self

    def __exit__(self, *exc):
        self._tr.end(self.tid, name=self.name, **self.end_args)
        return False


class NullTelemetry:
    """The disabled handle: every method is a no-op, ``enabled`` is
    False so hot paths can skip building event args entirely."""

    enabled = False
    trace: Optional[TraceRecorder] = None
    registry: Optional[MetricsRegistry] = None
    stats_sink: Optional[StatsSink] = None

    ENGINE_TID = 0

    def inc(self, name, n=1):
        pass

    def gauge(self, name, value):
        pass

    def observe(self, name, value):
        pass

    def span(self, name, **args):
        return _NULL_SPAN

    def instant(self, name, **args):
        pass

    def counter(self, name, **values):
        pass

    def request_begin(self, req, name, **args):
        pass

    def request_end(self, req, **args):
        pass

    def request_instant(self, req, name, **args):
        pass


#: Module-level singleton every component defaults to.
NULL_TELEMETRY = NullTelemetry()


class Telemetry(NullTelemetry):
    """Live telemetry: a metrics registry, optionally a Chrome trace.

    ``trace=True`` builds a fresh :class:`TraceRecorder`; an existing
    recorder may be passed instead (tests inject a fake clock).
    ``stats_stream``/``stats_every`` configure the periodic stats-line
    sink (``run()`` drives it once per engine step).

    Track layout: tid 0 is the engine (admission rounds, prefill waves,
    decode/spec waves, preempt/resume, pool counters); each request
    gets its own track at ``tid = uid + 1`` holding its lifecycle span
    chain — ``queued`` → ``running`` → (``preempted`` → ``running``)*
    — with ``submit``/``finish`` instants.  Exactly one lifecycle span
    is open per request at any time, so a drained run's trace always
    passes :func:`~repro.common.trace.validate_chrome_trace`.
    """

    enabled = True

    def __init__(self, *, trace=False, reservoir: int = 512,
                 stats_stream=None, stats_every: int = 0):
        self.registry = MetricsRegistry(reservoir)
        if trace is True:
            trace = TraceRecorder()
        # explicit identity checks: an EMPTY TraceRecorder is falsy
        # (len 0), so `trace or None` would silently drop it
        self.trace = None if trace is False or trace is None else trace
        self.stats_sink = None
        if stats_stream is not None or stats_every:
            self.stats_sink = StatsSink(stats_stream,
                                        every=stats_every or 1)
        self._open: Dict[int, str] = {}   # uid -> open lifecycle span
        if self.trace is not None:
            self.trace.thread_name(self.ENGINE_TID, "engine")

    # -- metrics ---------------------------------------------------------
    def inc(self, name, n=1):
        self.registry.inc(name, n)

    def gauge(self, name, value):
        self.registry.gauge(name, value)

    def observe(self, name, value):
        self.registry.observe(name, value)

    # -- engine track ----------------------------------------------------
    def span(self, name, **args):
        if self.trace is None:
            return _NULL_SPAN
        return _Span(self.trace, name, self.ENGINE_TID, args)

    def instant(self, name, **args):
        if self.trace is not None:
            self.trace.instant(name, self.ENGINE_TID, **args)

    def counter(self, name, **values):
        if self.trace is not None:
            self.trace.counter(name, self.ENGINE_TID, **values)

    # -- request tracks --------------------------------------------------
    def _req_tid(self, req) -> int:
        return int(req.uid) + 1

    def request_begin(self, req, name, **args):
        """Open ``req``'s next lifecycle span (closing any still-open
        one first — the chain is strictly sequential per request)."""
        if self.trace is None:
            return
        tid = self._req_tid(req)
        self.trace.thread_name(tid, f"req {req.uid}")
        prev = self._open.pop(req.uid, None)
        if prev is not None:
            self.trace.end(tid, name=prev)
        self.trace.begin(name, tid, **args)
        self._open[req.uid] = name

    def request_end(self, req, **args):
        if self.trace is None:
            return
        name = self._open.pop(req.uid, None)
        if name is not None:
            self.trace.end(self._req_tid(req), name=name, **args)

    def request_instant(self, req, name, **args):
        if self.trace is not None:
            self.trace.instant(name, self._req_tid(req), **args)

    # -- export ----------------------------------------------------------
    def save_trace(self, path: str) -> str:
        """Write the Chrome JSON trace (load in Perfetto / chrome://tracing)."""
        if self.trace is None:
            raise ValueError("this Telemetry was built without a trace "
                             "(pass trace=True)")
        return self.trace.save(path)
