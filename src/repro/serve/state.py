"""Request/slot lifecycle state for the serving engine (the STATE layer).

The engine used to interleave three concerns in one class: admission
POLICY (which waiting request goes next), slot/page STATE bookkeeping
(who owns which slot, which pages, which sampling knobs), and the
EXECUTOR (the jitted step/write/prefill programs).  This module owns the
middle layer: :class:`SlotTable` holds every piece of host-side
scheduling state — the waiting queue, the free-slot bitmask, per-slot
position/budget/active arrays, per-slot sampling knob arrays, and the
page-pool interactions (release on free) — behind small explicit
mutators (:meth:`alloc_slot` / :meth:`free_slot` / :meth:`retire`).

Scheduling policies (:mod:`repro.serve.scheduler`) see exactly this
object: it is the ``state`` argument of ``admit_order(queue, state)``
and ``select_victim(state)``, so a policy can inspect occupancy, queue
depth and pool pressure without ever touching device state or the
compiled programs (those stay in the engine / StepModel).
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Callable, List, Optional

import numpy as np

from repro.configs.base import SamplingParams
from repro.serve.sampling import KNOB_DTYPES, KNOB_GREEDY
from repro.serve.telemetry import NULL_TELEMETRY


def _knob_values(req):
    """A request's per-slot knob values (schema: sampling.KNOB_DTYPES).

    The uid is folded into the counter-based PRNG key as two 32-bit
    words (low bits + the bits above them) so the FULL uid reaches the
    key — a single masked word would give requests whose uids differ by
    its period (e.g. 2**31 under the old ``& 0x7FFFFFFF`` mask)
    bitwise-identical sampled streams."""
    sp = req.sampling
    return {"seed": sp.seed, "uid": req.uid & 0xFFFFFFFF,
            "uid_hi": (req.uid >> 32) & 0xFFFFFFFF,
            "temperature": sp.temperature, "top_k": sp.top_k,
            "top_p": sp.top_p}


# eq=False: a request is its identity (uids are unique per engine, and
# the queue/slot bookkeeping matches by object) — this also keeps
# Request hashable, so callers can key dicts/sets by request
@dataclasses.dataclass(eq=False)
class Request:
    uid: int
    prompt: np.ndarray                 # (P,) int32 tokens | (P, d_in) frames
    max_new_tokens: int = 0            # 0 for pure streaming requests
    eos_id: Optional[int] = None
    # default_factory: every request owns its params instance — a shared
    # class-level default would let one request's (user-)mutated knobs
    # silently leak into every other default-sampled request
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # scheduling knobs (consumed by repro.serve.scheduler policies):
    # higher priority admits first under policy="priority"; deadline is
    # the admission key under policy="edf" (earliest first) and the
    # SLO tag the load harness scores miss rates against
    priority: int = 0
    deadline: Optional[float] = None
    # speculative decoding: per-request verify width override (None =
    # the engine's ServeConfig.spec_k; validated at submit() against the
    # engine's compiled width, so it rides as plain per-slot DATA)
    spec_k: Optional[int] = None
    # set by ServeEngine.submit() (and reset on preemption re-queue):
    # what the queue-wait percentiles in EngineStats measure
    submit_t: Optional[float] = dataclasses.field(default=None,
                                                  repr=False)
    # lifecycle timestamps (time.monotonic), set once each: submission
    # (never reset — the TTFT/e2e anchor), first emitted token, and
    # retirement.  What the ttft_ms / e2e_ms telemetry histograms read.
    created_t: Optional[float] = dataclasses.field(default=None,
                                                   repr=False)
    first_token_t: Optional[float] = dataclasses.field(default=None,
                                                       repr=False)
    finish_t: Optional[float] = dataclasses.field(default=None,
                                                  repr=False)
    # filled by the engine:
    outputs: List[Any] = dataclasses.field(default_factory=list)
    finished: bool = False
    cancelled: bool = False
    # preemption: a victim's page bytes + carry live here (host memory)
    # between eviction and re-admission; None for never-preempted requests
    snapshot: Optional[Any] = dataclasses.field(default=None, repr=False)
    n_preemptions: int = 0

    @property
    def tokens(self) -> np.ndarray:
        """Generated token ids (LM) / per-frame outputs (streaming)."""
        return np.asarray(self.outputs)

    def validate_scheduling(self):
        """Bounds for the scheduler-facing knobs — checked at submit()
        so a bad value fails with a clear error instead of surviving
        until a policy comparison (or an int32 slot-array overflow)
        deep inside admission."""
        if isinstance(self.priority, bool) or not isinstance(
                self.priority, (int, np.integer)):
            raise ValueError(
                f"priority must be an int, got {self.priority!r}")
        if not -2**31 <= int(self.priority) < 2**31:
            raise ValueError(
                f"priority must fit int32, got {self.priority}")
        if self.deadline is not None:
            d = self.deadline
            if isinstance(d, bool) or not isinstance(
                    d, (int, float, np.integer, np.floating)):
                raise ValueError(f"deadline must be a number or None, "
                                 f"got {d!r}")
            if not (math.isfinite(d) and d > 0):
                raise ValueError(
                    f"deadline must be positive and finite, got {d}")
        return self


class SlotTable:
    """Host-side slot + request state for a fixed-capacity engine.

    ``pool`` (optional) is the paged-KV :class:`~repro.serve.paged.PagePool`;
    freeing a slot releases its pages and reservation.  ``pages_for_req``
    maps a request to its worst-case page reservation (0 when unpaged) —
    the one piece of StepModel knowledge admission and victim selection
    need, injected by the engine so policies stay model-agnostic.
    """

    def __init__(self, slots: int, pool=None,
                 pages_for_req: Optional[Callable[[Request], int]] = None,
                 telemetry=None):
        self.slots = int(slots)
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        self.pool = pool
        self._pages_for_req = pages_for_req
        # no-op by default; the engine passes its handle through so slot
        # occupancy gauges track alloc/free without engine involvement
        self.telemetry = telemetry if telemetry is not None \
            else NULL_TELEMETRY
        self.free_mask = (1 << self.slots) - 1     # bit i set = slot i free
        self.waiting: deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * self.slots
        self.pos = np.zeros(self.slots, np.int32)
        self.remaining = np.zeros(self.slots, np.int64)
        self.active = np.zeros(self.slots, bool)
        # per-slot sampling knobs: plain DATA through the one jitted step
        # (greedy defaults; a sampled request overwrites them at admission)
        self.knobs = {k: np.full(self.slots, KNOB_GREEDY[k], KNOB_DTYPES[k])
                      for k in KNOB_DTYPES}
        self.cur: Optional[np.ndarray] = None      # next input per slot
        self.finished: List[Request] = []

    # -- derived views (what policies and stats() read) -----------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def n_free(self) -> int:
        return bin(self.free_mask).count("1")

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    def pages_needed(self, req: Request) -> int:
        """Worst-case page reservation ``req`` needs to admit (0 when
        the engine is unpaged)."""
        if self.pool is None or self._pages_for_req is None:
            return 0
        return self._pages_for_req(req)

    def running(self):
        """(slot, request) pairs currently active, ascending slot."""
        return [(s, r) for s, r in enumerate(self.slot_req)
                if r is not None and self.active[s]]

    # -- mutators --------------------------------------------------------
    def alloc_slot(self) -> int:
        bit = int(self.free_mask & -self.free_mask)
        self.free_mask = int(self.free_mask) ^ bit
        return bit.bit_length() - 1

    def free_slot(self, slot: int):
        self.free_mask = int(self.free_mask) | (1 << int(slot))
        self.slot_req[slot] = None
        self.active[slot] = False
        if self.pool is not None:
            # pages (and the unused reservation tail) go straight back
            # into circulation; the pool content is NOT cleared — any
            # future read of a recycled page is position-masked
            self.pool.release(slot)
        for k, v in KNOB_GREEDY.items():
            self.knobs[k][slot] = v
        if self.telemetry.enabled:
            self.telemetry.gauge("active_slots", self.n_active)
            self.telemetry.gauge("free_slots", self.n_free)

    def retire(self, slot: int) -> Request:
        req = self.slot_req[slot]
        req.finished = True
        req.finish_t = time.monotonic()
        self.finished.append(req)
        self.free_slot(slot)
        return req

    def set_sampling(self, slot: int, req: Request):
        for k, v in _knob_values(req).items():
            self.knobs[k][slot] = v

    def pop_waiting(self, req: Request):
        """Remove ``req`` from the queue (identity match — policies hand
        back the same objects they were given)."""
        if self.waiting and self.waiting[0] is req:
            self.waiting.popleft()           # the common (FIFO-head) case
            return
        self.waiting = deque(r for r in self.waiting if r is not req)

    def discard_waiting(self, req: Request) -> bool:
        """Cancel path: drop a still-queued request (identity match only
        — ``Request.__eq__`` would compare prompt arrays elementwise and
        a LOOKALIKE request must not be dequeued).  Never touches the
        pool: a queued request holds no slot, pages or reservation."""
        if not any(r is req for r in self.waiting):
            return False
        self.waiting = deque(r for r in self.waiting if r is not req)
        return True
