"""Fixed-capacity continuous-batching engine (the EXECUTOR layer).

The serving stack is split into three layers with explicit seams:

  * STATE  — :mod:`repro.serve.state`: :class:`SlotTable` owns the
    waiting queue, the free-slot bitmask, per-slot position / budget /
    sampling-knob arrays and the page-pool interactions (release on
    free) behind small explicit mutators.
  * SCHEDULER — :mod:`repro.serve.scheduler`: a
    :class:`~repro.serve.scheduler.SchedulingPolicy` orders admission
    (``admit_order``) and may name a preemption victim
    (``select_victim``).  ``policy="fifo"`` (the default) reproduces the
    historical strict-FIFO defer-at-head admission byte for byte;
    ``"priority"`` / ``"sjf"`` reorder the queue deterministically (uid
    tie-break) and, under ``priority``, evict lower-priority running
    requests when a higher-priority arrival is blocked.
  * EXECUTOR — this module: the jitted step / write / prefill paths.
    The decode step stays ONE compiled program over the full slot batch
    whose shapes never change, under every policy — scheduling decisions
    are host-side list manipulation, invisible to jit.

Preemption (paged layout only): evicting a running request snapshots
its page chain + per-slot carry to host memory (``device_get`` of
exactly its pages via the block table), releases the pages back to the
pool, and re-queues it; re-admission re-reserves what the slot held at
eviction (recorded in the snapshot), re-seeds FRESH pages with the
snapshotted bytes and resumes mid-stream with no prefill.  Reads go through the block table and the sampling PRNG is
counter-based on (seed, uid, pos), so a preempted-then-resumed stream
is bitwise-equal to one that was never disturbed.

Request lifecycle::

    submit() -> WAITING -> [admit: chunked prefill -> state write] ->
    RUNNING (slot batch decode, inactive slots masked)
       -> retire -> FINISHED (tokens / stream outputs on the host)
       -> preempt -> WAITING (snapshot held) -> resume -> RUNNING

Two request flavors, selected by the StepModel:

  * autoregressive (DecoderLM): the prompt is prefilled in chunks at
    admission; emitted tokens feed back as the next input until
    ``max_new_tokens`` (or ``eos_id``) is reached.  Each request may
    carry :class:`~repro.configs.base.SamplingParams` — the knobs ride
    as per-slot arrays through the one jitted decode step (greedy and
    sampled traffic share a single compiled program), and the PRNG is
    counter-based (fold_in(seed, uid_lo, uid_hi, pos) — the FULL
    submission uid reaches the key as two 32-bit words) so a request's
    tokens are reproducible regardless of co-batched traffic.
  * streaming (MinimalistNetwork): input frames are fed one per step —
    the paper's edge case where samples arrive in real time — and every
    per-frame output is recorded; the request retires when its stream is
    exhausted.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.common import pow2ceil
from repro.configs.base import SamplingParams
from repro.serve.sampling import KNOB_DTYPES
from repro.serve.scheduler import make_policy
from repro.serve.spec import heterogeneous_k
from repro.serve.telemetry import (NULL_TELEMETRY, PercentileWindow,
                                   RateWindow, StatsSink)
# Request/_knob_values moved to serve.state with the layer split; they
# are re-exported here because engine.py was their public home
from repro.serve.state import Request, SlotTable, _knob_values  # noqa: F401

# jitted wrappers whose compile counts engine.metrics() reports — a
# StepModel/drafter may carry any subset (getattr skips the rest)
_JIT_PROGRAMS = ("_jit_step", "_jit_write", "_jit_prefill_fast",
                 "_jit_prefill_scan", "_jit_sample", "_jit_seed",
                 "_jit_verify", "_jit_copy_slot", "_jit_copy_pages")
_DRAFT_JIT_PROGRAMS = ("_jit_propose", "_jit_install")


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """One host-side snapshot of engine occupancy (``ServeEngine.stats()``).

    Replaces the bare ``utilization()`` readout: the load harness and
    ``run(verbose=True)`` record these per wave, and the pool fields are
    what a capacity planner actually needs (pages, not a ratio)."""

    policy: str
    n_steps: int
    slots: int
    active_slots: int
    queue_depth: int
    pages_in_use: int          # 0 when unpaged
    pages_free: int            # 0 when unpaged
    pages_reserved: int        # 0 when unpaged
    n_preemptions: int
    utilization: float         # decode tokens per slot-step paid
    # requests that finished after their submit(deadline=...) step count
    # elapsed on the engine's step clock (0 when no deadlines are set)
    deadline_misses: int = 0
    # rate stream (what an autoscaler actually acts on): windowed decode
    # throughput, submit->admission wait percentiles, and the speculative
    # draft-acceptance rate (0 when no drafter is configured)
    tokens_per_s: float = 0.0
    queue_wait_p50_ms: float = 0.0
    queue_wait_p99_ms: float = 0.0
    accept_rate: float = 0.0

    def line(self) -> str:
        """Compact single-line rendering for ``run(verbose=True)``."""
        return (f"[{self.policy} step {self.n_steps}] "
                f"slots {self.active_slots}/{self.slots} "
                f"queue {self.queue_depth} "
                f"pages {self.pages_in_use} used / {self.pages_free} "
                f"free / {self.pages_reserved} reserved "
                f"preempt {self.n_preemptions} "
                f"util {self.utilization:.2f} "
                f"tok/s {self.tokens_per_s:.0f} "
                f"qwait {self.queue_wait_p50_ms:.1f}/"
                f"{self.queue_wait_p99_ms:.1f}ms "
                f"accept {self.accept_rate:.2f}")


class ServeEngine:
    """Continuous-batching engine over any :class:`StepModel`.

    ``mesh=`` serves under a :class:`jax.sharding.Mesh`: the StepModel is
    bound to it (``bind_mesh``) so parameters TP-shard over "model" via
    the model's logical-axis rule tables, the slot-batch state DP-shards
    its slot axis over "data", and every host-side transfer (prompts,
    next tokens, sampling knobs) is device_put against the slot sharding
    — the decode step stays ONE compiled (now SPMD) program.  On a 1×1
    mesh this is bitwise identical to the no-mesh engine; the semantics
    (admission, retirement, per-request reproducibility) never change.

    ``policy=`` selects the admission/preemption policy: a name from
    :data:`repro.serve.scheduler.POLICIES` ("fifo" default, "priority",
    "sjf") or a :class:`~repro.serve.scheduler.SchedulingPolicy`
    instance.
    """

    def __init__(self, step_model, params, *, slots: int = 8, mesh=None,
                 prefix_cache: bool = False, policy="fifo",
                 drafter=None, drafter_params=None, spec_k: int = 1,
                 telemetry=None):
        self.sm = step_model
        self.slots = int(slots)
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        # observability handle (serve.telemetry): no-op by default, and
        # NEVER on the jitted path — every hook below runs host-side
        # around device calls, so tracing cannot move a bit or retrace
        self.telemetry = telemetry if telemetry is not None \
            else NULL_TELEMETRY
        self.policy = make_policy(policy)
        self.policy.telemetry = self.telemetry
        self.spec_k = int(spec_k)
        self.drafter = drafter
        self.draft_params = drafter_params
        if drafter is None:
            if self.spec_k != 1:
                raise ValueError(
                    f"spec_k={spec_k} needs a drafter (spec_k == 1 is "
                    "plain decode)")
        else:
            self._check_spec_compat(step_model, drafter, prefix_cache)
        if mesh is not None:
            step_model.bind_mesh(mesh, self.slots)
        self.mesh = step_model.mesh
        self.params = step_model.place_params(params)
        # paged KV layout: the engine owns the page allocator — block
        # tables, free list and per-slot chains live here on the host;
        # only the page POOLS are device state (inside self.state)
        self.pool = None
        if getattr(step_model, "kv_layout", "dense") == "paged":
            from repro.serve.paged import PagePool
            self.pool = PagePool(step_model.num_pages(self.slots),
                                 self.slots, step_model.max_pages)
            self.pool.telemetry = self.telemetry
        self.prefix_cache = None
        if prefix_cache:
            if self.pool is None:
                raise ValueError(
                    "prefix_cache=True needs kv_layout='paged'")
            step_model.check_prefix_cacheable()
            from repro.serve.paged import PrefixCache
            # window-bearing stacks overwrite ring slots during prefill,
            # so only end-of-prompt page state is cacheable (and the
            # tail must start exactly at the attach point)
            self.prefix_cache = PrefixCache(
                self.pool, step_model.paged.page_size,
                full_prompt_only=step_model._has_window)
            self.prefix_cache.telemetry = self.telemetry
        self.state = step_model.init_state(self.slots)
        self.st = SlotTable(self.slots, pool=self.pool,
                            pages_for_req=self._pages_for_req,
                            telemetry=self.telemetry)
        self._uid = 0
        # speculative decoding: the drafter's stacked-carry store, the
        # per-slot resume index into its K axis, and each slot's own
        # verify width (plain DATA through the fixed-K verify program)
        if self.drafter is not None:
            self.draft_store = self.drafter.init_store(self.slots)
            self.drafter.telemetry = self.telemetry
            self._draft_sel = np.zeros(self.slots, np.int32)
            self._req_k = np.ones(self.slots, np.int32)
        # telemetry
        self.n_steps = 0
        self.n_emitted = 0          # all tokens, incl. admission prefill
        self._n_decoded = 0         # tokens emitted by slot-batch steps
        self.n_prefix_hits = 0      # admissions that attached to cache
        self.n_prefix_tokens = 0    # prompt positions skipped by attaches
        self.n_cow_copies = 0       # device page copies (decode COW)
        self.n_forks = 0
        self.n_preemptions = 0      # victims evicted by the policy
        self.n_drafts_proposed = 0  # drafter tokens offered to verify
        self.n_drafts_accepted = 0  # ... that the target accepted
        self.n_deadline_misses = 0  # finished past deadline (step clock)
        # rate stream (EngineStats): bounded windows — (wall time, tokens
        # decoded) per step, and submit->admission waits in milliseconds
        self._rate = RateWindow(maxlen=256)
        self._queue_wait = PercentileWindow(maxlen=512)
        # jit compile counts last seen, per program — deltas become
        # telemetry jit_compiles events (metrics() reads live counts)
        self._jit_seen: Dict[str, int] = {}
        self._verbose_sink: Optional[StatsSink] = None

    def _check_spec_compat(self, step_model, drafter, prefix_cache):
        """Everything speculative decoding requires of the target, checked
        at CONSTRUCTION with specific errors (no request ever burns a uid
        against an engine that cannot verify it)."""
        if self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
        if getattr(drafter, "k", None) != self.spec_k:
            raise ValueError(
                f"drafter was built for spec_k={getattr(drafter, 'k', None)}"
                f" but the engine asks {self.spec_k} — the stacked-carry "
                "store and the verify program share one K")
        if not getattr(step_model, "autoregressive", False):
            raise ValueError("speculative decoding applies to "
                             "autoregressive LM targets only")
        if getattr(step_model, "kv_layout", "dense") != "paged":
            raise ValueError(
                "speculative decoding needs kv_layout='paged': rejection "
                "rollback = not committing pages (the dense layout writes "
                "in-place during decode)")
        if prefix_cache:
            raise ValueError("speculative decoding and prefix_cache are "
                             "mutually exclusive (singleton admission "
                             "waves; lift when needed)")
        if step_model.model.cfg.kv_dtype != "bf16":
            raise ValueError(
                f"speculative verify does not support kv_dtype="
                f"{step_model.model.cfg.kv_dtype!r}: the k-token snapshot "
                "overlay reads raw pool rows (quantized pools would need "
                "an in-graph dequant overlay)")
        o1 = sorted(set(step_model._slot_axis) - step_model._pool_names)
        if o1:
            raise ValueError(
                f"speculative targets must be attention-only stacks: "
                f"layers {o1} carry O(1) mixer state whose carry cannot "
                "be rolled back to an accepted prefix")
        if drafter.vocab != step_model.vocab:
            raise ValueError(
                f"drafter vocab ({drafter.vocab}) != target vocab "
                f"({step_model.vocab}): draft token ids must BE target "
                "token ids")
        rings = getattr(step_model, "_ring_lens", [])
        if rings and self.spec_k > min(rings):
            raise ValueError(
                f"spec_k={self.spec_k} exceeds the shortest sliding-"
                f"window ring ({min(rings)}): two speculative tokens "
                "would alias one ring slot in the verify overlay")

    # -- back-compat views onto the SlotTable ---------------------------
    # (tests and user code address scheduling state through the engine;
    # the STATE layer owns it, these read straight through)
    @property
    def free_mask(self) -> int:
        return self.st.free_mask

    @property
    def waiting(self):
        return self.st.waiting

    @property
    def slot_req(self):
        return self.st.slot_req

    @property
    def pos(self):
        return self.st.pos

    @property
    def remaining(self):
        return self.st.remaining

    @property
    def active(self):
        return self.st.active

    @property
    def knobs(self):
        return self.st.knobs

    @property
    def finished(self):
        return self.st.finished

    @property
    def _cur(self):
        return self.st.cur

    @_cur.setter
    def _cur(self, v):
        self.st.cur = v

    def _pages_for_req(self, req: Request) -> int:
        """Worst-case reservation: prompt + full budget for a fresh
        request; for a preempted one, the ORIGINAL reservation its slot
        held at eviction (recorded in the snapshot).  The two differ for
        fork children: a child's ``max_new_tokens`` counts from the FORK
        POINT while its chain covers every position up to there, so the
        prompt+budget formula would under-reserve it and restore (or a
        later decode append) would die in ``pool.grow``.  Re-reserving
        exactly what the slot held keeps the guarantee that the live
        chain never exceeds the reservation, so restore cannot fail
        mid-resume."""
        if self.pool is None:
            return 0
        if req.snapshot is not None:
            return req.snapshot["reserve"]
        return self.sm.pages_for(len(req.prompt) + req.max_new_tokens)

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 0,
               eos_id: Optional[int] = None,
               sampling: Optional[SamplingParams] = None, *,
               priority: int = 0,
               deadline: Optional[float] = None,
               spec_k: Optional[int] = None) -> Request:
        prompt = np.asarray(prompt)
        # speculative width override: validated against the engine's
        # compiled width BEFORE the uid burns (like every other reject)
        if spec_k is not None:
            if isinstance(spec_k, bool) or not isinstance(
                    spec_k, (int, np.integer)):
                raise ValueError(f"spec_k must be an int, got {spec_k!r}")
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            if spec_k > self.spec_k:
                raise ValueError(
                    f"spec_k={spec_k} exceeds the engine's verify width "
                    f"({self.spec_k}) — per-request widths may only "
                    "shrink the compiled K, never grow it")
        # ndim first: len() of a 0-d array raises TypeError, and a bare
        # scalar submission deserves the same clean rejection as []
        if prompt.ndim < 1 or prompt.size < 1:
            raise ValueError("empty prompt")
        if sampling is None:
            sampling = SamplingParams()    # fresh instance per request
        else:
            sampling.validate()
            if not self.sm.autoregressive:
                raise ValueError(
                    "sampling only applies to autoregressive requests")
        if self.sm.autoregressive:
            if prompt.ndim != 1:
                raise ValueError(
                    f"LM requests need a 1-D token prompt, got shape "
                    f"{prompt.shape}")
            if max_new_tokens < 1:
                raise ValueError(
                    f"LM requests need max_new_tokens >= 1, got "
                    f"{max_new_tokens}")
            prompt = prompt.astype(np.int32)
            # attention-bearing stacks write K/V at absolute positions:
            # past max_len the scatter would silently clamp / wrap and the
            # stream would decode garbage mid-request — reject up front
            if getattr(self.sm, "positional", False):
                need = len(prompt) + max_new_tokens
                if need > self.sm.max_len:
                    raise ValueError(
                        f"prompt ({len(prompt)}) + max_new_tokens "
                        f"({max_new_tokens}) = {need} cache positions, "
                        f"but the engine was built with "
                        f"max_len={self.sm.max_len}")
                # paged note: this bound is also what makes page OOM
                # impossible past this point — PagedConfig.validate_for
                # guarantees the pool holds one max-length request, so
                # any request accepted here fits an empty pool and
                # admission only ever DEFERS (see admit())
        req = Request(self._uid, prompt, max_new_tokens, eos_id, sampling,
                      priority=priority, deadline=deadline, spec_k=spec_k)
        req.validate_scheduling()          # raises BEFORE the uid burns
        self._uid += 1
        req.submit_t = time.monotonic()
        req.created_t = req.submit_t       # TTFT/e2e anchor (never reset)
        self.st.waiting.append(req)
        tel = self.telemetry
        if tel.enabled:
            tel.inc("requests_submitted")
            tel.gauge("queue_depth", self.st.queue_depth)
            tel.request_instant(req, "submit", prompt=len(prompt),
                                max_new_tokens=int(max_new_tokens),
                                priority=req.priority)
            tel.request_begin(req, "queued")
        return req

    def _wave_sampling(self, group, pad_len):
        """Per-request sampling knob arrays for an admission wave (padding
        rows replicate the last request; their draws are discarded).
        Built as numpy first so handing them to jit is a plain device put
        (a list literal would trace a tiny convert program per wave size)."""
        reqs = [r for r, _s in group]
        reqs += [reqs[-1]] * (pad_len - len(group))
        vals = [_knob_values(r) for r in reqs]
        return {k: np.asarray([v[k] for v in vals], KNOB_DTYPES[k])
                for k in KNOB_DTYPES}

    def _pad_slots(self, slots):
        """Pad an admission wave's slot list to a power of two with
        out-of-bounds indices — the scatter drops them, and jit compiles
        at most log2(slots) admission shapes per prompt-length bucket."""
        padded = np.full(pow2ceil(len(slots)), self.slots, np.int32)
        padded[:len(slots)] = slots
        return padded

    def admit(self):
        """Move waiting requests into free slots until no further
        progress is possible.  Looping matters: a slot freed MID-wave
        (eos or ``max_new_tokens==1`` on the wave's first sampled token
        retires it inside the prefill loop) refills in the SAME call
        instead of idling for a whole decode step.

        When admission stalls, the policy may name a running victim to
        PREEMPT (``select_victim``); its eviction frees a slot + pages
        and admission retries.  Termination: each pass either admits a
        request or shrinks the running set, and ``select_victim``
        returning None ends the round."""
        self.policy.begin_round(self.st)
        while True:
            if self._admit_once():
                continue
            victim = self.policy.select_victim(self.st)
            if victim is None:
                break
            self._preempt(victim)

    def _admit_once(self) -> bool:
        """One admission wave: same-length prompts prefill as one batched
        chunked call, their carries land in one scatter write, and the
        wave costs one host sync — admission overhead amortizes over the
        wave.  Returns True iff at least one request was admitted (or a
        preempted one resumed).

        The POLICY picks the wave: admission tries candidates in
        ``policy.admit_order`` and stops at the first it cannot place —
        under "fifo" that is exactly the historical strict-FIFO
        defer-at-head loop (no bypass by smaller requests behind the
        head; head-of-line blocking is the price of starvation-freedom).

        Paged KV: admission additionally RESERVES the request's
        worst-case page chain (prompt + full generation budget) — the
        FULL worst case even when a prefix attach or fork will share
        pages, so sharing is an opportunistic saving, never load-bearing
        capacity, and decode-time page appends / COW copies can never
        fail.  Requests that can never fit were already rejected at
        submit().

        Prefix caching runs SINGLETON waves (one request per wave, in
        policy order): each admission inserts its prompt's pages before
        the next request's cache lookup, so same-batch duplicates hit
        too."""
        st = self.st
        admitted = []
        resumed = False
        while st.waiting and st.free_mask:
            req = self.policy.admit_order(st.waiting, st)[0]
            if self.pool is not None and not self.pool.can_admit(
                    self._pages_for_req(req)):
                break                      # defer until pages free up
            st.pop_waiting(req)
            if req.submit_t is not None:
                wait_ms = (time.monotonic() - req.submit_t) * 1000.0
                self._queue_wait.push(wait_ms)
                if self.telemetry.enabled:
                    self.telemetry.observe("queue_wait_ms", wait_ms)
            slot = st.alloc_slot()
            if self.pool is not None:
                self.pool.reserve(slot, self._pages_for_req(req))
            st.slot_req[slot] = req
            if req.snapshot is not None:
                self._resume(req, slot)    # no prefill: pages re-seed
                resumed = True
                continue
            st.active[slot] = True
            if self.telemetry.enabled:
                self.telemetry.request_begin(req, "running", slot=slot)
            admitted.append((req, slot))
            if st.cur is None:
                shape = (self.slots,) + tuple(req.prompt.shape[1:])
                st.cur = np.zeros(shape, req.prompt.dtype)
            if self.prefix_cache is not None:
                break                      # singleton waves (see above)
        if not admitted:
            return resumed
        if not self.sm.autoregressive:
            # streaming: blank state reset for the whole wave in one write
            slots = [s for _r, s in admitted]
            pad = self._pad_slots(slots)
            blank = self.sm.init_state(len(pad))
            self.state = self.sm.write_slots(self.state, blank, pad)
            for req, slot in admitted:
                st.pos[slot] = 0
                st.remaining[slot] = len(req.prompt)
                st.cur[slot] = req.prompt[0]
            return True
        groups: dict = {}
        for req, slot in admitted:
            groups.setdefault(len(req.prompt), []).append((req, slot))
        tel = self.telemetry
        for plen, group in groups.items():
            cw = self.sm.chunk_for(plen)
            t0 = time.monotonic() if tel.enabled else 0.0
            with tel.span("prefill", plen=plen, wave=len(group),
                          chunk_w=cw, chunks=-(-plen // cw)) as sp:
                pages = None
                if self.prefix_cache is not None:
                    req0, slot0 = group[0]  # singleton wave (see above)
                    pages, attach = self.prefix_cache.match(
                        req0.prompt, cw)
                if pages is not None:
                    last, carry = self._attach_prefill(req0, slot0,
                                                       pages, attach)
                    sp.set(attached=attach)
                else:
                    if self.pool is not None:
                        for _r, s in group:
                            self.pool.grow(s, self.sm.pages_for(plen))
                    prompts = [r.prompt for r, _s in group]
                    prompts += [prompts[-1]] * (
                        len(self._pad_slots([s for _r, s in group]))
                        - len(group))
                    last, carry = self.sm.prefill(self.params,
                                                  np.stack(prompts))
                self._install_wave(plen, group, last, carry)
            if tel.enabled:
                tel.observe("prefill_ms",
                            (time.monotonic() - t0) * 1000.0)
        return True

    def _attach_prefill(self, req, slot, pages, attach):
        """Prefix-cache hit: share the resident pages into ``slot``,
        reconstruct the dense cache they hold, and prefill only the tail
        chunks — the attached stream is bitwise the stream a full
        prefill would have produced (same chunk grid, same bytes)."""
        sm, plen = self.sm, len(req.prompt)
        self.pool.share(slot, pages)
        # gather BEFORE any detach below rewires the block-table row
        seed = sm.seed_cache(self.state,
                             self.pool.block_tables[slot:slot + 1])
        self.pool.grow(slot, sm.pages_for(plen))
        if sm._has_window:
            # ring pages diverge from the entry's frozen bytes the moment
            # the tail writes — detach them, with no device copy: the
            # wave write below rewrites every chain page for every leaf
            for i in range(len(pages)):
                self.pool.cow(slot, i, materialize=False)
            start = attach
        else:
            # global/MLA: the overlap recompute writes identical bytes,
            # so shared pages stay shared; recompute at least the last
            # token (its logits feed the first sampled token)
            cw = sm.chunk_for(plen)
            start = (min(attach, plen - 1) // cw) * cw
        last, carry = sm.prefill(self.params, req.prompt[None, :],
                                 cache0=seed, start=start)
        self.n_prefix_hits += 1
        self.n_prefix_tokens += start
        return last, carry

    def _install_wave(self, plen, group, last, carry):
        """Scatter a prefilled wave into its slots, pin its prompts in
        the prefix cache, and draw/book-keep the first sampled token."""
        st = self.st
        slots = [s for _r, s in group]
        pad = self._pad_slots(slots)
        if self.pool is None:
            self.state = self.sm.write_slots(self.state, carry, pad)
        else:
            # page-granular scatter: each wave row's dense prefill
            # cache lands in its chain's pages; padding rows get
            # all-out-of-bounds page ids so their writes drop
            pages = np.full((len(pad), self.pool.max_pages),
                            self.pool.num_pages, np.int32)
            pages[:len(group)] = self.pool.block_tables[slots]
            self.state = self.sm.write_slots(self.state, carry, pad,
                                             pages=pages, plen=plen)
            if self.prefix_cache is not None:
                # pin BEFORE an instant retire below releases the chain
                for r, s in group:
                    self.prefix_cache.insert(
                        r.prompt, self.pool.block_tables[s],
                        self.sm.chunk_for(plen))
        if self.drafter is not None:
            # the drafter tracks the SAME stream: prefill its own carry
            # over the wave's prompts (same padded batch — padding rows
            # land at OOB slots and drop) and tile it K-wide, resume
            # index 0.  The target draws tok0 below; the drafter will
            # consume it as ``cur`` in the first propose wave.
            prompts = [r.prompt for r, _s in group]
            prompts += [prompts[-1]] * (len(pad) - len(group))
            carry = self.drafter.prefill(self.draft_params,
                                         np.stack(prompts))
            self.draft_store = self.drafter.install(self.draft_store,
                                                    carry, pad)
        # the wave's first generated token sits at position plen — its
        # draw uses the same counter-based (seed, uid, pos) key family
        # as the decode loop, so it is reproducible under any batching
        tok0 = np.asarray(self.sm.sample(
            last, self._wave_sampling(group, len(pad)),
            np.full(len(pad), plen, np.int32)))
        for i, (req, slot) in enumerate(group):
            t = int(tok0[i])
            req.outputs.append(t)
            self.n_emitted += 1
            self._first_token(req)
            st.pos[slot] = plen
            st.remaining[slot] = req.max_new_tokens - 1
            st.cur[slot] = t
            st.set_sampling(slot, req)
            if self.drafter is not None:
                self._draft_sel[slot] = 0
                self._req_k[slot] = (req.spec_k if req.spec_k is not None
                                     else self.spec_k)
            if st.remaining[slot] <= 0 or t == req.eos_id:
                self._retire(slot)

    # ------------------------------------------------------------------
    # preemption (policy-driven victim swap-out / swap-in)
    # ------------------------------------------------------------------
    def _preempt(self, slot: int):
        """Evict running ``slot``: device_get exactly its page chain +
        per-slot carry to host memory, release its pages/reservation and
        put the request back on the queue holding the snapshot.  Eager
        transfers only — the jitted step's compile count stays 1."""
        st = self.st
        req = st.slot_req[slot]
        if req is None or not st.active[slot]:
            raise ValueError(f"slot {slot} is not running (cannot "
                             "preempt)")
        if self.pool is None:
            raise ValueError("preemption needs kv_layout='paged' (page "
                             "swap is what makes eviction cheap)")
        n = int(self.pool.chain_len[slot])
        pages = self.pool.block_tables[slot, :n].copy()
        tel = self.telemetry
        with tel.span("preempt", uid=req.uid, slot=int(slot), pages=n):
            req.snapshot = {
                "n_pages": n,
                # the slot's reservation at eviction — re-admission
                # reserves exactly this (see _pages_for_req:
                # prompt+budget would under-size a fork child's chain)
                "reserve": self.pool.reserved_for(slot),
                "state": self.sm.snapshot_slot(self.state, slot, pages),
                "pos": int(st.pos[slot]),
                "remaining": int(st.remaining[slot]),
                "cur": np.copy(st.cur[slot]),
            }
            if self.drafter is not None:
                req.snapshot["draft"] = self.drafter.snapshot_slot(
                    self.draft_store, slot)
                req.snapshot["draft_sel"] = int(self._draft_sel[slot])
            req.submit_t = time.monotonic()  # queue wait restarts here
            req.n_preemptions += 1
            self.n_preemptions += 1
            st.free_slot(slot)             # pages + reservation go back
        if tel.enabled:
            tel.inc("preemptions")
            tel.request_begin(req, "preempted", slot=int(slot), pages=n)
        # appendleft: a policy that keeps arrival order re-tries the
        # victim first; ordering policies re-sort anyway
        st.waiting.appendleft(req)

    def _resume(self, req: Request, slot: int):
        """Re-admit a preempted request (caller holds slot+reservation):
        grow a FRESH chain, re-seed its pages from the snapshot, restore
        the per-slot carry/counters — then decode continues mid-stream,
        bitwise where it left off.  No prefill, no first-token draw."""
        st = self.st
        snap = req.snapshot
        tel = self.telemetry
        with tel.span("resume", uid=req.uid, slot=int(slot),
                      pages=snap["n_pages"]):
            self.pool.grow(slot, snap["n_pages"])
            pages = self.pool.block_tables[slot, :snap["n_pages"]]
            self.state = self.sm.restore_slot(self.state, snap["state"],
                                              slot, pages)
            st.pos[slot] = snap["pos"]
            st.remaining[slot] = snap["remaining"]
            st.cur[slot] = snap["cur"]
            st.set_sampling(slot, req)
            st.active[slot] = True
            if self.drafter is not None:
                self.draft_store = self.drafter.restore_slot(
                    self.draft_store, snap["draft"], slot)
                self._draft_sel[slot] = snap["draft_sel"]
                self._req_k[slot] = (req.spec_k if req.spec_k is not None
                                     else self.spec_k)
        if tel.enabled:
            tel.inc("resumes")
            tel.request_begin(req, "running", slot=int(slot),
                              resumed=True)
        req.snapshot = None                # drop the host bytes

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _first_token(self, req: Request):
        """Book the request's first emitted token (TTFT anchor)."""
        if req.first_token_t is not None:
            return
        req.first_token_t = time.monotonic()
        if self.telemetry.enabled and req.created_t is not None:
            self.telemetry.observe(
                "ttft_ms", (req.first_token_t - req.created_t) * 1000.0)

    def _retire(self, slot: int) -> Request:
        """Retire a finishing slot — the ONE finish path, so telemetry
        sees every completion (admission instant-retire, plain decode,
        spec waves)."""
        req = self.st.retire(slot)
        # deadline misses live on the engine's STEP clock — the unit
        # submit(deadline=...) is scored in by the load harness
        miss = req.deadline is not None and self.n_steps > req.deadline
        if miss:
            self.n_deadline_misses += 1
        tel = self.telemetry
        if tel.enabled:
            tel.inc("requests_finished")
            if miss:
                tel.inc("deadline_misses")
            tel.request_end(req, tokens=len(req.outputs),
                            preemptions=req.n_preemptions)
            tel.request_instant(req, "finish", tokens=len(req.outputs),
                                deadline_miss=miss)
            if req.created_t is not None and req.finish_t is not None:
                tel.observe("e2e_ms",
                            (req.finish_t - req.created_t) * 1000.0)
        return req

    def cancel(self, req: Request):
        """Abort a request: a waiting one leaves the queue (the pool is
        never touched — a queued request holds no slot, pages or
        reservation), a running one frees its slot (and, under the paged
        layout, its pages) before the next step.  Tokens already emitted
        stay on the request, which is marked finished+cancelled and
        never joins ``finished``."""
        if req.finished:
            return
        if not self.st.discard_waiting(req):
            for slot, r in enumerate(self.st.slot_req):
                if r is req:
                    self.st.free_slot(slot)
                    break
            else:
                raise ValueError("request is not known to this engine")
        req.snapshot = None                # a preempted wait drops bytes
        req.finished = True
        req.cancelled = True
        if self.telemetry.enabled:
            self.telemetry.inc("requests_cancelled")
            self.telemetry.request_end(req, cancelled=True)
            self.telemetry.request_instant(req, "cancel")

    def step(self):
        """Admit what fits, then run ONE slot-batched decode step (a
        propose/verify wave when a drafter is configured — up to
        ``spec_k`` tokens per slot for the same number of host syncs).

        All telemetry here is host-side wall clock + host counters
        around the device call — the jitted program and its inputs are
        byte-identical with telemetry on or off."""
        tel = self.telemetry
        with tel.span("admit", queue_depth=self.st.queue_depth):
            self.admit()
        st = self.st
        if not st.active.any():
            if tel.enabled:
                self._note_compiles()
            return
        t0 = time.monotonic()
        d0, a0 = self._n_decoded, self.n_drafts_accepted
        spec = self.drafter is not None
        with tel.span("spec_wave" if spec else "decode_wave",
                      active_slots=st.n_active,
                      queue_depth=st.queue_depth,
                      pages_in_use=(self.pool.pages_in_use
                                    if self.pool else 0)) as sp:
            if spec:
                self._spec_step()
                sp.set(accepted_drafts=self.n_drafts_accepted - a0)
            else:
                self._plain_step()
            sp.set(tokens=self._n_decoded - d0)
        now = time.monotonic()
        self._rate.push(now, self._n_decoded - d0)
        if tel.enabled:
            wave_ms = (now - t0) * 1000.0
            tel.observe("step_ms", wave_ms)
            # per-stream inter-token latency: one wave = one emission
            # opportunity per active slot (>= 1 token under spec)
            tel.observe("itl_ms", wave_ms)
            tel.inc("decode_waves")
            tel.inc("tokens_decoded", self._n_decoded - d0)
            tel.gauge("active_slots", st.n_active)
            tel.gauge("queue_depth", st.queue_depth)
            tel.counter("slots", active=st.n_active,
                        queue=st.queue_depth)
            if self.pool is not None:
                tel.gauge("pool_utilization",
                          self.pool.pages_in_use / self.pool.num_pages)
                tel.counter("pool", in_use=self.pool.pages_in_use,
                            free=len(self.pool._free),
                            reserved=self.pool.reserved_total)
            self._note_compiles()

    def _plain_step(self):
        """One slot-batched decode step (no drafter)."""
        st = self.st
        bt = None
        if self.pool is not None:
            # allocate-on-decode-append: this step writes K/V at
            # pos[slot], so every active chain must cover it — the pages
            # come out of the reservation made at admission, so growth
            # cannot fail mid-stream.  Copy-on-write: a write landing in
            # a SHARED page (fork sibling / prefix-cache pin also holds
            # it) first detaches to a private copy; the device copies
            # for the whole step batch run as ONE jitted program.
            cow_src, cow_dst = [], []
            for slot in np.flatnonzero(st.active):
                self.pool.grow(slot,
                               self.sm.pages_for(int(st.pos[slot]) + 1))
                for ci in self.sm.write_page_indices(int(st.pos[slot])):
                    pair = self.pool.cow(slot, ci)
                    if pair is not None:
                        cow_src.append(pair[0])
                        cow_dst.append(pair[1])
            if cow_src:
                self.state = self.sm.copy_pages(self.state, cow_src,
                                                cow_dst)
                self.n_cow_copies += len(cow_src)
            bt = self.pool.block_tables
        active = jnp.asarray(st.active)
        pos = jnp.asarray(st.pos)
        x = jnp.asarray(st.cur)
        sampling = None
        if self.sm.autoregressive:
            sampling = {k: jnp.asarray(v) for k, v in st.knobs.items()}
        kw = {} if bt is None else {"bt": bt}
        out, self.state = self.sm.step(self.params, x, self.state, pos,
                                       active, sampling, **kw)
        emitted = np.asarray(out)
        self.n_steps += 1
        for slot in np.flatnonzero(st.active):
            req = st.slot_req[slot]
            req.outputs.append(emitted[slot].copy())
            self.n_emitted += 1
            self._n_decoded += 1
            self._first_token(req)
            st.pos[slot] += 1
            st.remaining[slot] -= 1
            if self.sm.autoregressive:
                st.cur[slot] = emitted[slot]
                done = (st.remaining[slot] <= 0
                        or emitted[slot] == req.eos_id)
            else:
                done = st.remaining[slot] <= 0
                if not done:
                    st.cur[slot] = req.prompt[st.pos[slot]]
            if done:
                self._retire(slot)

    def _spec_step(self):
        """One propose/verify wave: the drafter rolls ``spec_k`` greedy
        steps per slot (one jitted program), the target scores all of
        them in one ``verify`` call that also commits exactly the
        accepted prefix's K/V, and the host loop advances each slot by
        its ``n_emit`` accepted+correction tokens.  Greedy slots advance
        bitwise along the target-only stream; sampled slots draw from
        provably the target's distribution (serve.sampling).  Exactly
        one compiled propose program and one compiled verify program
        serve every traffic mix — per-slot widths, positions and
        sampling knobs are data."""
        st = self.st
        # per-slot verify widths: the request's own spec_k clamped by the
        # remaining budget, so commits never pass pos + remaining (the
        # reservation and the max_len bound stop exactly there)
        k_slot = heterogeneous_k(self._req_k, st.remaining, self.spec_k)
        # a wave writes K/V at pos .. pos+k_slot-1: grow/COW the whole
        # span up front (same reservation-backed guarantee as one step)
        cow_src, cow_dst = [], []
        for slot in np.flatnonzero(st.active):
            p0, kk = int(st.pos[slot]), int(k_slot[slot])
            self.pool.grow(slot, self.sm.pages_for(p0 + kk))
            touched = set()
            for p in range(p0, p0 + kk):
                touched.update(self.sm.write_page_indices(p))
            for ci in sorted(touched):
                pair = self.pool.cow(slot, ci)
                if pair is not None:
                    cow_src.append(pair[0])
                    cow_dst.append(pair[1])
        if cow_src:
            self.state = self.sm.copy_pages(self.state, cow_src, cow_dst)
            self.n_cow_copies += len(cow_src)
        tel = self.telemetry
        active = jnp.asarray(st.active)
        pos = jnp.asarray(st.pos)
        with tel.span("propose", k=int(k_slot.max())):
            toks, self.draft_store = self.drafter.propose(
                self.draft_params, self.draft_store, self._draft_sel,
                np.asarray(st.cur), active)
        sampling = {k: jnp.asarray(v) for k, v in st.knobs.items()}
        with tel.span("verify"):
            emitted, n_emit, self.state = self.sm.verify(
                self.params, toks, self.state, pos, active,
                k_slot, sampling, bt=self.pool.block_tables)
        emitted = np.asarray(emitted)
        n_emit = np.asarray(n_emit)
        self.n_steps += 1
        for slot in np.flatnonzero(st.active):
            req = st.slot_req[slot]
            n = int(n_emit[slot])
            self.n_drafts_proposed += int(k_slot[slot]) - 1
            self.n_drafts_accepted += n - 1
            done = False
            n_take = n
            for j in range(n):
                t = int(emitted[slot, j])
                req.outputs.append(emitted[slot, j].copy())
                self.n_emitted += 1
                self._n_decoded += 1
                self._first_token(req)
                if t == req.eos_id:
                    # tokens past an eos are discarded — target-only
                    # decode would never have produced them (their K/V
                    # commits die with the freed pages)
                    n_take = j + 1
                    done = True
                    break
            st.pos[slot] += n_take
            st.remaining[slot] -= n_take
            if st.remaining[slot] <= 0:
                done = True
            if done:
                self._retire(slot)
            else:
                st.cur[slot] = emitted[slot, n_take - 1]
                # resume carry: the drafter state after consuming the
                # stream through pos-1 is the wave's (n_take-1)-th feed
                self._draft_sel[slot] = n_take - 1

    def fork(self, req: Request, n: int = 1, *,
             max_new_tokens: Optional[int] = None,
             sampling: Optional[SamplingParams] = None) -> List[Request]:
        """Split a RUNNING request into ``n`` additional streams that
        share its page chain copy-on-write — beam search and best-of-n
        pay the parent's prefill (and all pages decoded so far) once.

        Each child copies the parent's block-table row (``PagePool.share``
        increments every page's refcount), its recurrent non-pool state
        (one jitted ``copy_slot``), its emitted-so-far outputs, position
        and input token; a later decode write into a still-shared page
        detaches a private copy first (see :meth:`step`).  Children get
        a FRESH uid, so sampled children draw independent streams from
        the counter-based PRNG while greedy children reproduce the
        parent bitwise.

        ``max_new_tokens=None`` inherits the parent's remaining budget;
        an int gives each child that many tokens from the fork point.
        Children need a free slot and a full worst-case reservation NOW
        — fork raises rather than queueing (a queued fork would race the
        parent's ongoing decode)."""
        st = self.st
        if self.pool is None:
            raise ValueError("fork() needs kv_layout='paged' (page "
                             "sharing is what makes a fork O(1))")
        if not self.sm.autoregressive:
            raise ValueError("fork() applies to LM requests only")
        parent = next((s for s, r in enumerate(st.slot_req)
                       if r is req), None)
        if parent is None:
            raise ValueError(
                "fork parent must be RUNNING (admitted, not finished) — "
                "fork after admit()/step() has placed it in a slot")
        if sampling is not None:
            sampling.validate()
        children: List[Request] = []
        for _ in range(int(n)):
            pos = int(st.pos[parent])
            budget = (int(st.remaining[parent])
                      if max_new_tokens is None else int(max_new_tokens))
            if budget < 1:
                raise ValueError(f"fork needs a generation budget >= 1, "
                                 f"got {budget}")
            if pos + budget > self.sm.max_len:
                raise ValueError(
                    f"fork at position {pos} + {budget} new tokens "
                    f"exceeds max_len={self.sm.max_len}")
            if not st.free_mask:
                raise RuntimeError("no free slot to fork into")
            need = self.sm.pages_for(pos + budget)
            if not self.pool.can_admit(need):
                raise RuntimeError(
                    f"cannot fork: child needs a reservation of {need} "
                    f"pages but only {self.pool.available} are "
                    "unreserved (shared pages don't count — "
                    "reservations stay worst-case under sharing)")
            slot = st.alloc_slot()
            self.pool.reserve(slot, need)
            nchain = int(self.pool.chain_len[parent])
            self.pool.share(slot,
                            self.pool.block_tables[parent, :nchain])
            samp = (dataclasses.replace(sampling) if sampling is not None
                    else dataclasses.replace(req.sampling))
            child = Request(self._uid, req.prompt, budget, req.eos_id,
                            samp, priority=req.priority,
                            deadline=req.deadline, spec_k=req.spec_k)
            self._uid += 1
            child.outputs = list(req.outputs)
            st.slot_req[slot] = child
            st.active[slot] = True
            st.pos[slot] = st.pos[parent]
            st.remaining[slot] = budget
            st.cur[slot] = st.cur[parent]
            st.set_sampling(slot, child)
            self.state = self.sm.copy_slot(self.state, parent, slot)
            if self.drafter is not None:
                self.draft_store = self.drafter.copy_slot(
                    self.draft_store, parent, slot)
                self._draft_sel[slot] = self._draft_sel[parent]
                self._req_k[slot] = self._req_k[parent]
            self.n_forks += 1
            if self.telemetry.enabled:
                self.telemetry.inc("forks")
                self.telemetry.instant("fork", parent_uid=req.uid,
                                       child_uid=child.uid,
                                       slot=int(slot))
                self.telemetry.request_begin(child, "running",
                                             slot=int(slot), forked=True)
            children.append(child)
        return children

    def run(self, max_steps: Optional[int] = None, *,
            verbose: bool = False) -> List[Request]:
        """Drive until every submitted request finishes; returns them in
        completion order.  ``verbose=True`` prints a :meth:`stats` line
        after every step (occupancy, queue, pool pages, preemptions).

        Deadlock guard: a step with nothing active, nothing retired and
        a non-empty queue can never make progress (no running request
        will ever free the pages the queue's head is deferred on) — the
        old loop busy-spun forever; now it raises, naming the blocked
        request and the pool state."""
        st = self.st
        steps = 0
        # the stats line goes through a SINK, not a hardwired print:
        # Telemetry(stats_stream=..., stats_every=N) owns the stream and
        # cadence; verbose=True without one falls back to a per-step
        # stdout sink (the historical rendering, byte for byte)
        sink = self.telemetry.stats_sink
        if sink is None and verbose:
            if self._verbose_sink is None:
                self._verbose_sink = StatsSink()
            sink = self._verbose_sink
        while st.waiting or st.active.any():
            n_finished = len(st.finished)
            self.step()
            if sink is not None:
                sink.emit(self.stats())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
            if (st.waiting and not st.active.any()
                    and len(st.finished) == n_finished):
                # the blocked head is the POLICY's head — under
                # priority/sjf that need not be waiting[0]
                head = self.policy.admit_order(st.waiting, st)[0]
                need = self._pages_for_req(head)
                pool = ("no page pool" if self.pool is None else
                        f"pool: {self.pool.available} of "
                        f"{self.pool.num_pages} pages unreserved, "
                        f"{self.pool.pages_in_use} in use, "
                        f"reserved_total={self.pool.reserved_total}")
                raise RuntimeError(
                    f"engine stalled: request uid={head.uid} "
                    f"(prompt={len(head.prompt)} tokens, "
                    f"max_new_tokens={head.max_new_tokens}, needs "
                    f"{need} pages) cannot admit, no slot is active to "
                    f"free capacity, and {len(st.waiting)} request(s) "
                    f"wait behind it — {pool}")
        return st.finished

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def stats(self) -> EngineStats:
        """Current occupancy snapshot (see :class:`EngineStats`)."""
        paid = self.n_steps * self.slots
        p50, p99 = self._queue_wait.percentiles((50, 99))
        return EngineStats(
            policy=self.policy.name,
            n_steps=self.n_steps,
            slots=self.slots,
            active_slots=self.st.n_active,
            queue_depth=self.st.queue_depth,
            pages_in_use=(self.pool.pages_in_use if self.pool else 0),
            pages_free=(len(self.pool._free) if self.pool else 0),
            pages_reserved=(self.pool.reserved_total if self.pool
                            else 0),
            n_preemptions=self.n_preemptions,
            utilization=self._n_decoded / paid if paid else 0.0,
            deadline_misses=self.n_deadline_misses,
            tokens_per_s=self._rate.per_s(),
            queue_wait_p50_ms=p50,
            queue_wait_p99_ms=p99,
            accept_rate=(self.n_drafts_accepted /
                         self.n_drafts_proposed
                         if self.n_drafts_proposed else 0.0))

    def _jit_programs(self) -> Dict[str, Any]:
        """The jitted wrappers this engine can observe compile counts
        on, by short name (``step``, ``verify``, ``draft_propose``, ...).
        Lazily-built wrappers (``_jit_prefill_fast`` before the first
        prefill) are skipped until they exist."""
        out = {}
        for attr in _JIT_PROGRAMS:
            fn = getattr(self.sm, attr, None)
            if fn is not None and hasattr(fn, "_cache_size"):
                out[attr[len("_jit_"):]] = fn
        if self.drafter is not None:
            for attr in _DRAFT_JIT_PROGRAMS:
                fn = getattr(self.drafter, attr, None)
                if fn is not None and hasattr(fn, "_cache_size"):
                    out["draft" + attr[len("_jit"):]] = fn
        return out

    def _note_compiles(self):
        """Diff jit cache sizes against the last observation; new
        entries become ``jit_compiles`` counter increments and engine-
        track instants.  Host-side observation only — reading
        ``_cache_size()`` never triggers or prevents a compile."""
        tel = self.telemetry
        for name, fn in self._jit_programs().items():
            n = fn._cache_size()
            seen = self._jit_seen.get(name, 0)
            if n > seen:
                tel.inc("jit_compiles", n - seen)
                tel.instant("jit_compile", program=name, cache_size=n)
                self._jit_seen[name] = n

    def metrics(self) -> Dict[str, Any]:
        """Machine-readable engine metrics as a typed dict — the
        autoscaling-loop / dashboard readout.  Always available (the
        engine's own counters and the jit compile counts don't need a
        Telemetry handle); the ``telemetry`` section carries the
        registry's counters/gauges/histograms when one is attached.

        Sections: ``counters`` (monotonic ints), ``gauges`` (point-in-
        time floats), ``rates`` (windowed — what an autoscaler acts
        on), ``jit`` (``<program>_compiles`` per jitted wrapper — the
        compile-count-1 contract reads ``jit["step_compiles"]``)."""
        s = self.stats()
        m: Dict[str, Any] = {
            "counters": {
                "steps": self.n_steps,
                "tokens_emitted": self.n_emitted,
                "tokens_decoded": self._n_decoded,
                "requests_finished": len(self.st.finished),
                "preemptions": self.n_preemptions,
                "forks": self.n_forks,
                "cow_copies": self.n_cow_copies,
                "prefix_hits": self.n_prefix_hits,
                "prefix_tokens_skipped": self.n_prefix_tokens,
                "drafts_proposed": self.n_drafts_proposed,
                "drafts_accepted": self.n_drafts_accepted,
                "deadline_misses": self.n_deadline_misses,
            },
            "gauges": {
                "slots": float(self.slots),
                "active_slots": float(s.active_slots),
                "queue_depth": float(s.queue_depth),
                "pages_in_use": float(s.pages_in_use),
                "pages_free": float(s.pages_free),
                "pages_reserved": float(s.pages_reserved),
                "pool_utilization": (
                    s.pages_in_use / self.pool.num_pages
                    if self.pool else 0.0),
                "utilization": s.utilization,
            },
            "rates": {
                "tokens_per_s": s.tokens_per_s,
                "queue_wait_p50_ms": s.queue_wait_p50_ms,
                "queue_wait_p99_ms": s.queue_wait_p99_ms,
                "accept_rate": s.accept_rate,
            },
            "jit": {f"{name}_compiles": fn._cache_size()
                    for name, fn in self._jit_programs().items()},
        }
        if self.telemetry.enabled:
            m["telemetry"] = self.telemetry.registry.as_dict()
        return m

    @property
    def utilization(self) -> float:
        """Decode-emitted tokens per slot-step actually paid for (tokens
        produced by admission prefill are excluded — they cost prefill
        FLOPs, not decode slot-steps)."""
        return self.stats().utilization
