"""Fixed-capacity continuous-batching scheduler.

The engine owns ``slots`` recurrent states (one per in-flight request) plus
per-slot position / budget counters.  Requests of arbitrary prompt and
generation lengths are admitted into free slots as they open up and retired
the step they finish — the decode step itself is ONE jitted program over
the full slot batch whose shapes never change, so XLA compiles it exactly
once per engine (no slot compaction, no retraces).

Request lifecycle::

    submit() -> WAITING -> [admit: chunked prefill -> state write] ->
    RUNNING (slot batch decode, inactive slots masked) -> retire ->
    FINISHED (tokens / stream outputs collected on the host)

Two request flavors, selected by the StepModel:

  * autoregressive (DecoderLM): the prompt is prefilled in chunks at
    admission; emitted tokens feed back as the next input until
    ``max_new_tokens`` (or ``eos_id``) is reached.  Each request may
    carry :class:`~repro.configs.base.SamplingParams` — the knobs ride
    as per-slot arrays through the one jitted decode step (greedy and
    sampled traffic share a single compiled program), and the PRNG is
    counter-based (fold_in(seed, uid_lo, uid_hi, pos) — the FULL
    submission uid reaches the key as two 32-bit words) so a request's
    tokens are reproducible regardless of co-batched traffic.
  * streaming (MinimalistNetwork): input frames are fed one per step —
    the paper's edge case where samples arrive in real time — and every
    per-frame output is recorded; the request retires when its stream is
    exhausted.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.common import pow2ceil
from repro.configs.base import SamplingParams
from repro.serve.sampling import KNOB_DTYPES, KNOB_GREEDY

def _knob_values(req):
    """A request's per-slot knob values (schema: sampling.KNOB_DTYPES).

    The uid is folded into the counter-based PRNG key as two 32-bit
    words (low bits + the bits above them) so the FULL uid reaches the
    key — a single masked word would give requests whose uids differ by
    its period (e.g. 2**31 under the old ``& 0x7FFFFFFF`` mask)
    bitwise-identical sampled streams."""
    sp = req.sampling
    return {"seed": sp.seed, "uid": req.uid & 0xFFFFFFFF,
            "uid_hi": (req.uid >> 32) & 0xFFFFFFFF,
            "temperature": sp.temperature, "top_k": sp.top_k,
            "top_p": sp.top_p}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (P,) int32 tokens | (P, d_in) frames
    max_new_tokens: int = 0            # 0 for pure streaming requests
    eos_id: Optional[int] = None
    # default_factory: every request owns its params instance — a shared
    # class-level default would let one request's (user-)mutated knobs
    # silently leak into every other default-sampled request
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # filled by the engine:
    outputs: List[Any] = dataclasses.field(default_factory=list)
    finished: bool = False
    cancelled: bool = False

    @property
    def tokens(self) -> np.ndarray:
        """Generated token ids (LM) / per-frame outputs (streaming)."""
        return np.asarray(self.outputs)


class ServeEngine:
    """Continuous-batching engine over any :class:`StepModel`.

    ``mesh=`` serves under a :class:`jax.sharding.Mesh`: the StepModel is
    bound to it (``bind_mesh``) so parameters TP-shard over "model" via
    the model's logical-axis rule tables, the slot-batch state DP-shards
    its slot axis over "data", and every host-side transfer (prompts,
    next tokens, sampling knobs) is device_put against the slot sharding
    — the decode step stays ONE compiled (now SPMD) program.  On a 1×1
    mesh this is bitwise identical to the no-mesh engine; the semantics
    (admission, retirement, per-request reproducibility) never change.
    """

    def __init__(self, step_model, params, *, slots: int = 8, mesh=None):
        self.sm = step_model
        self.slots = int(slots)
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        if mesh is not None:
            step_model.bind_mesh(mesh, self.slots)
        self.mesh = step_model.mesh
        self.params = step_model.place_params(params)
        # paged KV layout: the engine owns the page allocator — block
        # tables, free list and per-slot chains live here on the host;
        # only the page POOLS are device state (inside self.state)
        self.pool = None
        if getattr(step_model, "kv_layout", "dense") == "paged":
            from repro.serve.paged import PagePool
            self.pool = PagePool(step_model.num_pages(self.slots),
                                 self.slots, step_model.max_pages)
        self.state = step_model.init_state(self.slots)
        self.free_mask = (1 << self.slots) - 1     # bit i set = slot i free
        self.waiting: deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * self.slots
        self.pos = np.zeros(self.slots, np.int32)
        self.remaining = np.zeros(self.slots, np.int64)
        self.active = np.zeros(self.slots, bool)
        # per-slot sampling knobs: plain DATA through the one jitted step
        # (greedy defaults; a sampled request overwrites them at admission)
        self.knobs = {k: np.full(self.slots, KNOB_GREEDY[k], KNOB_DTYPES[k])
                      for k in KNOB_DTYPES}
        self._cur: Optional[np.ndarray] = None     # next input per slot
        self._uid = 0
        # telemetry
        self.n_steps = 0
        self.n_emitted = 0          # all tokens, incl. admission prefill
        self._n_decoded = 0         # tokens emitted by slot-batch steps
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 0,
               eos_id: Optional[int] = None,
               sampling: Optional[SamplingParams] = None) -> Request:
        prompt = np.asarray(prompt)
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if sampling is None:
            sampling = SamplingParams()    # fresh instance per request
        else:
            sampling.validate()
            if not self.sm.autoregressive:
                raise ValueError(
                    "sampling only applies to autoregressive requests")
        if self.sm.autoregressive:
            if prompt.ndim != 1:
                raise ValueError(
                    f"LM requests need a 1-D token prompt, got shape "
                    f"{prompt.shape}")
            if max_new_tokens < 1:
                raise ValueError(
                    f"LM requests need max_new_tokens >= 1, got "
                    f"{max_new_tokens}")
            prompt = prompt.astype(np.int32)
            # attention-bearing stacks write K/V at absolute positions:
            # past max_len the scatter would silently clamp / wrap and the
            # stream would decode garbage mid-request — reject up front
            if getattr(self.sm, "positional", False):
                need = len(prompt) + max_new_tokens
                if need > self.sm.max_len:
                    raise ValueError(
                        f"prompt ({len(prompt)}) + max_new_tokens "
                        f"({max_new_tokens}) = {need} cache positions, "
                        f"but the engine was built with "
                        f"max_len={self.sm.max_len}")
                # paged note: this bound is also what makes page OOM
                # impossible past this point — PagedConfig.validate_for
                # guarantees the pool holds one max-length request, so
                # any request accepted here fits an empty pool and
                # admission only ever DEFERS (see admit())
        req = Request(self._uid, prompt, max_new_tokens, eos_id, sampling)
        self._uid += 1
        self.waiting.append(req)
        return req

    def _alloc_slot(self) -> int:
        bit = int(self.free_mask & -self.free_mask)
        self.free_mask = int(self.free_mask) ^ bit
        return bit.bit_length() - 1

    def _free_slot(self, slot: int):
        self.free_mask = int(self.free_mask) | (1 << int(slot))
        self.slot_req[slot] = None
        self.active[slot] = False
        if self.pool is not None:
            # pages (and the unused reservation tail) go straight back
            # into circulation; the pool content is NOT cleared — any
            # future read of a recycled page is position-masked
            self.pool.release(slot)
        for k, v in KNOB_GREEDY.items():
            self.knobs[k][slot] = v

    def _set_sampling(self, slot: int, req: Request):
        for k, v in _knob_values(req).items():
            self.knobs[k][slot] = v

    def _wave_sampling(self, group, pad_len):
        """Per-request sampling knob arrays for an admission wave (padding
        rows replicate the last request; their draws are discarded).
        Built as numpy first so handing them to jit is a plain device put
        (a list literal would trace a tiny convert program per wave size)."""
        reqs = [r for r, _s in group]
        reqs += [reqs[-1]] * (pad_len - len(group))
        vals = [_knob_values(r) for r in reqs]
        return {k: np.asarray([v[k] for v in vals], KNOB_DTYPES[k])
                for k in KNOB_DTYPES}

    def _pad_slots(self, slots):
        """Pad an admission wave's slot list to a power of two with
        out-of-bounds indices — the scatter drops them, and jit compiles
        at most log2(slots) admission shapes per prompt-length bucket."""
        padded = np.full(pow2ceil(len(slots)), self.slots, np.int32)
        padded[:len(slots)] = slots
        return padded

    def admit(self):
        """Move waiting requests into free slots, one WAVE at a time:
        same-length prompts prefill as one batched chunked call, their
        carries land in one scatter write, and the wave costs one host
        sync — admission overhead amortizes over the wave.

        Paged KV: admission additionally RESERVES the request's
        worst-case page chain (prompt + full generation budget), so
        decode-time page appends can never fail.  When the pool cannot
        cover the next request's reservation the queue DEFERS — strictly
        FIFO, no bypass by smaller requests behind it (head-of-line
        blocking is the price of starvation-freedom) — and retries as
        finished requests release pages.  Requests that can never fit
        were already rejected at submit()."""
        admitted = []
        while self.waiting and self.free_mask:
            req = self.waiting[0]
            if self.pool is not None and not self.pool.can_admit(
                    self.sm.pages_for(len(req.prompt)
                                      + req.max_new_tokens)):
                break                      # defer until pages free up
            self.waiting.popleft()
            slot = self._alloc_slot()
            if self.pool is not None:
                self.pool.reserve(slot, self.sm.pages_for(
                    len(req.prompt) + req.max_new_tokens))
                self.pool.grow(slot, self.sm.pages_for(len(req.prompt)))
            self.slot_req[slot] = req
            self.active[slot] = True
            admitted.append((req, slot))
            if self._cur is None:
                shape = (self.slots,) + tuple(req.prompt.shape[1:])
                self._cur = np.zeros(shape, req.prompt.dtype)
        if not admitted:
            return
        if not self.sm.autoregressive:
            # streaming: blank state reset for the whole wave in one write
            slots = [s for _r, s in admitted]
            pad = self._pad_slots(slots)
            blank = self.sm.init_state(len(pad))
            self.state = self.sm.write_slots(self.state, blank, pad)
            for req, slot in admitted:
                self.pos[slot] = 0
                self.remaining[slot] = len(req.prompt)
                self._cur[slot] = req.prompt[0]
            return
        groups: dict = {}
        for req, slot in admitted:
            groups.setdefault(len(req.prompt), []).append((req, slot))
        for plen, group in groups.items():
            slots = [s for _r, s in group]
            pad = self._pad_slots(slots)
            prompts = [r.prompt for r, _s in group]
            prompts += [prompts[-1]] * (len(pad) - len(group))
            last, carry = self.sm.prefill(self.params, np.stack(prompts))
            if self.pool is None:
                self.state = self.sm.write_slots(self.state, carry, pad)
            else:
                # page-granular scatter: each wave row's dense prefill
                # cache lands in its chain's pages; padding rows get
                # all-out-of-bounds page ids so their writes drop
                pages = np.full((len(pad), self.pool.max_pages),
                                self.pool.num_pages, np.int32)
                pages[:len(group)] = self.pool.block_tables[slots]
                self.state = self.sm.write_slots(self.state, carry, pad,
                                                 pages=pages, plen=plen)
            # the wave's first generated token sits at position plen — its
            # draw uses the same counter-based (seed, uid, pos) key family
            # as the decode loop, so it is reproducible under any batching
            tok0 = np.asarray(self.sm.sample(
                last, self._wave_sampling(group, len(pad)),
                np.full(len(pad), plen, np.int32)))
            for i, (req, slot) in enumerate(group):
                t = int(tok0[i])
                req.outputs.append(t)
                self.n_emitted += 1
                self.pos[slot] = plen
                self.remaining[slot] = req.max_new_tokens - 1
                self._cur[slot] = t
                self._set_sampling(slot, req)
                if self.remaining[slot] <= 0 or t == req.eos_id:
                    self._retire(slot)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.finished = True
        self.finished.append(req)
        self._free_slot(slot)

    def cancel(self, req: Request):
        """Abort a request: a waiting one leaves the queue, a running one
        frees its slot (and, under the paged layout, its pages) before
        the next step.  Tokens already emitted stay on the request, which
        is marked finished+cancelled and never joins ``finished``."""
        if req.finished:
            return
        # identity matches only: Request.__eq__ would compare prompt
        # arrays elementwise, and a LOOKALIKE request must not be freed
        if any(r is req for r in self.waiting):
            self.waiting = deque(r for r in self.waiting if r is not req)
        else:
            for slot, r in enumerate(self.slot_req):
                if r is req:
                    self._free_slot(slot)
                    break
            else:
                raise ValueError("request is not known to this engine")
        req.finished = True
        req.cancelled = True

    def step(self):
        """Admit what fits, then run ONE slot-batched decode step."""
        self.admit()
        if not self.active.any():
            return
        bt = None
        if self.pool is not None:
            # allocate-on-decode-append: this step writes K/V at
            # pos[slot], so every active chain must cover it — the pages
            # come out of the reservation made at admission, so growth
            # cannot fail mid-stream
            for slot in np.flatnonzero(self.active):
                self.pool.grow(slot,
                               self.sm.pages_for(int(self.pos[slot]) + 1))
            bt = self.pool.block_tables
        active = jnp.asarray(self.active)
        pos = jnp.asarray(self.pos)
        x = jnp.asarray(self._cur)
        sampling = None
        if self.sm.autoregressive:
            sampling = {k: jnp.asarray(v) for k, v in self.knobs.items()}
        kw = {} if bt is None else {"bt": bt}
        out, self.state = self.sm.step(self.params, x, self.state, pos,
                                       active, sampling, **kw)
        emitted = np.asarray(out)
        self.n_steps += 1
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            req.outputs.append(emitted[slot].copy())
            self.n_emitted += 1
            self._n_decoded += 1
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if self.sm.autoregressive:
                self._cur[slot] = emitted[slot]
                done = (self.remaining[slot] <= 0
                        or emitted[slot] == req.eos_id)
            else:
                done = self.remaining[slot] <= 0
                if not done:
                    self._cur[slot] = req.prompt[self.pos[slot]]
            if done:
                self._retire(slot)

    def run(self, max_steps: Optional[int] = None) -> List[Request]:
        """Drive until every submitted request finishes; returns them in
        completion order."""
        steps = 0
        while self.waiting or self.active.any():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.finished

    # ------------------------------------------------------------------
    @property
    def utilization(self) -> float:
        """Decode-emitted tokens per slot-step actually paid for (tokens
        produced by admission prefill are excluded — they cost prefill
        FLOPs, not decode slot-steps)."""
        paid = self.n_steps * self.slots
        return self._n_decoded / paid if paid else 0.0
