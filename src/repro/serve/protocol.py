"""The StepModel protocol: what the serving engine requires of a model.

A StepModel reduces any supported architecture to four operations over a
slot-batched recurrent state (every leaf carries the slot axis first):

  * ``init_state(batch)``                      — blank per-slot state
  * ``prefill(params, xs, pos0=0)``            — consume an admission
                                                 wave's prompts from a
                                                 fresh internal state
  * ``step(params, x, state, pos, active, sampling=None)``
                                               — one slot-batch decode
                                                 step (vector pos/active;
                                                 per-slot sampling knobs,
                                                 emitted value feeds back
                                                 for LMs)

LM adapters additionally expose ``sample(logits, sampling, pos)`` — the
admission-wave token draw (the engine samples the first generated token
from the prefill logits with the same counter-based keys the decode step
uses).  ``emit(out)`` survives as an optional greedy-argmax debugging
helper; the engine no longer calls it.

Two adapters are provided:

  * :class:`DecoderStepModel` — any ``models.transformer.DecoderLM``
    (minGRU / Mamba / attention / hybrid stacks).  Pure O(1)-state stacks
    take the direct batched ``decode_step`` with a dummy position (their
    mixers are position-free); attention-bearing stacks are vmapped over
    slots so each slot keeps its own absolute position in the KV cache.
  * :class:`MinimalistStepModel` — the paper's raw ``MinimalistNetwork``
    (frame streaming, e.g. per-sample sMNIST classification), optionally
    through the fused single-step Pallas kernel on exported 2 b codes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pow2ceil
from repro.configs.base import ATTN, ATTN_LOCAL, MLA
from repro.serve.sampling import greedy_arrays, sample_tokens


class StepModel:
    """Contract only; see module docstring."""

    #: LM generation (emit feeds back as the next input) vs frame streaming
    #: (inputs always come from the request's own sequence).
    autoregressive: bool = True

    def init_state(self, batch):
        raise NotImplementedError

    def prefill(self, params, xs, pos0=0):
        """xs: (B, P, …) an admission wave's prompts (equal lengths) ->
        (last_out (B, …), carry state with batch B)."""
        raise NotImplementedError

    def step(self, params, x, state, pos, active, sampling=None):
        """ONE slot-batch decode step.  Returns (emitted, merged_state):
        the emitted value per slot (token id / output vector) and the
        state with inactive slots frozen — both produced inside a single
        jitted program so the hot path is one dispatch + one host sync.
        ``sampling`` is a dict of per-slot knob ARRAYS (see
        repro.serve.sampling) or None for all-greedy; either way the
        same program runs — knobs are data, not trace constants."""
        raise NotImplementedError

    def emit(self, out):
        """Optional: raw output -> recorded value (greedy debugging aid)."""
        raise NotImplementedError

    def write_slots(self, state, batch_state, slots):
        """Scatter a batched carry (batch axis aligned with ``slots``) into
        the slot batch.  Entries of ``slots`` >= capacity are padding and
        dropped (JAX scatter OOB-drop semantics) — admission waves pad the
        group batch to a power of two so jit shapes stay bounded."""
        raise NotImplementedError


def _axis_mask(active, leaf, axis=0):
    """Broadcast (slots,) bool over a leaf whose slot dim sits at ``axis``."""
    shape = [1] * leaf.ndim
    shape[axis] = active.shape[0]
    return active.reshape(shape)


def masked_update(state, new_state, active, axis=0):
    """Freeze inactive slots: new value where active, old where not."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(_axis_mask(active, n, axis), n, o),
        new_state, state)


# ---------------------------------------------------------------------------
# DecoderLM adapter
# ---------------------------------------------------------------------------

class DecoderStepModel(StepModel):
    """StepModel over a DecoderLM; state = the per-layer decode caches."""

    autoregressive = True

    def __init__(self, model, *, max_len: int = 256,
                 prefill_chunk: int = 256):
        self.model = model
        self.max_len = int(max_len)
        self.prefill_chunk = int(prefill_chunk)
        self.vocab = model.cfg.vocab
        kinds = {s.kind for s in model.cfg.layer_specs()}
        # position-free stacks: every mixer carries O(1) state and ignores
        # absolute position -> one batched decode_step, never retraced.
        self.positional = bool(kinds & {ATTN, ATTN_LOCAL, MLA})
        # in the model's native cache layout, scanned-unit leaves carry the
        # layer-repeat axis FIRST — their slot (batch) axis is 1, not 0.
        self._slot_axis = {name: (1 if mode == "scanned" else 0)
                           for name, _l, mode in model._all_layers()}
        # MoE stacks: the decode step routes through the capacity-free
        # gather-GEMM path and chunked prefill through per-request
        # grouping (models.moe, MoEConfig.dispatch="auto"), so routing —
        # and therefore the generated text — no longer depends on the
        # co-batched traffic or the prefill chunking.  Only an explicit
        # dispatch="pooled" opts back into batch-DEPENDENT serving (the
        # training semantics, capacity drops included) — that one still
        # warns, because there the old caveat remains true.
        self.moe_dispatch = (model.cfg.moe.dispatch
                             if any(s.moe for s in model.cfg.layer_specs())
                             else None)
        if self.moe_dispatch == "pooled":
            import warnings
            warnings.warn(
                f"{model.cfg.name}: dispatch='pooled' pools every token of "
                "a call into one capacity-limited dispatch — serving "
                "outputs will vary with concurrent traffic and prefill "
                "chunking (use 'auto' or 'per_request' for batch-invariant "
                "routing)", stacklevel=2)
        self._jit_step = jax.jit(self._step_impl)
        self._jit_write = jax.jit(self._write_impl)
        self._jit_sample = jax.jit(self._sample_impl)
        self.emit = jax.jit(self._emit_impl)
        self._greedy = {}           # per-batch greedy sampling arrays
        # populated lazily by serve.prefill.chunked_prefill
        self._jit_prefill_fast = None
        self._jit_prefill_scan = None
        self._cache_templates = {}

    # -- state ----------------------------------------------------------
    def init_state(self, batch):
        if not self.positional:
            return self.model.init_cache(batch, self.max_len)
        # per-slot unit caches (inner batch 1), stacked on the slot axis
        unit = self.model.cache_spec(1, self.max_len)
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros((batch,) + s.shape, s.dtype), unit)

    # -- prefill (an admission wave of same-length prompts) -------------
    def prefill(self, params, xs, pos0=0):
        """xs: (B, P) int32 prompts.  Grid-padded chunking via
        serve.prefill, with the chunk capped at the next power of two of
        the prompt: a 10-token prompt pays a 16-wide chunk, not the full
        ``prefill_chunk`` — padding waste stays < 2x while the chunk
        program family stays log2-bounded (each width compiles once and
        serves every prompt length that buckets to it)."""
        from repro.serve.prefill import chunked_prefill
        chunk = min(self.prefill_chunk, pow2ceil(xs.shape[1]))
        return chunked_prefill(self, params, xs, chunk=chunk, pos0=pos0)

    # -- decode ---------------------------------------------------------
    def _step_impl(self, params, tok, state, pos, active, samp):
        if not self.positional:
            logits, new_state = self.model.decode_step(
                params, tok[:, None], state, jnp.int32(0))
            logits = logits[:, -1, :]
            merged = {}
            for name, sub in state.items():
                ax = self._slot_axis[name]
                merged[name] = masked_update(sub, new_state[name],
                                             active, axis=ax)
        else:
            vstep = jax.vmap(self.model.decode_step,
                             in_axes=(None, 0, 0, 0))
            logits, new_state = vstep(params, tok[:, None, None], state, pos)
            logits = logits[:, 0, -1, :]
            merged = masked_update(state, new_state, active)
        # the token produced from input position p lands at position p+1 —
        # the PRNG key folds in the GENERATED token's position, so the
        # admission-sampled first token (at pos = prompt length) and the
        # decode stream never collide on a counter value
        return self._sample_impl(logits, samp, pos + 1), merged

    def step(self, params, tok, state, pos, active, sampling=None):
        """tok: (slots,) int32; pos, active: (slots,); sampling: dict of
        per-slot knob arrays (None -> all-greedy arrays of the same
        dtypes, so greedy/sampled traffic share ONE compiled program)."""
        if sampling is None:
            n = int(tok.shape[0])
            if n not in self._greedy:
                self._greedy[n] = greedy_arrays(n)
            sampling = self._greedy[n]
        return self._jit_step(params, tok, state, pos, active, sampling)

    def _sample_impl(self, logits, samp, pos):
        """Per-row counter-keyed sampling over the REAL vocab; greedy rows
        (temperature <= 0) take the argmax path inside the same program.
        A runtime cond skips the whole stochastic pipeline (sorts, PRNG)
        when EVERY slot is greedy, so all-greedy traffic keeps the plain
        argmax hot path without a second compiled program."""
        lg = logits[..., :self.vocab].astype(jnp.float32)
        return jax.lax.cond(
            jnp.any(samp["temperature"] > 0.0),
            lambda: sample_tokens(lg, samp["seed"], samp["uid"],
                                  samp["uid_hi"], pos,
                                  samp["temperature"], samp["top_k"],
                                  samp["top_p"]),
            lambda: jnp.argmax(lg, -1).astype(jnp.int32))

    def sample(self, logits, sampling, pos):
        """Draw one token per row of ``logits`` (admission-wave shape)."""
        return self._jit_sample(logits, sampling, jnp.asarray(pos,
                                                              jnp.int32))

    def _emit_impl(self, logits):
        """Greedy over the REAL vocab (ignore Megatron padding columns).
        Kept as a debugging helper — the serving paths go through
        sample()/step(), whose greedy branch is this same argmax."""
        return jnp.argmax(logits[..., :self.vocab], -1).astype(jnp.int32)

    # -- slot writes ----------------------------------------------------
    def _write_impl(self, state, batch_state, slots):
        out = {}
        for name, sub in state.items():
            ax = self._slot_axis[name]

            def upd(s, v, ax=ax):
                if self.positional:
                    # stacked layout (slots, *unit): bring the cache batch
                    # axis to the front, re-insert its singleton, scatter.
                    v2 = jnp.expand_dims(jnp.moveaxis(v, ax, 0), 1 + ax)
                    return s.at[slots].set(v2.astype(s.dtype))
                if ax == 0:
                    return s.at[slots].set(v.astype(s.dtype))
                return s.at[:, slots].set(v.astype(s.dtype))

            out[name] = jax.tree_util.tree_map(upd, sub, batch_state[name])
        return out

    def write_slots(self, state, batch_state, slots):
        """Install an admission wave's prefill carry into its slots."""
        return self._jit_write(state, batch_state, jnp.asarray(slots,
                                                               jnp.int32))


# ---------------------------------------------------------------------------
# MinimalistNetwork adapter (paper's edge-streaming case)
# ---------------------------------------------------------------------------

class MinimalistStepModel(StepModel):
    """Frame-streaming StepModel over ``core.mingru.MinimalistNetwork``.

    ``use_fused_kernel=True`` serves the exported hardware model through
    the fused single-step Pallas kernel (kernels.minimalist_block) — pass
    the *trained block params* as usual; the 2 b-code export
    (:func:`repro.kernels.minimalist_block.ops.from_block_params`) is
    cached per params object and redone (with a fresh jit trace, since
    the codes are baked in as constants) whenever a different params
    pytree is passed.
    """

    autoregressive = False

    def __init__(self, net, *, scan_backend=None, use_fused_kernel=False,
                 kernel_backend="pallas"):
        self.net = net
        self.scan_backend = scan_backend
        self.use_fused_kernel = use_fused_kernel
        self.kernel_backend = kernel_backend
        self._exported = None
        self._export_src = None
        self._jit_step = jax.jit(self._step_impl)
        self._jit_write = jax.jit(self._write_impl)

    def _export(self, params):
        """(Re)export 2 b codes when a different params object arrives.
        The codes enter the fused step as jit CONSTANTS, so the step jit
        is rebuilt alongside them — otherwise stale weights would serve
        silently after a checkpoint reload or QAT phase change."""
        if self._exported is None or self._export_src is not params:
            from repro.kernels.minimalist_block import ops as mb_ops
            self._exported = [mb_ops.from_block_params(params[b.name])
                              for b in self.net.blocks]
            self._export_src = params
            self._jit_step = jax.jit(self._step_impl)
        return self._exported

    def init_state(self, batch):
        return self.net.initial_state(batch)

    def _raw_step(self, params, x, state):
        if self.use_fused_kernel:
            from repro.kernels.minimalist_block import ops as mb_ops
            out, new_states = x, []
            for i, exp in enumerate(self._exported):
                y, h = mb_ops.minimalist_step(
                    out, *exp, state[i], backend=self.kernel_backend)
                new_states.append(h)
                # readout layer: the analog h is the result (no comparator)
                out = h if i == len(self._exported) - 1 else y
            return out, new_states
        return self.net.step(params, x, state)

    def _step_impl(self, params, x, state, pos, active):
        del pos
        out, new_state = self._raw_step(params, x, state)
        return out, masked_update(state, new_state, active)

    def step(self, params, x, state, pos, active, sampling=None):
        """x: (slots, d_in) frames; pos unused (position-free); sampling
        ignored — frame streaming emits analog outputs, not tokens."""
        del sampling
        if self.use_fused_kernel:
            self._export(params)        # host-side, once; jit sees constants
        return self._jit_step(params, x, state, pos, active)

    def emit(self, out):
        return out

    def _write_impl(self, state, batch_state, slots):
        return jax.tree_util.tree_map(
            lambda s, v: s.at[slots].set(v.astype(s.dtype)),
            state, batch_state)

    def write_slots(self, state, batch_state, slots):
        return self._jit_write(state, batch_state,
                               jnp.asarray(slots, jnp.int32))
