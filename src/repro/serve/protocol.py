"""The StepModel protocol: what the serving engine requires of a model.

A StepModel reduces any supported architecture to four operations over a
slot-batched recurrent state (every leaf carries the slot axis first):

  * ``init_state(batch)``                      — blank per-slot state
  * ``prefill(params, xs, pos0=0)``            — consume an admission
                                                 wave's prompts from a
                                                 fresh internal state
  * ``step(params, x, state, pos, active, sampling=None)``
                                               — one slot-batch decode
                                                 step (vector pos/active;
                                                 per-slot sampling knobs,
                                                 emitted value feeds back
                                                 for LMs)

LM adapters additionally expose ``sample(logits, sampling, pos)`` — the
admission-wave token draw (the engine samples the first generated token
from the prefill logits with the same counter-based keys the decode step
uses).  ``emit(out)`` survives as an optional greedy-argmax debugging
helper; the engine no longer calls it.

Two adapters are provided:

  * :class:`DecoderStepModel` — any ``models.transformer.DecoderLM``
    (minGRU / Mamba / attention / hybrid stacks).  Pure O(1)-state stacks
    take the direct batched ``decode_step`` with a dummy position (their
    mixers are position-free); attention-bearing stacks are vmapped over
    slots so each slot keeps its own absolute position in the KV cache.
  * :class:`MinimalistStepModel` — the paper's raw ``MinimalistNetwork``
    (frame streaming, e.g. per-sample sMNIST classification), optionally
    through the fused single-step Pallas kernel on exported 2 b codes.

Mesh serving: ``bind_mesh(mesh, slots)`` commits an adapter to a
``jax.sharding.Mesh`` — parameters TP-shard over "model" through the
model's own logical-axis rule tables, the slot-batch state DP-shards
its slot axis over "data" (``parallel.sharding.SERVE_CACHE_RULES``),
and per-call host arrays are ``device_put`` against the slot sharding,
so the decode step stays one compiled SPMD program.  See
:class:`ServeShardings` and README §Sharded serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import pow2ceil
from repro.configs.base import ATTN, ATTN_LOCAL, MLA
from repro.parallel import sharding as shd
from repro.serve.sampling import greedy_arrays, sample_tokens, verify_tokens


@dataclasses.dataclass(frozen=True)
class ServeShardings:
    """Every placement the serving engine needs, for one (mesh, model,
    slot count): parameters TP-shard over "model" via the model's own
    logical-axis rule tables, the slot-batch state DP-shards its slot
    axis over "data" (TP-shardable cache dims ride the serve cache
    rules), and per-slot decode arrays (tokens / positions / active /
    sampling knobs) shard like a batch.  ``replicated`` is the fully
    replicated placement for scalars and scatter indices."""

    mesh: Any
    params: Any       # NamedSharding pytree matching the param pytree
    state: Any        # NamedSharding pytree matching init_state(slots)
    slot: Any         # NamedSharding for (slots,)-leading arrays
    replicated: Any   # NamedSharding(mesh, P())


class StepModel:
    """Contract only; see module docstring."""

    #: LM generation (emit feeds back as the next input) vs frame streaming
    #: (inputs always come from the request's own sequence).
    autoregressive: bool = True

    #: bound by :meth:`bind_mesh`; ``None`` = classic single-device serving.
    mesh = None
    sharding: Optional[ServeShardings] = None
    _slot_shardings = None      # (dim0, rank) -> NamedSharding cache

    def shardings(self, mesh, slots, rules=None) -> ServeShardings:
        """Compute (without binding) the placements this model's serve
        arrays take on ``mesh`` with a ``slots``-wide slot batch."""
        raise NotImplementedError

    def bind_mesh(self, mesh, slots, rules=None) -> ServeShardings:
        """Commit this StepModel to ``mesh``: recompute shardings and
        rebuild the jitted programs so every compiled step runs SPMD
        (and donates the slot state).  One mesh per StepModel — the
        engine calls this at init when constructed with ``mesh=``."""
        raise NotImplementedError

    def place_params(self, params):
        """device_put ``params`` against the bound mesh (identity when
        unbound)."""
        if self.mesh is None:
            return params
        return jax.device_put(params, self.sharding.params)

    def put_slot(self, a):
        """device_put one per-slot/wave array (dim0 = slot axis) against
        the bound mesh (divisibility-gated DP; no-op when unbound).  The
        NamedSharding per (dim0, rank) is cached — this runs ~10x per
        decode step on the latency-critical host path."""
        if self.mesh is None:
            return a
        a = jnp.asarray(a)
        key = (a.shape[0] if a.ndim else None, a.ndim)
        if self._slot_shardings is None:
            self._slot_shardings = {}
        sh = self._slot_shardings.get(key)
        if sh is None:
            sh = NamedSharding(self.mesh, shd.dim0_dp_spec(a.shape,
                                                           self.mesh))
            self._slot_shardings[key] = sh
        return jax.device_put(a, sh)

    def init_state(self, batch):
        raise NotImplementedError

    def prefill(self, params, xs, pos0=0):
        """xs: (B, P, …) an admission wave's prompts (equal lengths) ->
        (last_out (B, …), carry state with batch B)."""
        raise NotImplementedError

    def step(self, params, x, state, pos, active, sampling=None):
        """ONE slot-batch decode step.  Returns (emitted, merged_state):
        the emitted value per slot (token id / output vector) and the
        state with inactive slots frozen — both produced inside a single
        jitted program so the hot path is one dispatch + one host sync.
        ``sampling`` is a dict of per-slot knob ARRAYS (see
        repro.serve.sampling) or None for all-greedy; either way the
        same program runs — knobs are data, not trace constants."""
        raise NotImplementedError

    def emit(self, out):
        """Optional: raw output -> recorded value (greedy debugging aid)."""
        raise NotImplementedError

    def write_slots(self, state, batch_state, slots):
        """Scatter a batched carry (batch axis aligned with ``slots``) into
        the slot batch.  Entries of ``slots`` >= capacity are padding and
        dropped (JAX scatter OOB-drop semantics) — admission waves pad the
        group batch to a power of two so jit shapes stay bounded."""
        raise NotImplementedError


def _axis_mask(active, leaf, axis=0):
    """Broadcast (slots,) bool over a leaf whose slot dim sits at ``axis``."""
    shape = [1] * leaf.ndim
    shape[axis] = active.shape[0]
    return active.reshape(shape)


def masked_update(state, new_state, active, axis=0):
    """Freeze inactive slots: new value where active, old where not."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(_axis_mask(active, n, axis), n, o),
        new_state, state)


# ---------------------------------------------------------------------------
# DecoderLM adapter
# ---------------------------------------------------------------------------

class DecoderStepModel(StepModel):
    """StepModel over a DecoderLM; state = the per-layer decode caches.

    ``kv_layout`` selects where attention caches live:

      * "dense" (default) — every slot owns (max_len, ...) cache rows;
        positional stacks decode via a per-slot vmap of ``decode_step``.
      * "paged" — attention caches are shared page pools plus per-slot
        block tables (``serve.paged``); decode runs the natively
        slot-batched ``decode_step_paged`` (a vmap cannot thread shared
        pool state), admission prefill still computes the dense wave
        cache and ``write_slots`` scatters it PAGE-granularly, and the
        engine allocates pages as positions cross page boundaries.  The
        default ``paged_impl="pallas"`` reads through the page-indirect
        kernel (pinned per-family tolerance vs the gather oracle);
        ``paged_impl="gather"`` keeps the decode math bitwise identical
        to the dense layout.  With ``kv_dtype="int8"`` pools store
        symmetric per-page codes + float32 scale leaves (``*_scale``):
        ``write_slots`` quantizes page rows on install, the in-graph
        decode write requantizes incrementally, and the scales ride the
        pool subtrees so page copies (COW) and sharding need no special
        cases.
    """

    autoregressive = True

    def __init__(self, model, *, max_len: int = 256,
                 prefill_chunk: int = 256, kv_layout: str = "dense",
                 paged=None):
        self.model = model
        self.max_len = int(max_len)
        self.prefill_chunk = int(prefill_chunk)
        self.vocab = model.cfg.vocab
        kinds = {s.kind for s in model.cfg.layer_specs()}
        # position-free stacks: every mixer carries O(1) state and ignores
        # absolute position -> one batched decode_step, never retraced.
        self.positional = bool(kinds & {ATTN, ATTN_LOCAL, MLA})
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', "
                             f"got {kv_layout!r}")
        self.kv_layout = kv_layout
        self.paged = None
        self._pool_names = frozenset()
        if kv_layout == "paged":
            from repro.serve.paged import PagedConfig
            if not self.positional:
                raise ValueError(
                    f"{model.cfg.name}: kv_layout='paged' needs an "
                    "attention-bearing stack — pure O(1)-state stacks "
                    "have no KV cache to page (serve them dense)")
            # the longest in-cache span any layer keeps: global/MLA
            # layers span max_len; a pure sliding-window stack is bounded
            # by its ring, so its page chains (and the block-table width)
            # never exceed the window
            if kinds & {ATTN, MLA}:
                self._page_cap = self.max_len
            else:
                self._page_cap = min(model.cfg.sliding_window, self.max_len)
            self.paged = paged if paged is not None else PagedConfig()
            self.max_pages = self.pages_for(self.max_len)
            self.paged.validate_for(self.max_len, self.max_pages)
            self._pool_names = frozenset(model.paged_layer_names())
            # copy-on-write metadata: which in-chain page indices a
            # decode write at position p touches.  Global/MLA layers
            # write the absolute page p//ps; each sliding-window ring of
            # length L recycles page (p % L)//ps in place.
            ring = set()
            has_global = False
            for name, lyr, _m in model._all_layers():
                if name not in self._pool_names:
                    continue
                L = lyr.mixer.ring_length(self.max_len)
                if L < self.max_len:
                    ring.add(int(L))
                else:
                    has_global = True
            self._ring_lens = sorted(ring)
            self._has_global = has_global
            self._has_window = bool(ring)
        # in the model's native cache layout, scanned-unit leaves carry the
        # layer-repeat axis FIRST — their slot (batch) axis is 1, not 0.
        self._slot_axis = {name: (1 if mode == "scanned" else 0)
                           for name, _l, mode in model._all_layers()}
        # MoE stacks: the decode step routes through the capacity-free
        # gather-GEMM path and chunked prefill through per-request
        # grouping (models.moe, MoEConfig.dispatch="auto"), so routing —
        # and therefore the generated text — no longer depends on the
        # co-batched traffic or the prefill chunking.  Only an explicit
        # dispatch="pooled" opts back into batch-DEPENDENT serving (the
        # training semantics, capacity drops included) — that one still
        # warns, because there the old caveat remains true.
        self.moe_dispatch = (model.cfg.moe.dispatch
                             if any(s.moe for s in model.cfg.layer_specs())
                             else None)
        if self.moe_dispatch == "pooled":
            import warnings
            warnings.warn(
                f"{model.cfg.name}: dispatch='pooled' pools every token of "
                "a call into one capacity-limited dispatch — serving "
                "outputs will vary with concurrent traffic and prefill "
                "chunking (use 'auto' or 'per_request' for batch-invariant "
                "routing)", stacklevel=2)
        if self.kv_layout == "paged":
            self._jit_step = jax.jit(self._step_impl_paged)
            # the prompt length is a SHAPE (pages written per layer), so
            # it is static — one compiled write per (wave, plen) bucket,
            # exactly the prefill's own compile classes
            self._jit_write = jax.jit(self._write_impl_paged,
                                      static_argnums=(4,))
            # sharing machinery: fork slot-state copies, COW page copies,
            # prefix-attach cache seeding (all page-pool local — the page
            # axis is never sharded, so none of these need collectives)
            self._jit_copy_slot = jax.jit(self._copy_slot_impl)
            self._jit_copy_pages = jax.jit(self._copy_pages_impl)
            self._jit_seed = jax.jit(self._seed_impl)
            self._jit_verify = jax.jit(self._verify_impl_paged)
        else:
            self._jit_step = jax.jit(self._step_impl)
            self._jit_write = jax.jit(self._write_impl)
        self._jit_sample = jax.jit(self._sample_impl)
        self.emit = jax.jit(self._emit_impl)
        self._greedy = {}           # per-batch greedy sampling arrays
        # populated lazily by serve.prefill.chunked_prefill
        self._jit_prefill_fast = None
        self._jit_prefill_scan = None
        self._cache_templates = {}
        self._state_shardings = {}  # per-batch state placement (mesh only)

    # -- paged layout ----------------------------------------------------
    def pages_for(self, n: int) -> int:
        """Pages a request needs once it spans ``n`` positions (the max
        over layers: window rings cap at the ring length)."""
        ps = self.paged.page_size
        return -(-min(int(n), self._page_cap) // ps)

    def num_pages(self, slots: int) -> int:
        """Resolved pool capacity (0 in the config = dense-equivalent)."""
        return self.paged.resolve_num_pages(slots, self.max_pages)

    def write_page_indices(self, pos: int):
        """In-chain page indices a decode write at position ``pos``
        touches (the engine COWs these when they are shared): the
        absolute page for global/MLA layers, plus each sliding-window
        ring's recycled page."""
        ps = self.paged.page_size
        out = set()
        if self._has_global:
            out.add(int(pos) // ps)
        for L in self._ring_lens:
            out.add((int(pos) % L) // ps)
        return sorted(out)

    def check_prefix_cacheable(self):
        """Prefix caching reconstructs a request's WHOLE decode state
        from pages — reject stacks where that is impossible."""
        if self.kv_layout != "paged":
            raise ValueError("prefix caching needs kv_layout='paged'")
        o1 = sorted(set(self._slot_axis) - set(self._pool_names))
        if o1:
            raise ValueError(
                f"prefix caching needs an all-attention stack: layers "
                f"{o1} carry O(1) mixer state that does not live in "
                "pages, so an attached request could not reconstruct it")
        if self._page_cap < self.max_len:
            raise ValueError(
                "prefix caching needs page chains spanning max_len; a "
                f"pure sliding-window stack caps them at the ring "
                f"({self._page_cap} positions) and overwrites prompt "
                "pages in place")
        return True

    # -- page sharing (forks / prefix attaches) --------------------------
    def _copy_slot_impl(self, state, src, dst):
        """Duplicate the per-slot NON-pool leaves of ``src`` into ``dst``
        (fork: the page pools themselves are shared via block tables)."""
        out = {}
        for name, sub in state.items():
            if name in self._pool_names:
                out[name] = sub
                continue
            ax = self._slot_axis[name]

            def cp(s, ax=ax):
                row = jax.lax.dynamic_index_in_dim(s, src, ax,
                                                   keepdims=True)
                return jax.lax.dynamic_update_slice_in_dim(s, row, dst,
                                                           ax)

            out[name] = jax.tree_util.tree_map(cp, sub)
        return out

    def copy_slot(self, state, src: int, dst: int):
        """Fork: copy slot ``src``'s recurrent (non-pool) state into
        ``dst`` inside one jitted program (src/dst ride as traced
        scalars — one compile, any pair)."""
        src, dst = jnp.int32(src), jnp.int32(dst)
        if self.mesh is not None:
            src = jax.device_put(src, self.sharding.replicated)
            dst = jax.device_put(dst, self.sharding.replicated)
        return self._jit_copy_slot(state, src, dst)

    def _copy_pages_impl(self, state, src, dst):
        """Copy pool rows ``src[i] -> dst[i]`` in every page pool.
        Out-of-bounds ``dst`` padding drops (scatter semantics); the
        matching ``src`` padding reads clamp harmlessly."""
        out = {}
        for name, sub in state.items():
            if name not in self._pool_names:
                out[name] = sub
                continue
            ax = self._slot_axis[name]

            def cp(s, ax=ax):
                if ax == 0:
                    return s.at[dst].set(s[src])
                return s.at[:, dst].set(s[:, src])

            out[name] = jax.tree_util.tree_map(cp, sub)
        return out

    def copy_pages(self, state, src, dst):
        """Copy-on-write device copies: page ``src[i]`` -> ``dst[i]`` in
        every pool leaf.  Padded to a power of two (OOB dst indices
        drop) so jit compiles log2-many shapes; the page axis is never
        sharded, so under a mesh this stays collective-free."""
        import numpy as np
        n = pow2ceil(len(src))
        sp = np.zeros(n, np.int32)
        sp[:len(src)] = src
        dp = np.full(n, np.iinfo(np.int32).max, np.int32)
        dp[:len(dst)] = dst
        sp, dp = jnp.asarray(sp), jnp.asarray(dp)
        if self.mesh is not None:
            sp = jax.device_put(sp, self.sharding.replicated)
            dp = jax.device_put(dp, self.sharding.replicated)
        return self._jit_copy_pages(state, sp, dp)

    def _seed_impl(self, state, bt_row):
        """Native dense B=1 prefill cache gathered from ``bt_row``'s
        pages — the in-cache index mapping (absolute for global/MLA,
        ring for windows) is exactly ``gather_pages``'s, so the seeded
        cache is bitwise the dense cache the chain's writer produced
        (bf16 pools).  Int8 pools seed the DEQUANTIZED view (codes ×
        per-page scale): re-installing it quantizes back to bit-exact
        codes (see ``_write_impl_paged``)."""
        from repro.kernels.paged_attention.ref import (gather_dequant,
                                                       gather_pages)
        tmpl = self.model.cache_spec(1, self.max_len)
        out = {}
        for name, sub in state.items():
            ax = self._slot_axis[name]
            qkeys = ({k for k in sub if k + "_scale" in sub}
                     if isinstance(sub, dict) else set())
            if qkeys:
                nsub = {}
                for key in sorted(qkeys):
                    spec = tmpl[name][key]
                    Lv = spec.shape[ax + 1]
                    pool, sc = sub[key], sub[key + "_scale"]
                    if ax == 0:
                        nsub[key] = gather_dequant(pool, sc, bt_row, Lv,
                                                   spec.dtype)
                    else:
                        nsub[key] = jax.vmap(
                            lambda p, s, Lv=Lv: gather_dequant(
                                p, s, bt_row, Lv))(pool, sc).astype(
                                    spec.dtype)
                out[name] = nsub
                continue

            def g(pool, spec, ax=ax):
                Lv = spec.shape[ax + 1]
                if ax == 0:
                    return gather_pages(pool, bt_row,
                                        Lv).astype(spec.dtype)
                return jax.vmap(
                    lambda p: gather_pages(p, bt_row, Lv))(
                        pool).astype(spec.dtype)

            out[name] = jax.tree_util.tree_map(g, sub, tmpl[name])
        return out

    def seed_cache(self, state, bt_row):
        """Prefix attach: reconstruct the dense (B=1, native layout)
        cache held by ``bt_row``'s page chain, ready to resume
        ``prefill(cache0=..., start=...)`` from the attach point.
        Entries past the chain gather garbage — every read of them is
        position-masked or overwritten by the tail prefill."""
        bt = jnp.asarray(bt_row, jnp.int32)
        if self.mesh is not None:
            bt = jax.device_put(bt, self.sharding.replicated)
        cache = self._jit_seed(state, bt)
        if self.mesh is not None:
            cache = self.place_cache(cache)
        return cache

    # -- preemption (scheduler victim swap-out / swap-in) ----------------
    def snapshot_slot(self, state, slot, pages):
        """Host snapshot of everything slot ``slot`` owns: its chain's
        page rows (``pages`` = the physical ids, from the block table)
        out of every pool leaf, plus its per-slot row of every non-pool
        (O(1)-state) leaf — so hybrid recurrent/attention stacks swap
        out whole.  Eager ops + one ``device_get``: preemption is a
        rare host-paced event, so it buys no extra jitted program and
        the decode step's compile count stays 1.  Int8 pools snapshot
        codes AND ``<key>_scale`` rows (they ride the same subtree), so
        a restore reproduces the quantized bytes bit-exactly."""
        if self.kv_layout != "paged":
            raise ValueError("preemption snapshots need kv_layout="
                             "'paged' (page swap is what makes them "
                             "cheap)")
        pg = jnp.asarray(pages, jnp.int32)
        snap = {}
        for name, sub in state.items():
            ax = self._slot_axis[name]
            if name in self._pool_names:
                def take(s, ax=ax):
                    return jnp.take(s, pg, axis=ax)
            else:
                def take(s, ax=ax):
                    return jax.lax.index_in_dim(s, int(slot), axis=ax,
                                                keepdims=False)
            snap[name] = jax.tree_util.tree_map(take, sub)
        return jax.device_get(snap)

    def restore_slot(self, state, snap, slot, pages):
        """Inverse of :meth:`snapshot_slot`: install a host snapshot
        into ``slot`` under a FRESH page chain ``pages``.  The new ids
        need not match the snapshotted ones — every decode read goes
        through the block table, so the resumed stream sees identical
        bytes at identical positions and (with the counter-based PRNG
        keyed on (seed, uid, pos)) decodes bitwise-equal to a run that
        was never preempted."""
        if self.kv_layout != "paged":
            raise ValueError("preemption restores need kv_layout="
                             "'paged'")
        pg = jnp.asarray(pages, jnp.int32)
        slot = int(slot)
        out = {}
        for name, sub in state.items():
            ax = self._slot_axis[name]
            if name in self._pool_names:
                def put(s, v, ax=ax):
                    v = jnp.asarray(v, s.dtype)
                    if ax == 0:
                        return s.at[pg].set(v)
                    return s.at[:, pg].set(v)
            else:
                def put(s, v, ax=ax):
                    v = jnp.asarray(v, s.dtype)
                    if ax == 0:
                        return s.at[slot].set(v)
                    return s.at[:, slot].set(v)
            out[name] = jax.tree_util.tree_map(put, sub, snap[name])
        if self.mesh is not None:
            # eager scatters can drift placement — re-pin to the serve
            # cache shardings so the next jitted step sees the one
            # placement it was compiled for
            out = jax.device_put(
                out, self._state_sharding(self.mesh, self._bound_slots))
        return out

    # -- mesh placement --------------------------------------------------
    def state_spec(self, batch):
        """ShapeDtypeStruct tree of init_state(batch) (no allocation)."""
        if self.kv_layout == "paged":
            return self.model.paged_cache_spec(
                batch, self.max_len, self.num_pages(batch),
                self.paged.page_size)
        if not self.positional:
            return self.model.cache_spec(batch, self.max_len)
        unit = self.model.cache_spec(1, self.max_len)
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((batch,) + s.shape, s.dtype),
            unit)

    def state_axes(self):
        """Logical axes of init_state's layout.  Native model layout for
        O(1)-state stacks; positional DENSE stacks stack per-slot unit
        caches, so the slot axis is prepended as a leading "batch" (the
        unit's own singleton batch dim then loses the DP divisibility
        race and replicates, as it should).  The PAGED layout is native
        again: page pools carry ("pages", "page", ...) — the page axis is
        never sharded (same contract as kv_len) while kv_heads / latents
        TP-shard — and the O(1) leaves keep their slot batch."""
        if self.kv_layout == "paged":
            return self.model.paged_cache_axes()
        axes = self.model.cache_axes()
        if not self.positional:
            return axes
        return jax.tree_util.tree_map(
            lambda t: ("batch",) + tuple(t), axes,
            is_leaf=lambda x: isinstance(x, tuple))

    def _state_sharding(self, mesh, batch):
        key = (id(mesh), batch)
        if key not in self._state_shardings:
            spec = shd.serve_cache_specs(self.state_axes(),
                                         self.state_spec(batch), mesh)
            self._state_shardings[key] = shd.named_sharding_tree(spec,
                                                                 mesh)
        return self._state_shardings[key]

    def shardings(self, mesh, slots, rules=None) -> ServeShardings:
        p_shapes = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
        p_spec = shd.param_specs(self.model, p_shapes, mesh, rules)
        return ServeShardings(
            mesh=mesh,
            params=shd.named_sharding_tree(p_spec, mesh),
            state=self._state_sharding(mesh, int(slots)),
            slot=NamedSharding(mesh, shd.dim0_dp_spec((int(slots),), mesh)),
            replicated=NamedSharding(mesh, P()))

    def bind_mesh(self, mesh, slots, rules=None) -> ServeShardings:
        """Rebuild the jitted programs for SPMD serving on ``mesh``:

        * the decode step and the admission scatter pin their state
          output to the serve cache shardings (so the engine's carried
          state never drifts placement between steps — one compiled
          program, not a placement-chasing family) and DONATE the
          incoming state buffer;
        * per-call host arrays are device_put against the slot sharding
          by :meth:`step` / :meth:`sample` / :meth:`write_slots`;
        * prefill templates and compiled programs are dropped so
          serve.prefill rebuilds them placed.
        """
        slots = int(slots)
        if (self.mesh is mesh
                and getattr(self, "_bound_slots", None) == slots
                and getattr(self, "_bound_rules", None) == rules):
            return self.sharding
        self._state_shardings = {}
        self._slot_shardings = {}
        self.mesh = mesh
        self._bound_slots = slots
        self._bound_rules = rules
        self.sharding = self.shardings(mesh, slots, rules)
        if self.kv_layout == "paged":
            self._jit_step = jax.jit(
                self._step_impl_paged, donate_argnums=(2,),
                out_shardings=(self.sharding.slot, self.sharding.state))
            self._jit_write = jax.jit(
                self._write_impl_paged, static_argnums=(4,),
                donate_argnums=(0,), out_shardings=self.sharding.state)
            self._jit_copy_slot = jax.jit(
                self._copy_slot_impl, donate_argnums=(0,),
                out_shardings=self.sharding.state)
            self._jit_copy_pages = jax.jit(
                self._copy_pages_impl, donate_argnums=(0,),
                out_shardings=self.sharding.state)
            self._jit_seed = jax.jit(self._seed_impl)
            # emitted tokens are (slots, K): rank-2 slot-leading — the
            # spec only reads dim0 divisibility, so any K shares it
            slot2 = NamedSharding(mesh,
                                  shd.dim0_dp_spec((slots, 2), mesh))
            self._jit_verify = jax.jit(
                self._verify_impl_paged, donate_argnums=(2,),
                out_shardings=(slot2, self.sharding.slot,
                               self.sharding.state))
        else:
            self._jit_step = jax.jit(
                self._step_impl, donate_argnums=(2,),
                out_shardings=(self.sharding.slot, self.sharding.state))
            self._jit_write = jax.jit(self._write_impl,
                                      donate_argnums=(0,),
                                      out_shardings=self.sharding.state)
        self._jit_sample = jax.jit(self._sample_impl)
        self._greedy = {}
        self._jit_prefill_fast = None
        self._jit_prefill_scan = None
        self._cache_templates = {}
        return self.sharding

    def place_cache(self, cache):
        """Place a NATIVE-layout prefill cache (batch = wave size) against
        the serve cache rules (used by serve.prefill for its templates)."""
        if self.mesh is None:
            return cache
        shapes = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), cache)
        spec = shd.serve_cache_specs(self.model.cache_axes(), shapes,
                                     self.mesh)
        return jax.device_put(cache,
                              shd.named_sharding_tree(spec, self.mesh))

    # -- state ----------------------------------------------------------
    def init_state(self, batch):
        state = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.state_spec(batch))
        if self.mesh is not None:
            state = jax.device_put(state,
                                   self._state_sharding(self.mesh, batch))
        return state

    # -- prefill (an admission wave of same-length prompts) -------------
    def chunk_for(self, plen: int) -> int:
        """The chunk width a ``plen``-token prompt prefills at — part of
        the prefix-cache key: attaching is bitwise only between requests
        sharing one chunk grid."""
        return min(self.prefill_chunk, pow2ceil(int(plen)))

    def prefill(self, params, xs, pos0=0, cache0=None, start=0):
        """xs: (B, P) int32 prompts.  Grid-padded chunking via
        serve.prefill, with the chunk capped at the next power of two of
        the prompt: a 10-token prompt pays a 16-wide chunk, not the full
        ``prefill_chunk`` — padding waste stays < 2x while the chunk
        program family stays log2-bounded (each width compiles once and
        serves every prompt length that buckets to it).

        ``cache0``/``start``: prefix-attach tail prefill — resume from a
        seeded cache (see :meth:`seed_cache`), consuming only the chunks
        from ``start`` (chunk-grid aligned) onward."""
        from repro.serve.prefill import chunked_prefill
        chunk = self.chunk_for(xs.shape[1])
        return chunked_prefill(self, params, xs, chunk=chunk, pos0=pos0,
                               cache0=cache0, start=start)

    # -- decode ---------------------------------------------------------
    def _step_impl(self, params, tok, state, pos, active, samp):
        if not self.positional:
            logits, new_state = self.model.decode_step(
                params, tok[:, None], state, jnp.int32(0))
            logits = logits[:, -1, :]
            merged = {}
            for name, sub in state.items():
                ax = self._slot_axis[name]
                merged[name] = masked_update(sub, new_state[name],
                                             active, axis=ax)
        else:
            vstep = jax.vmap(self.model.decode_step,
                             in_axes=(None, 0, 0, 0))
            logits, new_state = vstep(params, tok[:, None, None], state, pos)
            logits = logits[:, 0, -1, :]
            merged = masked_update(state, new_state, active)
        # the token produced from input position p lands at position p+1 —
        # the PRNG key folds in the GENERATED token's position, so the
        # admission-sampled first token (at pos = prompt length) and the
        # decode stream never collide on a counter value
        return self._sample_impl(logits, samp, pos + 1), merged

    def _step_impl_paged(self, params, tok, state, pos, active, samp, bt):
        """Natively slot-batched paged decode (no vmap: the page pools
        are shared state).  Pool leaves come back already frozen for
        inactive slots — their write was dropped in-layer — so only the
        per-slot O(1) leaves take the masked merge."""
        logits, new_state = self.model.decode_step_paged(
            params, tok[:, None], state, pos, bt, active, self.max_len)
        logits = logits[:, -1, :]
        merged = {}
        for name, sub in state.items():
            if name in self._pool_names:
                merged[name] = new_state[name]
            else:
                merged[name] = masked_update(sub, new_state[name], active,
                                             axis=self._slot_axis[name])
        return self._sample_impl(logits, samp, pos + 1), merged

    def step(self, params, tok, state, pos, active, sampling=None,
             bt=None):
        """tok: (slots,) int32; pos, active: (slots,); sampling: dict of
        per-slot knob arrays (None -> all-greedy arrays of the same
        dtypes, so greedy/sampled traffic share ONE compiled program);
        bt: (slots, max_pages) int32 block tables (paged layout only —
        plain DATA through the jitted step, like the sampling knobs).
        Under a bound mesh every host-side array is device_put against
        the slot sharding first, so each step dispatches the same
        compiled SPMD program (placement is part of the jit key)."""
        if sampling is None:
            n = int(tok.shape[0])
            if n not in self._greedy:
                g = greedy_arrays(n)
                if self.mesh is not None:
                    g = {k: self.put_slot(v) for k, v in g.items()}
                self._greedy[n] = g
            sampling = self._greedy[n]
        if self.mesh is not None:
            tok, pos, active = (self.put_slot(tok), self.put_slot(pos),
                                self.put_slot(active))
            sampling = {k: self.put_slot(v) for k, v in sampling.items()}
        if self.kv_layout == "paged":
            if bt is None:
                raise ValueError("paged kv_layout needs block tables "
                                 "(the engine passes pool.block_tables)")
            bt = jnp.asarray(bt, jnp.int32)
            if self.mesh is not None:
                bt = self.put_slot(bt)
            return self._jit_step(params, tok, state, pos, active,
                                  sampling, bt)
        return self._jit_step(params, tok, state, pos, active, sampling)

    # -- speculative verify (serve/spec.py + the engine drive this) ------
    def _verify_impl_paged(self, params, toks, state, pos, active, k_slot,
                           samp, bt):
        """ONE jitted program for the whole verify wave: score the K fed
        tokens against the untouched pools, run the rejection/residual
        verifier on the real-vocab fp32 logits, then commit exactly the
        accepted prefix's K/V — the pool never holds a speculative byte,
        so rollback is simply "don't advance pos"."""
        logits, blocks = self.model.verify_step_paged(
            params, toks, state, pos, bt, active, self.max_len)
        lg = logits[..., :self.vocab].astype(jnp.float32)
        emitted, n_emit = verify_tokens(
            lg, toks, k_slot, samp["seed"], samp["uid"], samp["uid_hi"],
            pos, samp["temperature"], samp["top_k"], samp["top_p"])
        n_emit = jnp.where(active, n_emit, 0)
        merged = self.model.commit_step_paged(
            state, blocks, pos, bt, n_emit, active, self.max_len)
        return emitted, n_emit, merged

    def verify(self, params, toks, state, pos, active, k_slot,
               sampling=None, bt=None):
        """k-token speculative verify.  ``toks``: (slots, K) int32 — per
        slot the CURRENT token (last emitted, not yet in cache) followed
        by K-1 greedy drafts, fed at positions ``pos .. pos+K-1``;
        ``k_slot``: (slots,) int32 per-slot verify widths (1..K — plain
        DATA, so heterogeneous widths share one compiled program).
        Returns ``(emitted (slots, K), n_emit (slots,), state)``:
        ``emitted[b, :n_emit[b]]`` are the tokens for stream positions
        ``pos[b]+1 ..``, their K/V already committed page-granularly
        (inactive slots report ``n_emit == 0`` and commit nothing).
        ``k_slot == 1`` everywhere is bitwise the plain :meth:`step`."""
        if self.kv_layout != "paged":
            raise ValueError("speculative verify needs kv_layout='paged' "
                             "(rollback = uncommitted pages)")
        if bt is None:
            raise ValueError("paged verify needs block tables "
                             "(the engine passes pool.block_tables)")
        toks = jnp.asarray(toks, jnp.int32)
        k_slot = jnp.asarray(k_slot, jnp.int32)
        bt = jnp.asarray(bt, jnp.int32)
        if sampling is None:
            n = int(toks.shape[0])
            if n not in self._greedy:
                g = greedy_arrays(n)
                if self.mesh is not None:
                    g = {k: self.put_slot(v) for k, v in g.items()}
                self._greedy[n] = g
            sampling = self._greedy[n]
        if self.mesh is not None:
            toks, pos, active = (self.put_slot(toks), self.put_slot(pos),
                                 self.put_slot(active))
            k_slot, bt = self.put_slot(k_slot), self.put_slot(bt)
            sampling = {k: self.put_slot(v) for k, v in sampling.items()}
        return self._jit_verify(params, toks, state, pos, active, k_slot,
                                sampling, bt)

    def _sample_impl(self, logits, samp, pos):
        """Per-row counter-keyed sampling over the REAL vocab; greedy rows
        (temperature <= 0) take the argmax path inside the same program.
        A runtime cond skips the whole stochastic pipeline (sorts, PRNG)
        when EVERY slot is greedy, so all-greedy traffic keeps the plain
        argmax hot path without a second compiled program."""
        lg = logits[..., :self.vocab].astype(jnp.float32)
        return jax.lax.cond(
            jnp.any(samp["temperature"] > 0.0),
            lambda: sample_tokens(lg, samp["seed"], samp["uid"],
                                  samp["uid_hi"], pos,
                                  samp["temperature"], samp["top_k"],
                                  samp["top_p"]),
            lambda: jnp.argmax(lg, -1).astype(jnp.int32))

    def sample(self, logits, sampling, pos):
        """Draw one token per row of ``logits`` (admission-wave shape)."""
        pos = jnp.asarray(pos, jnp.int32)
        if self.mesh is not None:
            sampling = {k: self.put_slot(v) for k, v in sampling.items()}
            pos = self.put_slot(pos)
        return self._jit_sample(logits, sampling, pos)

    def _emit_impl(self, logits):
        """Greedy over the REAL vocab (ignore Megatron padding columns).
        Kept as a debugging helper — the serving paths go through
        sample()/step(), whose greedy branch is this same argmax."""
        return jnp.argmax(logits[..., :self.vocab], -1).astype(jnp.int32)

    # -- slot writes ----------------------------------------------------
    def _write_impl(self, state, batch_state, slots):
        out = {}
        for name, sub in state.items():
            ax = self._slot_axis[name]

            def upd(s, v, ax=ax):
                if self.positional:
                    # stacked layout (slots, *unit): bring the cache batch
                    # axis to the front, re-insert its singleton, scatter.
                    v2 = jnp.expand_dims(jnp.moveaxis(v, ax, 0), 1 + ax)
                    return s.at[slots].set(v2.astype(s.dtype))
                if ax == 0:
                    return s.at[slots].set(v.astype(s.dtype))
                return s.at[:, slots].set(v.astype(s.dtype))

            out[name] = jax.tree_util.tree_map(upd, sub, batch_state[name])
        return out

    def _write_impl_paged(self, state, batch_state, slots, pages, plen):
        """Admission-wave install under the paged layout: O(1)-state
        leaves scatter at their slot ids (native layout), attention
        leaves scatter PAGE-granularly — the wave's dense prefill cache
        is resliced into (page,)-sized rows that land at the chain's page
        ids.  ``pages`` rows of padding wave entries are all out of
        bounds, so their writes drop exactly like padded slot ids."""
        ps = self.paged.page_size
        out = {}
        for name, sub in state.items():
            ax = self._slot_axis[name]
            if name in self._pool_names:
                def rows(v, ax=ax):
                    # v: dense wave cache; slot axis at ax, length at ax+1
                    # -> ((..., n, ps, ...) page rows, n)
                    Lv = v.shape[ax + 1]
                    n = -(-min(plen, Lv) // ps)
                    take = min(n * ps, Lv)
                    sl = [slice(None)] * v.ndim
                    sl[ax + 1] = slice(0, take)
                    v2 = v[tuple(sl)]
                    if take < n * ps:     # ring shorter than whole pages
                        padw = [(0, 0)] * v.ndim
                        padw[ax + 1] = (0, n * ps - take)
                        v2 = jnp.pad(v2, padw)
                    shape = v2.shape[:ax + 1] + (n, ps) + v2.shape[ax + 2:]
                    return v2.reshape(shape), n

                def scat(s, v2, n, ax=ax):
                    if ax == 0:
                        return s.at[pages[:, :n]].set(v2)
                    return s.at[:, pages[:, :n]].set(v2)

                # int8 pools carry float32 ``<key>_scale`` leaves the
                # dense wave cache does not have: quantize each data
                # leaf's page rows on install (symmetric absmax scale per
                # page per feature row) and scatter codes + scales.
                # Re-installing an unchanged page (prefix attaches
                # rewrite the whole chain) reproduces its codes
                # bit-exactly: a quantized page's max |code| is QMAX, so
                # the recomputed scale matches to float rounding.
                qkeys = ({k for k in sub if k + "_scale" in sub}
                         if isinstance(sub, dict) else set())
                if qkeys:
                    from repro.kernels.paged_attention import quant as kvq
                    nsub = {}
                    for key in sorted(qkeys):
                        v2, n = rows(batch_state[name][key])
                        sc = kvq.page_abs_scale(v2, page_axis=ax + 2)
                        codes = kvq.quantize(v2, sc, page_axis=ax + 2)
                        nsub[key] = scat(sub[key], codes, n)
                        nsub[key + "_scale"] = scat(sub[key + "_scale"],
                                                    sc, n)
                    out[name] = nsub
                    continue

                def updp(s, v, ax=ax):
                    v2, n = rows(v, ax=ax)
                    return scat(s, v2.astype(s.dtype), n, ax=ax)

                out[name] = jax.tree_util.tree_map(updp, sub,
                                                   batch_state[name])
            else:
                def upd(s, v, ax=ax):
                    if ax == 0:
                        return s.at[slots].set(v.astype(s.dtype))
                    return s.at[:, slots].set(v.astype(s.dtype))

                out[name] = jax.tree_util.tree_map(upd, sub,
                                                   batch_state[name])
        return out

    def write_slots(self, state, batch_state, slots, pages=None,
                    plen=None):
        """Install an admission wave's prefill carry into its slots.
        Paged layout: ``pages`` = the wave's block-table rows (padding
        rows all out of bounds) and ``plen`` = the wave's prompt length
        (static: it fixes how many pages each layer writes)."""
        slots = jnp.asarray(slots, jnp.int32)
        if self.mesh is not None:
            slots = jax.device_put(slots, self.sharding.replicated)
        if self.kv_layout == "paged":
            if pages is None or plen is None:
                raise ValueError("paged write_slots needs the wave's page "
                                 "rows and its prompt length")
            pages = jnp.asarray(pages, jnp.int32)
            if self.mesh is not None:
                pages = jax.device_put(pages, self.sharding.replicated)
            # the write program depends on plen only through per-leaf
            # PAGE counts, so round up to a page multiple before it
            # becomes the static jit key: prompt lengths that share page
            # buckets share one compiled write (identical program either
            # way — the page count ceil()s to the same value)
            ps = self.paged.page_size
            return self._jit_write(state, batch_state, slots, pages,
                                   -(-int(plen) // ps) * ps)
        return self._jit_write(state, batch_state, slots)


# ---------------------------------------------------------------------------
# MinimalistNetwork adapter (paper's edge-streaming case)
# ---------------------------------------------------------------------------

class MinimalistStepModel(StepModel):
    """Frame-streaming StepModel over ``core.mingru.MinimalistNetwork``.

    ``use_fused_kernel=True`` serves the exported hardware model through
    the fused single-step Pallas kernel (kernels.minimalist_block) — pass
    the *trained block params* as usual; the 2 b-code export
    (:func:`repro.kernels.minimalist_block.ops.from_block_params`) is
    cached per params object and redone (with a fresh jit trace, since
    the codes are baked in as constants) whenever a different params
    pytree is passed.
    """

    autoregressive = False

    def __init__(self, net, *, scan_backend=None, use_fused_kernel=False,
                 kernel_backend="pallas"):
        self.net = net
        self.scan_backend = scan_backend
        self.use_fused_kernel = use_fused_kernel
        self.kernel_backend = kernel_backend
        self._exported = None
        self._export_src = None
        self._jit_step = jax.jit(self._step_impl)
        self._jit_write = jax.jit(self._write_impl)

    # -- mesh placement --------------------------------------------------
    # Frame streaming serves DP-only: slots (and their O(1) states) shard
    # over "data"; the paper-scale analog blocks are far too small to pay
    # TP collectives, so params replicate.
    def shardings(self, mesh, slots, rules=None) -> ServeShardings:
        del rules
        repl = NamedSharding(mesh, P())
        state_shapes = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            self.net.initial_state(int(slots)))
        return ServeShardings(
            mesh=mesh, params=repl,
            state=shd.named_sharding_tree(
                shd.slot_specs(state_shapes, mesh), mesh),
            slot=NamedSharding(mesh, shd.dim0_dp_spec((int(slots),), mesh)),
            replicated=repl)

    def bind_mesh(self, mesh, slots, rules=None) -> ServeShardings:
        del rules                        # DP-only: no rule table in play
        slots = int(slots)
        if self.mesh is mesh and getattr(self, "_bound_slots", None) == slots:
            return self.sharding
        self._slot_shardings = {}
        self.mesh = mesh
        self._bound_slots = slots
        self.sharding = self.shardings(mesh, slots)
        self._jit_step = jax.jit(self._step_impl, donate_argnums=(2,),
                                 out_shardings=(self.sharding.slot,
                                                self.sharding.state))
        self._jit_write = jax.jit(self._write_impl, donate_argnums=(0,),
                                  out_shardings=self.sharding.state)
        return self.sharding

    def _export(self, params):
        """(Re)export 2 b codes when a different params object arrives.
        The codes enter the fused step as jit CONSTANTS, so the step jit
        is rebuilt alongside them — otherwise stale weights would serve
        silently after a checkpoint reload or QAT phase change."""
        if self._exported is None or self._export_src is not params:
            from repro.kernels.minimalist_block import ops as mb_ops
            self._exported = [mb_ops.from_block_params(params[b.name])
                              for b in self.net.blocks]
            self._export_src = params
            if self.mesh is not None:     # keep the bound-mesh jit options
                self._jit_step = jax.jit(
                    self._step_impl, donate_argnums=(2,),
                    out_shardings=(self.sharding.slot, self.sharding.state))
            else:
                self._jit_step = jax.jit(self._step_impl)
        return self._exported

    def init_state(self, batch):
        state = self.net.initial_state(batch)
        if self.mesh is not None:
            shapes = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
            state = jax.device_put(state, shd.named_sharding_tree(
                shd.slot_specs(shapes, self.mesh), self.mesh))
        return state

    def _raw_step(self, params, x, state):
        if self.use_fused_kernel:
            from repro.kernels.minimalist_block import ops as mb_ops
            out, new_states = x, []
            for i, exp in enumerate(self._exported):
                y, h = mb_ops.minimalist_step(
                    out, *exp, state[i], backend=self.kernel_backend)
                new_states.append(h)
                # readout layer: the analog h is the result (no comparator)
                out = h if i == len(self._exported) - 1 else y
            return out, new_states
        return self.net.step(params, x, state)

    def _step_impl(self, params, x, state, pos, active):
        del pos
        out, new_state = self._raw_step(params, x, state)
        return out, masked_update(state, new_state, active)

    def step(self, params, x, state, pos, active, sampling=None):
        """x: (slots, d_in) frames; pos unused (position-free); sampling
        ignored — frame streaming emits analog outputs, not tokens."""
        del sampling
        if self.use_fused_kernel:
            self._export(params)        # host-side, once; jit sees constants
        if self.mesh is not None:
            x, pos, active = (self.put_slot(x), self.put_slot(pos),
                              self.put_slot(active))
        return self._jit_step(params, x, state, pos, active)

    def emit(self, out):
        return out

    def _write_impl(self, state, batch_state, slots):
        return jax.tree_util.tree_map(
            lambda s, v: s.at[slots].set(v.astype(s.dtype)),
            state, batch_state)

    def write_slots(self, state, batch_state, slots):
        slots = jnp.asarray(slots, jnp.int32)
        if self.mesh is not None:
            slots = jax.device_put(slots, self.sharding.replicated)
            batch_state = jax.tree_util.tree_map(self.put_slot, batch_state)
        return self._jit_write(state, batch_state, slots)
