"""Pluggable admission/preemption policies (the SCHEDULER layer).

A :class:`SchedulingPolicy` decides two things, and only two things:

  * ``admit_order(queue, state)`` — the order in which waiting requests
    should be considered for admission.  The engine admits greedily from
    the front of this order and STOPS at the first candidate it cannot
    place (head-of-line within the policy's order): under ``fifo`` that
    is byte-for-byte the old strict-FIFO defer-at-head admission, under
    ``priority``/``sjf`` the head-of-line victim is a policy choice, not
    an accident of arrival order.
  * ``select_victim(state)`` — optionally name a RUNNING slot to preempt
    when the policy-ordered head is blocked (no free slot, or the page
    pool cannot cover its reservation).  The engine swaps the victim's
    page chain + carry to host memory, releases its pages, and re-queues
    it for later resume (see ``ServeEngine._preempt``); preempted-then-
    resumed streams are bitwise-equal to undisturbed runs.  Returning
    ``None`` (the default) disables preemption.

Policies see only the host-side :class:`~repro.serve.state.SlotTable`
— never device state or compiled programs — so a new policy is a few
lines of pure python with no retrace risk: the executor's jitted step
is the same ONE compiled program under every policy.

Determinism contract: every ordering ties-breaks on the request uid
(submission order), so a policy's decisions are a pure function of the
submitted workload — re-running the same submissions reproduces the
same admission order, the same preemptions, and (with the gather paged
impl) the same bits.
"""
from __future__ import annotations

from typing import List, Optional

from repro.serve.state import Request, SlotTable
from repro.serve.telemetry import NULL_TELEMETRY

#: Legal values of the engine's ``policy=`` knob / ``--policy`` flag.
POLICIES = ("fifo", "priority", "sjf", "edf")


class SchedulingPolicy:
    """Contract only; see module docstring."""

    name: str = "base"
    #: Observability handle, set by the engine at construction (no-op
    #: default) — victim selections emit trace instants through it.
    telemetry = NULL_TELEMETRY

    def begin_round(self, state: SlotTable):
        """Hook: called once per admission round (one engine step),
        before any ``admit_order`` call — aging counters live here."""

    def admit_order(self, queue, state: SlotTable) -> List[Request]:
        """Waiting requests in the order admission should try them."""
        raise NotImplementedError

    def select_victim(self, state: SlotTable) -> Optional[int]:
        """Slot to preempt so the blocked head can admit, or None."""
        return None

    def _head_blocked(self, state: SlotTable) -> Optional[Request]:
        """The policy-ordered head iff it cannot currently admit (the
        only situation preemption may consider a victim for)."""
        if not state.waiting:
            return None
        head = self.admit_order(state.waiting, state)[0]
        if state.free_mask and (state.pool is None or
                                state.pool.can_admit(
                                    state.pages_needed(head))):
            return None                    # nothing blocked — no victim
        return head

    def __repr__(self):
        return f"{type(self).__name__}()"


class FIFOPolicy(SchedulingPolicy):
    """Strict arrival order with defer-at-head — byte-for-byte the
    engine's historical admission (head-of-line blocking is the price
    of starvation-freedom).  Never preempts."""

    name = "fifo"

    def admit_order(self, queue, state):
        return list(queue)


class PriorityPolicy(SchedulingPolicy):
    """Per-request priority classes (``submit(priority=...)``, higher
    first), uid tie-break inside a class.  When the highest-priority
    waiting request is blocked, the lowest-priority running request
    (youngest — largest uid — within the class, so the least work is
    thrown away per eviction... the youngest has decoded fewest tokens
    under equal budgets) is offered as a preemption victim, but only on
    a STRICT priority gap: equal-priority traffic never thrashes."""

    name = "priority"

    def __init__(self, preempt: bool = True):
        self.preempt = bool(preempt)

    def admit_order(self, queue, state):
        return sorted(queue, key=lambda r: (-r.priority, r.uid))

    def select_victim(self, state):
        if not self.preempt or state.pool is None:
            return None                   # page swap is what makes
        head = self._head_blocked(state)  # eviction cheap — paged only
        if head is None:
            return None
        victim, freeable = None, 0
        for slot, r in state.running():
            if not r.priority < head.priority:
                continue                  # strict gap only: no thrash
            freeable += state.pool.reserved_for(slot)
            key = (r.priority, -r.uid)
            if victim is None or key < victim[0]:
                victim = (key, slot)
        if victim is None:
            return None
        # eviction must be able to unblock the head: the engine evicts
        # one victim per retry, so name one only if the CUMULATIVE
        # evictable set's released reservations (plus what is already
        # unreserved) cover the head's need — otherwise the eviction
        # discards decode work and admits nothing
        if state.pages_needed(head) > state.pool.available + freeable:
            return None
        if self.telemetry.enabled:
            self.telemetry.instant(
                "victim_selected", policy=self.name,
                slot=int(victim[1]),
                victim_uid=state.slot_req[victim[1]].uid,
                head_uid=head.uid)
        return victim[1]


class SJFPolicy(SchedulingPolicy):
    """Shortest-prefill-first with aging.  The admission key is
    ``prefill_cost - aging * rounds_waited`` (uid tie-break): short
    prompts jump the queue, but every waiting request's key falls by
    ``aging`` per engine step, so a prompt of length P is guaranteed to
    outrank ANY newcomer after at most ceil((P - 1) / aging) rounds —
    the starvation bound the policy tests pin.  Preempted requests have
    zero prefill left (their pages resume from host bytes), so they
    re-admit ahead of fresh prompts.  Never preempts on its own."""

    name = "sjf"

    def __init__(self, aging: float = 1.0):
        if not aging > 0:
            raise ValueError(f"aging must be > 0, got {aging}")
        self.aging = float(aging)
        self._age = {}                    # uid -> rounds spent waiting

    def begin_round(self, state):
        live = {r.uid for r in state.waiting}
        for uid in live:
            self._age[uid] = self._age.get(uid, -1) + 1
        for uid in set(self._age) - live:  # admitted / cancelled: forget
            del self._age[uid]

    def _cost(self, req):
        plen = 0 if req.snapshot is not None else len(req.prompt)
        return plen - self.aging * self._age.get(req.uid, 0)

    def admit_order(self, queue, state):
        return sorted(queue, key=lambda r: (self._cost(r), r.uid))


class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first: admission orders by ``Request.deadline``
    (``submit(deadline=...)`` — the classic real-time key), requests
    without a deadline sort last (+inf), uid tie-break.  When the
    earliest-deadline waiting request is blocked, the running request
    with the LATEST deadline is offered as a preemption victim — but
    only on a STRICT deadline gap (victim strictly later than the head),
    so two requests with the same deadline never thrash, and a
    no-deadline head never preempts anyone (it cannot be "earlier" than
    any running deadline).  No-deadline running requests (+inf) are the
    first victims — best-effort traffic yields to SLO traffic."""

    name = "edf"
    _INF = float("inf")

    def __init__(self, preempt: bool = True):
        self.preempt = bool(preempt)

    @classmethod
    def _key(cls, req):
        return cls._INF if req.deadline is None else float(req.deadline)

    def admit_order(self, queue, state):
        return sorted(queue, key=lambda r: (self._key(r), r.uid))

    def select_victim(self, state):
        if not self.preempt or state.pool is None:
            return None                   # page swap is what makes
        head = self._head_blocked(state)  # eviction cheap — paged only
        if head is None:
            return None
        hk = self._key(head)
        victim, freeable = None, 0
        for slot, r in state.running():
            if not self._key(r) > hk:
                continue                  # strict gap only: no thrash
            freeable += state.pool.reserved_for(slot)
            # latest deadline first; youngest (largest uid) inside a
            # deadline class, so the least decode work is thrown away
            key = (-self._key(r), -r.uid)
            if victim is None or key < victim[0]:
                victim = (key, slot)
        if victim is None:
            return None
        # same cumulative-unblock guard as PriorityPolicy: evicting must
        # be able to admit the head, or the work is thrown away for
        # nothing (the engine evicts one victim per retry)
        if state.pages_needed(head) > state.pool.available + freeable:
            return None
        if self.telemetry.enabled:
            self.telemetry.instant(
                "victim_selected", policy=self.name,
                slot=int(victim[1]),
                victim_uid=state.slot_req[victim[1]].uid,
                head_uid=head.uid)
        return victim[1]


def make_policy(policy) -> SchedulingPolicy:
    """Resolve the engine's ``policy=`` knob: a name from
    :data:`POLICIES` or an already-built SchedulingPolicy instance."""
    if isinstance(policy, SchedulingPolicy):
        return policy
    if policy == "fifo":
        return FIFOPolicy()
    if policy == "priority":
        return PriorityPolicy()
    if policy == "sjf":
        return SJFPolicy()
    if policy == "edf":
        return EDFPolicy()
    raise ValueError(f"policy must be one of {POLICIES} or a "
                     f"SchedulingPolicy instance, got {policy!r}")
