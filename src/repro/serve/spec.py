"""Speculative decoding: the minGRU drafter (README §Speculative decoding).

The paper's thesis — minimal-GRU recurrence is cheap enough to run "for
free" next to heavier compute — is exactly the draft-model property:
an O(1)-state minGRU stack proposes ``k-1`` greedy tokens per wave for
every active slot, and the attention target scores all ``k`` positions
in ONE ``verify_step_paged`` call (``DecoderStepModel.verify``), paying
its per-token weight/KV traffic once per wave instead of once per token.

:class:`DraftStepModel` wraps a pure-recurrent ``DecoderLM`` (every
mixer keeps O(1) state — minGRU/Mamba; no KV cache, no positions) and
keeps, per engine slot, the K stacked hidden states the last propose
wave produced: state ``m`` is the drafter's carry AFTER consuming the
wave's ``m``-th fed token.  When the verifier accepts ``n_emit`` tokens
the engine simply selects state ``n_emit - 1`` as the resume point for
the next wave (``sel``) — acceptance bookkeeping is an index, never a
recompute, and a rejected tail costs nothing on the drafter side either.

Alignment invariant (what makes ``sel`` correct): between waves,
``store[slot, sel]`` is the drafter state after consuming the stream up
to and including position ``pos - 1``, where ``pos``/``cur`` are the
slot's position and its last emitted-but-uncached token.  A propose
wave feeds ``cur, d_1, .., d_{K-1}`` (its own greedy drafts), so the
state after feed ``m`` corresponds to stream position ``pos + m`` — and
every accepted prefix ``d_1 .. d_a`` IS the true stream, so state
``a = n_emit - 1`` was computed from true tokens only.  The correction/
bonus token the verifier emits at ``pos + n_emit`` is never consumed
here: it becomes the next wave's ``cur``.

Everything runs as ONE jitted program per wave (``propose``): gather the
per-slot resume states, roll K greedy single-token ``decode_step`` calls
under ``lax.scan``, stack the K carries back into the store, and freeze
inactive slots.  Admission installs the drafter's own chunked-prefill
carry tiled K-wide (``sel = 0``); preemption/fork snapshot, restore and
copy single slot rows eagerly (rare, host-paced events).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ATTN_LOCAL, MLA
from repro.serve.protocol import DecoderStepModel, masked_update
from repro.serve.telemetry import NULL_TELEMETRY


class DraftStepModel:
    """K-token greedy draft proposer over a pure O(1)-state DecoderLM.

    ``store`` layout: the target engine's slot axis, then a K axis of
    stacked carries, inserted into the drafter's native decode-cache
    leaves — plain layers ``(slots, K, d)``, scanned units
    ``(n_repeats, slots, K, d)`` (slot axis 1, like the native cache).
    """

    def __init__(self, model, *, spec_k: int, prefill_chunk: int = 256):
        kinds = {s.kind for s in model.cfg.layer_specs()}
        if kinds & {ATTN, ATTN_LOCAL, MLA}:
            raise ValueError(
                f"drafter {model.cfg.name} carries attention layers "
                f"({sorted(kinds)}): a draft model must be a pure "
                "O(1)-state stack — its whole point is constant per-token "
                "state with no KV traffic")
        if int(spec_k) < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.model = model
        self.k = int(spec_k)
        self.vocab = model.cfg.vocab
        # the drafter reuses the serving prefill machinery through its own
        # (dense, position-free) adapter — admission prompts prefill with
        # the same grid-padded chunking as any O(1) stack
        self.sm = DecoderStepModel(model, max_len=1,
                                   prefill_chunk=prefill_chunk)
        self._slot_axis = self.sm._slot_axis
        self._jit_propose = jax.jit(self._propose_impl)
        self._jit_install = jax.jit(self._install_impl)
        # observability handle (no-op default; the engine passes its own)
        self.telemetry = NULL_TELEMETRY

    # -- store -----------------------------------------------------------
    def init_store(self, slots: int):
        """Zero store: (slots, K) stacked carries per decode-cache leaf."""
        spec = self.sm.state_spec(int(slots))
        out = {}
        for name, sub in spec.items():
            ax = self._slot_axis[name]

            def z(s, ax=ax):
                shape = s.shape[:ax + 1] + (self.k,) + s.shape[ax + 1:]
                return jnp.zeros(shape, s.dtype)

            out[name] = jax.tree_util.tree_map(z, sub)
        return out

    # -- propose (the per-wave hot path, ONE jitted program) -------------
    def _propose_impl(self, params, store, sel, tok, active):
        # gather each slot's resume carry: store[.., slot, sel[slot], ..]
        cache = {}
        for name, sub in store.items():
            ax = self._slot_axis[name]

            def take(s, ax=ax):
                idx = sel.reshape((1,) * ax + (-1, 1) +
                                  (1,) * (s.ndim - ax - 2))
                return jnp.take_along_axis(s, idx, axis=ax + 1) \
                          .squeeze(ax + 1)

            cache[name] = jax.tree_util.tree_map(take, sub)

        def body(carry, _):
            t, c = carry
            logits, c2 = self.model.decode_step(params, t[:, None], c,
                                                jnp.int32(0))
            nxt = jnp.argmax(logits[:, -1, :self.vocab],
                             -1).astype(jnp.int32)
            return (nxt, c2), (nxt, c2)

        (_, _), (drafts, states) = jax.lax.scan(
            body, (tok, cache), None, length=self.k)
        # drafts[m] = greedy continuation after feed m (= d_{m+1});
        # the verify wave feeds [cur, d_1, .., d_{K-1}] — the K-th draft
        # is rolled only for its carry (full acceptance resumes from it)
        toks = jnp.concatenate(
            [tok[:, None], drafts[:self.k - 1].T], axis=1)
        new_store = {}
        for name, sub in states.items():
            ax = self._slot_axis[name]
            ns = jax.tree_util.tree_map(
                lambda s, ax=ax: jnp.moveaxis(s, 0, ax + 1), sub)
            new_store[name] = masked_update(store[name], ns, active,
                                            axis=ax)
        return toks, new_store

    def propose(self, params, store, sel, tok, active):
        """Roll K greedy drafter steps per slot from its selected carry.
        ``sel``: (slots,) int32 — which of the K stacked carries is the
        resume point (the engine sets it to last wave's ``n_emit - 1``);
        ``tok``: (slots,) int32 current tokens.  Returns
        ``(toks (slots, K), new store)`` with ``toks[:, 0] == tok`` —
        exactly the verify wave's input.  Inactive slots keep their old
        carries and contribute garbage (ignored) drafts."""
        sel = jnp.asarray(sel, jnp.int32)
        tok = jnp.asarray(tok, jnp.int32)
        active = jnp.asarray(active)
        return self._jit_propose(params, store, sel, tok, active)

    # -- admission -------------------------------------------------------
    def prefill(self, params, xs):
        """Consume an admission wave's prompts; returns the (B,) native
        decode-cache carry (the wave's last logits are discarded — the
        TARGET draws the first token; the drafter only tracks state)."""
        _last, carry = self.sm.prefill(params, xs)
        return carry

    def _install_impl(self, store, carry, slots):
        out = {}
        for name, sub in store.items():
            ax = self._slot_axis[name]

            def upd(s, v, ax=ax):
                v = jnp.expand_dims(v.astype(s.dtype), ax + 1)
                shape = v.shape[:ax + 1] + (self.k,) + v.shape[ax + 2:]
                v = jnp.broadcast_to(v, shape)
                if ax == 0:
                    return s.at[slots].set(v)
                return s.at[:, slots].set(v)

            out[name] = jax.tree_util.tree_map(upd, sub, carry[name])
        return out

    def install(self, store, carry, slots):
        """Scatter an admission wave's prefill carry into its slots,
        tiled across the K axis (so ``sel = 0`` — or any index — resumes
        from the post-prompt state).  ``slots`` is the engine's padded
        wave slot list; out-of-bounds padding drops like every other
        admission scatter."""
        return self._jit_install(store, carry,
                                 jnp.asarray(slots, jnp.int32))

    # -- preemption / fork (rare host-paced events, eager ops) -----------
    def snapshot_slot(self, store, slot: int):
        """Host snapshot of one slot's (K,) stacked carries."""
        out = {}
        for name, sub in store.items():
            ax = self._slot_axis[name]
            out[name] = jax.tree_util.tree_map(
                lambda s, ax=ax: jax.lax.index_in_dim(
                    s, int(slot), axis=ax, keepdims=False), sub)
        self.telemetry.instant("draft_snapshot", slot=int(slot))
        return jax.device_get(out)

    def restore_slot(self, store, snap, slot: int):
        """Install a host snapshot back into ``slot`` (any slot — reads
        go through ``sel``, so the resumed stream drafts identically)."""
        out = {}
        for name, sub in store.items():
            ax = self._slot_axis[name]

            def put(s, v, ax=ax):
                v = jnp.asarray(v, s.dtype)
                if ax == 0:
                    return s.at[int(slot)].set(v)
                return s.at[:, int(slot)].set(v)

            out[name] = jax.tree_util.tree_map(put, sub, snap[name])
        self.telemetry.instant("draft_restore", slot=int(slot))
        return out

    def copy_slot(self, store, src: int, dst: int):
        """Fork: duplicate ``src``'s stacked carries into ``dst``."""
        out = {}
        for name, sub in store.items():
            ax = self._slot_axis[name]

            def cp(s, ax=ax):
                row = jax.lax.index_in_dim(s, int(src), axis=ax,
                                           keepdims=False)
                if ax == 0:
                    return s.at[int(dst)].set(row)
                return s.at[:, int(dst)].set(row)

            out[name] = jax.tree_util.tree_map(cp, sub)
        self.telemetry.instant("draft_copy", src=int(src), dst=int(dst))
        return out


def heterogeneous_k(requested, remaining, k_max: int):
    """Per-slot verify widths for one wave: the request's own ``spec_k``
    (or the engine default), clamped by the slot's remaining generation
    budget — a slot two tokens from its budget must not commit K/V
    beyond position ``pos + remaining`` (the page reservation and
    ``max_len`` bound stop there).  numpy in, numpy out (host path)."""
    return np.minimum(np.minimum(np.maximum(requested, 1), int(k_max)),
                      np.maximum(remaining, 1)).astype(np.int32)
