"""Per-request stochastic decoding for the serving engine.

Counter-based PRNG: every sampled token draws its randomness from

    key = fold_in(fold_in(fold_in(PRNGKey(seed), uid_lo), uid_hi), pos)

(the request uid split into its low 32 bits and the bits above them, so
the FULL uid reaches the key — no mask aliasing between long-lived
requests), so a request's stream depends only on its own ``(seed, uid)`` and the
absolute position of the token being generated — never on which other
requests share the slot batch, how admission waves were grouped, or how
many times the engine restarted a step.  The whole pipeline
(temperature -> top-k -> top-p -> Gumbel draw) is pure elementwise math
over the slot axis (one ``vmap``), so it lives INSIDE the single jitted
decode step: greedy and sampled traffic share one compiled program and
per-slot knobs arrive as arrays, never as retrace-triggering constants.

Filter semantics (matching the common serving convention):

  * ``temperature <= 0`` — greedy argmax (the stochastic path is fully
    bypassed for that slot).
  * ``top_k > 0``        — keep logits >= the k-th largest value (ties at
    the boundary are all kept); ``top_k == 0`` disables.
  * ``top_p < 1``        — keep the MINIMAL nucleus: tokens are ranked by
    probability and kept while the mass accumulated BEFORE a token is
    still < top_p, so the kept set is the smallest prefix whose total
    mass reaches top_p; ``top_p >= 1`` disables.

Filters compose in that order on the temperature-scaled logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def request_key(seed, uid, pos, uid_hi=0):
    """The counter-based per-token key: fold_in(seed, uid, uid_hi, pos).

    The request uid is folded in as TWO words (low 32 bits + the bits
    above them) so the full uid reaches the key — a single masked fold
    would alias requests whose uids differ by a multiple of the mask
    period into bitwise-identical sampled streams."""
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(jax.random.fold_in(key, uid), uid_hi)
    return jax.random.fold_in(key, pos)


def _filter_row(logits, temperature, top_k, top_p):
    """The temperature -> top-k -> top-p filter pipeline on one row of
    fp32 logits: returns the SCALED logits with every filtered token at
    -inf, so ``softmax(result)`` is exactly the distribution
    :func:`_sample_row` draws from.  Shared with the speculative-decode
    verifier, which must apply the identical filters to be
    distribution-preserving."""
    V = logits.shape[-1]
    scaled = logits / jnp.maximum(temperature, 1e-6)
    # top-k: threshold at the k-th largest scaled logit
    kth = jnp.sort(scaled)[::-1][jnp.clip(top_k, 1, V) - 1]
    use_k = (top_k > 0) & (top_k < V)
    scaled = jnp.where(use_k & (scaled < kth), -jnp.inf, scaled)
    # top-p: minimal nucleus of the (possibly top-k-truncated) distribution
    probs = jax.nn.softmax(scaled)
    order = jnp.argsort(-probs)
    mass_before = jnp.cumsum(probs[order]) - probs[order]
    keep_sorted = (mass_before < jnp.clip(top_p, 1e-6, 1.0)) | (top_p >= 1.0)
    keep = jnp.zeros((V,), bool).at[order].set(keep_sorted)
    return jnp.where(keep, scaled, -jnp.inf)


def _sample_row(logits, seed, uid, uid_hi, pos, temperature, top_k, top_p):
    """One slot's token draw. logits: (V,) over the REAL vocab."""
    logits = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(logits).astype(jnp.int32)
    scaled = _filter_row(logits, temperature, top_k, top_p)
    tok = jax.random.categorical(request_key(seed, uid, pos, uid_hi),
                                 scaled)
    return jnp.where(temperature <= 0.0, greedy_tok, tok.astype(jnp.int32))


#: Batched draw over the slot/wave axis.  All arguments are (B, …) arrays;
#: each row is sampled independently from its own counter-based key, which
#: is what makes a request's tokens reproducible under any co-batching.
sample_tokens = jax.vmap(_sample_row)


# ---------------------------------------------------------------------------
# Speculative decoding: rejection/residual sampling (see serve/spec.py)
# ---------------------------------------------------------------------------

#: Salts folded into the per-position counter key so the verifier's
#: accept-uniform and residual draws are independent of each other AND of
#: the plain sequential draw at the same position (which uses the unsalted
#: key).  Values are arbitrary distinct constants.
ACCEPT_SALT = 0x5A11
RESID_SALT = 0x5A12


def rejection_sample_row(p_logits, q_logits, draft_tok, seed, uid, uid_hi,
                         pos):
    """One general-q rejection/residual step — the textbook speculative
    sampling rule: accept ``draft_tok`` with probability
    ``min(1, p/q)``, else draw from the normalized residual ``(p-q)+``.
    The composite marginal is EXACTLY ``p`` for any proposal ``q``.

    Randomness is counter-keyed by ``(seed, uid, pos)`` like every other
    draw: the accept uniform folds in :data:`ACCEPT_SALT`, the residual
    draw :data:`RESID_SALT`.  Returns ``(token, accepted)``.  The
    engine's verifier uses the one-hot-q special case (the drafter
    proposes greedily), where accept probability reduces to ``p(draft)``
    and the residual to ``p`` with the draft token removed; this general
    form is the reference the hypothesis tests pin."""
    p = jax.nn.softmax(p_logits.astype(jnp.float32))
    q = jax.nn.softmax(q_logits.astype(jnp.float32))
    base = request_key(seed, uid, pos, uid_hi)
    u = jax.random.uniform(jax.random.fold_in(base, ACCEPT_SALT))
    ratio = p[draft_tok] / jnp.maximum(q[draft_tok], 1e-30)
    accepted = u < jnp.minimum(1.0, ratio)
    resid = jnp.maximum(p - q, 0.0)
    resid_logits = jnp.where(resid > 0, jnp.log(resid), -jnp.inf)
    # p == q exactly -> empty residual, but then ratio == 1 and the
    # accept branch always wins, so the (arbitrary) categorical output
    # of an all--inf row is never selected
    r = jax.random.categorical(jax.random.fold_in(base, RESID_SALT),
                               resid_logits)
    return (jnp.where(accepted, draft_tok, r).astype(jnp.int32),
            accepted)


def _verify_row(logits, toks, k_slot, seed, uid, uid_hi, pos,
                temperature, top_k, top_p):
    """One slot's k-token verification.

    ``logits``: (K, V) target logits over the REAL vocab for the K fed
    tokens ``toks`` = [current, d_1, .., d_{K-1}] at positions
    ``pos .. pos+K-1`` — row j is the target's distribution for stream
    position ``pos+1+j``.  The drafter proposes GREEDILY, so its
    proposal at each tested position is the one-hot distribution at
    ``toks[j+1]``: rejection sampling degenerates to accept-with-
    probability ``p(draft)``, residual = ``p`` with the draft removed
    and renormalized — exactly the general rule of
    :func:`rejection_sample_row` specialized to one-hot q.

    ``k_slot`` (1..K) is this slot's verify width: only drafts
    ``toks[1..k_slot-1]`` are tested; ``k_slot == 1`` degenerates to
    plain single-token decode (zero tests, one plain draw).

    Greedy rows (temperature <= 0): a draft is accepted iff it equals
    the raw-fp32 argmax — the same argmax :func:`_sample_row` computes —
    so a fully-greedy stream is BITWISE the sequential greedy stream.
    Sampled rows accept with the target probability after the identical
    temperature/top-k/top-p filters, and every draw is counter-keyed by
    the position it decides, so output bytes are reproducible under any
    co-batching or acceptance history.

    Returns ``(emitted (K,), n_emit)``: ``emitted[:n_emit]`` are the
    tokens for positions ``pos+1 .. pos+n_emit`` (accepted drafts plus
    one correction/bonus token); ``n_emit`` is in ``[1, k_slot]``."""
    K, V = logits.shape
    lg = logits.astype(jnp.float32)
    idx = jnp.arange(K, dtype=jnp.int32)
    positions = pos + 1 + idx             # stream position row j decides
    greedy_toks = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    filtered = jax.vmap(_filter_row, in_axes=(0, None, None, None))(
        lg, temperature, top_k, top_p)
    probs = jax.nn.softmax(filtered, axis=-1)
    # draft tested against row j is toks[j+1]; the last row has no draft
    # (it only ever produces the correction/bonus draw)
    drafts = jnp.concatenate([toks[1:], toks[:1]])
    base_keys = jax.vmap(
        lambda p_: request_key(seed, uid, p_, uid_hi))(positions)
    u = jax.vmap(lambda k_: jax.random.uniform(
        jax.random.fold_in(k_, ACCEPT_SALT)))(base_keys)
    p_draft = jnp.take_along_axis(probs, drafts[:, None], axis=-1)[:, 0]
    accept = jnp.where(temperature <= 0.0,
                       drafts == greedy_toks, u < p_draft)
    valid = idx < (k_slot - 1)            # rows with a draft to test
    a = jnp.sum(jnp.cumprod((accept & valid).astype(jnp.int32)))
    n_emit = (a + 1).astype(jnp.int32)
    # residual draw at each row: target with the rejected draft removed
    resid_logits = jnp.where(jnp.arange(V)[None, :] == drafts[:, None],
                             -jnp.inf, filtered)
    r = jax.vmap(lambda k_, rl: jax.random.categorical(
        jax.random.fold_in(k_, RESID_SALT), rl))(
        base_keys, resid_logits).astype(jnp.int32)
    # plain draw: what the SEQUENTIAL sampler would emit at this position
    # (used on full acceptance — the free bonus token)
    b = jax.vmap(_sample_row,
                 in_axes=(0, None, None, None, 0, None, None, None))(
        lg, seed, uid, uid_hi, positions, temperature, top_k, top_p)
    full = n_emit == k_slot
    fix = jnp.where(temperature <= 0.0, greedy_toks,
                    jnp.where(full, b, r))
    emitted = jnp.where(idx < a, drafts, fix)
    return emitted, n_emit


#: Batched k-token verification over the slot axis: all arguments are
#: (B, ...) arrays (logits (B, K, V), toks (B, K), the rest (B,)).
verify_tokens = jax.vmap(_verify_row)


#: The per-slot knob schema.  Every producer of knob arrays (the engine's
#: slot state, admission waves, greedy defaults) MUST use these dtypes —
#: exact agreement is what keeps every traffic mix on ONE compiled decode
#: step (a drifted dtype would silently retrace).
KNOB_DTYPES = {
    "seed": jnp.uint32,
    "uid": jnp.uint32,       # low 32 bits of the request uid
    "uid_hi": jnp.uint32,    # bits 32..63 — folded separately (full uid)
    "temperature": jnp.float32,
    "top_k": jnp.int32,
    "top_p": jnp.float32,
}

#: Knob values that reproduce greedy argmax.
KNOB_GREEDY = {"seed": 0, "uid": 0, "uid_hi": 0, "temperature": 0.0,
               "top_k": 0, "top_p": 1.0}


def greedy_arrays(n):
    """Per-slot sampling knobs that reproduce greedy argmax (the defaults
    the engine installs in every slot until a sampled request claims it)."""
    return {k: jnp.full((n,), KNOB_GREEDY[k], KNOB_DTYPES[k])
            for k in KNOB_DTYPES}
