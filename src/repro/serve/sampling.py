"""Per-request stochastic decoding for the serving engine.

Counter-based PRNG: every sampled token draws its randomness from

    key = fold_in(fold_in(fold_in(PRNGKey(seed), uid_lo), uid_hi), pos)

(the request uid split into its low 32 bits and the bits above them, so
the FULL uid reaches the key — no mask aliasing between long-lived
requests), so a request's stream depends only on its own ``(seed, uid)`` and the
absolute position of the token being generated — never on which other
requests share the slot batch, how admission waves were grouped, or how
many times the engine restarted a step.  The whole pipeline
(temperature -> top-k -> top-p -> Gumbel draw) is pure elementwise math
over the slot axis (one ``vmap``), so it lives INSIDE the single jitted
decode step: greedy and sampled traffic share one compiled program and
per-slot knobs arrive as arrays, never as retrace-triggering constants.

Filter semantics (matching the common serving convention):

  * ``temperature <= 0`` — greedy argmax (the stochastic path is fully
    bypassed for that slot).
  * ``top_k > 0``        — keep logits >= the k-th largest value (ties at
    the boundary are all kept); ``top_k == 0`` disables.
  * ``top_p < 1``        — keep the MINIMAL nucleus: tokens are ranked by
    probability and kept while the mass accumulated BEFORE a token is
    still < top_p, so the kept set is the smallest prefix whose total
    mass reaches top_p; ``top_p >= 1`` disables.

Filters compose in that order on the temperature-scaled logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def request_key(seed, uid, pos, uid_hi=0):
    """The counter-based per-token key: fold_in(seed, uid, uid_hi, pos).

    The request uid is folded in as TWO words (low 32 bits + the bits
    above them) so the full uid reaches the key — a single masked fold
    would alias requests whose uids differ by a multiple of the mask
    period into bitwise-identical sampled streams."""
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(jax.random.fold_in(key, uid), uid_hi)
    return jax.random.fold_in(key, pos)


def _sample_row(logits, seed, uid, uid_hi, pos, temperature, top_k, top_p):
    """One slot's token draw. logits: (V,) over the REAL vocab."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(logits).astype(jnp.int32)
    scaled = logits / jnp.maximum(temperature, 1e-6)
    # top-k: threshold at the k-th largest scaled logit
    kth = jnp.sort(scaled)[::-1][jnp.clip(top_k, 1, V) - 1]
    use_k = (top_k > 0) & (top_k < V)
    scaled = jnp.where(use_k & (scaled < kth), -jnp.inf, scaled)
    # top-p: minimal nucleus of the (possibly top-k-truncated) distribution
    probs = jax.nn.softmax(scaled)
    order = jnp.argsort(-probs)
    mass_before = jnp.cumsum(probs[order]) - probs[order]
    keep_sorted = (mass_before < jnp.clip(top_p, 1e-6, 1.0)) | (top_p >= 1.0)
    keep = jnp.zeros((V,), bool).at[order].set(keep_sorted)
    scaled = jnp.where(keep, scaled, -jnp.inf)
    tok = jax.random.categorical(request_key(seed, uid, pos, uid_hi),
                                 scaled)
    return jnp.where(temperature <= 0.0, greedy_tok, tok.astype(jnp.int32))


#: Batched draw over the slot/wave axis.  All arguments are (B, …) arrays;
#: each row is sampled independently from its own counter-based key, which
#: is what makes a request's tokens reproducible under any co-batching.
sample_tokens = jax.vmap(_sample_row)


#: The per-slot knob schema.  Every producer of knob arrays (the engine's
#: slot state, admission waves, greedy defaults) MUST use these dtypes —
#: exact agreement is what keeps every traffic mix on ONE compiled decode
#: step (a drifted dtype would silently retrace).
KNOB_DTYPES = {
    "seed": jnp.uint32,
    "uid": jnp.uint32,       # low 32 bits of the request uid
    "uid_hi": jnp.uint32,    # bits 32..63 — folded separately (full uid)
    "temperature": jnp.float32,
    "top_k": jnp.int32,
    "top_p": jnp.float32,
}

#: Knob values that reproduce greedy argmax.
KNOB_GREEDY = {"seed": 0, "uid": 0, "uid_hi": 0, "temperature": 0.0,
               "top_k": 0, "top_p": 1.0}


def greedy_arrays(n):
    """Per-slot sampling knobs that reproduce greedy argmax (the defaults
    the engine installs in every slot until a sampled request claims it)."""
    return {k: jnp.full((n,), KNOB_GREEDY[k], KNOB_DTYPES[k])
            for k in KNOB_DTYPES}
