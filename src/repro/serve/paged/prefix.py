"""Hash-keyed prefix cache over the refcounted page pool.

A finished admission wave's prompt pages stay useful: a later request
whose page-aligned prompt prefix matches a resident entry ATTACHES to
the existing pages (``PagePool.share``) and chunk-prefills only the
tail — pay the shared system prompt's prefill once, vLLM/SGLang style.

Keys are CHAINED blake2b digests over page-sized token blocks:
``h_i = H(h_{i-1} || tokens[i*ps:(i+1)*ps])`` — so the digest of the
first ``i`` pages keys exactly that token prefix, and matching walks the
new prompt's own digests longest-first.

Soundness contract (enforced jointly with the engine / StepModel):

  * entries pin their pages via ``PagePool.incref`` — a pinned page can
    be freed only by eviction, and the parent request's own decode
    writes copy-on-write away from it, so pinned content is FROZEN at
    its post-prefill bytes;
  * global/MLA stacks (``full_prompt_only=False``) insert one entry per
    page-aligned prompt prefix — later writes land in later pages, so
    every page prefix is clean;
  * window-bearing stacks (``full_prompt_only=True``) insert a single
    entry per prompt, only when the prompt is page-aligned: ring slots
    are overwritten DURING prefill, so only the end-of-prompt ring state
    exists in the pages.  A match additionally requires the attach point
    to sit on the requester's chunk grid with at least one tail token —
    the ring-snapshot mask infers entry positions from ``pos0``, so the
    tail prefill must start exactly at the attach point;
  * an entry matches only a requester with the SAME prefill chunk width
    (``chunk_w``): chunk shapes are part of the bitwise contract;
  * the cache never blocks admission: ``available`` accounting ignores
    pins, and the pool's ``reclaim`` hook (wired here) evicts LRU
    entries when the free list runs dry, so a reserve-covered
    allocation always finds a page.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.telemetry import NULL_TELEMETRY


class PrefixCache:
    """LRU prefix cache; all host-side (token hashing + page pinning)."""

    def __init__(self, pool, page_size: int, *,
                 full_prompt_only: bool = False):
        self.pool = pool
        self.ps = int(page_size)
        self.full_prompt_only = bool(full_prompt_only)
        # digest -> {"pages": tuple, "plen": int, "chunk_w": int, "tick"}
        self._entries: dict = {}
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.n_evicted = 0
        pool.reclaim = self.reclaim
        #: observability handle (no-op default; the engine passes its own)
        self.telemetry = NULL_TELEMETRY

    def __len__(self):
        return len(self._entries)

    @property
    def pinned_pages(self) -> int:
        """Distinct pages currently pinned by resident entries."""
        return len({p for e in self._entries.values() for p in e["pages"]})

    # -- hashing ---------------------------------------------------------
    def _digests(self, tokens, n_pages: int) -> List[bytes]:
        a = np.ascontiguousarray(
            np.asarray(tokens[:n_pages * self.ps], np.int32))
        out, h = [], b""
        for i in range(n_pages):
            blk = a[i * self.ps:(i + 1) * self.ps]
            h = hashlib.blake2b(h + blk.tobytes(), digest_size=16).digest()
            out.append(h)
        return out

    # -- lookup ----------------------------------------------------------
    def match(self, prompt, chunk_w: int) -> Tuple[Optional[List[int]],
                                                   int]:
        """Longest resident page-aligned prefix of ``prompt`` admissible
        for a requester prefilling at ``chunk_w``.  Returns
        ``(pages, attach)`` — the pages to share (NOT yet increfed; the
        caller shares them into a slot) and the attach length in
        positions — or ``(None, 0)`` on a miss."""
        plen = len(prompt)
        m = plen // self.ps
        digs = self._digests(prompt, m) if m else []
        for i in range(m, 0, -1):
            e = self._entries.get(digs[i - 1])
            if e is None or e["chunk_w"] != int(chunk_w):
                continue
            attach = i * self.ps
            if self.full_prompt_only and (attach % int(chunk_w)
                                          or attach >= plen):
                # window ring: the tail must START at the attach point on
                # the requester's chunk grid, with >= 1 token to prefill
                continue
            self._tick += 1
            e["tick"] = self._tick
            self.hits += 1
            if self.telemetry.enabled:
                self.telemetry.inc("prefix_hits")
            return list(e["pages"]), attach
        self.misses += 1
        if self.telemetry.enabled:
            self.telemetry.inc("prefix_misses")
        return None, 0

    # -- insertion -------------------------------------------------------
    def insert(self, prompt, block_row, chunk_w: int):
        """Pin ``prompt``'s freshly written pages (``block_row`` = the
        slot's block-table row).  Global/MLA mode inserts every
        page-aligned prefix; window mode inserts the full prompt only
        (and only when page-aligned).  Re-inserting a resident prefix
        just refreshes its LRU tick."""
        plen = len(prompt)
        m = plen // self.ps
        if m == 0:
            return
        if self.full_prompt_only and plen % self.ps:
            return
        digs = self._digests(prompt, m)
        first = m if self.full_prompt_only else 1
        for i in range(first, m + 1):
            key = digs[i - 1]
            self._tick += 1
            e = self._entries.get(key)
            if e is not None:
                e["tick"] = self._tick
                continue
            pages = tuple(int(p) for p in block_row[:i])
            self.pool.incref(pages)
            self._entries[key] = {"pages": pages, "plen": i * self.ps,
                                  "chunk_w": int(chunk_w),
                                  "tick": self._tick}

    # -- eviction ----------------------------------------------------------
    def _evict(self, key) -> List[int]:
        e = self._entries.pop(key)
        self.n_evicted += 1
        if self.telemetry.enabled:
            self.telemetry.inc("prefix_evictions")
        return self.pool.decref(e["pages"])

    def reclaim(self, n: int = 1):
        """Pool hook: free at least ``n`` pages by evicting LRU entries
        (stops when the cache is empty — the pool's reservation
        invariant guarantees that suffices for covered allocations)."""
        freed = 0
        while self._entries and freed < n:
            key = min(self._entries, key=lambda k: self._entries[k]["tick"])
            freed += len(self._evict(key))

    def clear(self):
        """Drop every entry (and its pins)."""
        while self._entries:
            self._evict(next(iter(self._entries)))
