"""Paged KV cache: block-table page pool for the serving engine.

The dense serving layout preallocates ``(slots, max_len, ...)`` cache
rows — memory scales with the worst case, not with live tokens.  Under
the paged layout every attention layer keeps a shared page pool
``(num_pages, page_size, ...)`` on device, and this package's HOST-side
allocator hands page ids to requests:

  * :class:`PagedConfig` — page size / pool capacity knobs (validated)
  * :class:`PagePool`    — refcounted free-list allocator: per-request
    page chains, one block-table row per slot, reservation-based
    admission (a request is admitted only when its worst-case chain is
    covered, so decode can NEVER run out of pages mid-stream),
    allocate-on-decode-append, copy-on-write page sharing (forks /
    prefix attaches), and free-on-finish/cancel at refcount zero.
  * :class:`PrefixCache` — hash-keyed LRU cache pinning finished
    prompts' pages so matching requests attach and prefill only the
    tail (``ServeEngine(prefix_cache=True)``).

See README §Paged KV cache / §Prefix caching & copy-on-write forks for
the layout diagram and the admission policy (OOM at submit for
can-never-fit requests; DEFER at admit when the pool is temporarily
full).
"""
from repro.serve.paged.pool import PagedConfig, PagePool
from repro.serve.paged.prefix import PrefixCache

__all__ = ["PagedConfig", "PagePool", "PrefixCache"]
