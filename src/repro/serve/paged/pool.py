"""Block-table page allocator (host side).

Pages are rows of the device-resident pools; this module only moves
int32 page ids around.  Invariants the serving engine relies on:

  * a page id belongs to exactly one slot's chain or to the free list
    (never both, never two chains) — so concurrent slots can scatter
    into the shared pool without write aliasing;
  * reservations are conservative: ``reserve`` succeeds only if the
    request's WORST-CASE page count fits alongside every other
    outstanding reservation, so ``grow`` (allocate-on-decode-append) can
    never fail mid-stream — the OOM-vs-defer decision happens once, at
    admission, never during decode;
  * ``release`` returns both the allocated pages and the unused tail of
    the reservation (an eos-retired request frees capacity it never
    touched).
"""
from __future__ import annotations

import numpy as np


class PagedConfig:
    """Paged-KV knobs.  ``num_pages == 0`` means auto-size the pool to
    dense-equivalent capacity (slots × pages-per-max-length-request) —
    useful for bitwise paged-vs-dense testing; production deployments
    set it below that to actually save memory."""

    def __init__(self, page_size: int = 16, num_pages: int = 0):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if num_pages < 0:
            raise ValueError(f"num_pages must be >= 0 (0 = auto-size), "
                             f"got {num_pages}")
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)

    def validate_for(self, max_len: int, pages_per_request: int):
        """A pool that cannot hold ONE max-length request can never
        serve anything — fail at construction, not mid-traffic."""
        if self.num_pages and self.num_pages < pages_per_request:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold one max-length "
                f"request: max_len={max_len} at page_size={self.page_size} "
                f"needs {pages_per_request} pages (raise num_pages to >= "
                f"{pages_per_request}, or 0 to auto-size)")
        return self

    def resolve_num_pages(self, slots: int, pages_per_request: int) -> int:
        return self.num_pages or int(slots) * int(pages_per_request)

    def __repr__(self):
        return (f"PagedConfig(page_size={self.page_size}, "
                f"num_pages={self.num_pages})")


class PagePool:
    """Free-list page allocator over ``num_pages`` pages for ``slots``
    concurrent requests, each owning up to ``max_pages`` chain entries
    (one block-table row)."""

    def __init__(self, num_pages: int, slots: int, max_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = int(num_pages)
        self.slots = int(slots)
        self.max_pages = int(max_pages)
        # LIFO free list: pop() hands out the lowest ids first
        self._free = list(range(self.num_pages - 1, -1, -1))
        # unallocated entries stay 0: reads through them are always
        # position-masked (they clamp harmlessly in gathers/kernels)
        self.block_tables = np.zeros((self.slots, self.max_pages),
                                     np.int32)
        self.chain_len = np.zeros(self.slots, np.int32)
        self._reserved = np.zeros(self.slots, np.int64)
        self.reserved_total = 0

    # -- accounting -----------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        """Pages physically allocated to chains."""
        return self.num_pages - len(self._free)

    @property
    def available(self) -> int:
        """Pages not yet promised to any admitted request."""
        return self.num_pages - self.reserved_total

    # -- admission ------------------------------------------------------
    def can_admit(self, n_pages: int) -> bool:
        return n_pages <= self.available

    def reserve(self, slot: int, n_pages: int):
        """Promise ``n_pages`` to ``slot`` (its worst-case chain)."""
        if not self.can_admit(n_pages):
            raise RuntimeError(
                f"reserve({n_pages}) exceeds available pages "
                f"({self.available}) — admit() must check can_admit first")
        if self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        self._reserved[slot] = n_pages
        self.reserved_total += n_pages

    # -- allocate-on-append ---------------------------------------------
    def grow(self, slot: int, n_chain: int):
        """Extend ``slot``'s chain to ``n_chain`` pages, drawing on its
        reservation.  Called at admission (prompt pages) and before each
        decode step that crosses a page boundary."""
        if n_chain > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: chain of {n_chain} pages exceeds its "
                f"reservation of {int(self._reserved[slot])} — scheduler "
                "bug (reservations are sized to the worst case)")
        while self.chain_len[slot] < n_chain:
            self.block_tables[slot, self.chain_len[slot]] = self._free.pop()
            self.chain_len[slot] += 1

    # -- free ------------------------------------------------------------
    def release(self, slot: int):
        """Finish/cancel: return the chain to the free list and drop the
        remaining reservation.  Idempotent for an empty slot."""
        n = int(self.chain_len[slot])
        self._free.extend(int(p) for p in self.block_tables[slot, :n])
        self.reserved_total -= int(self._reserved[slot])
        self._reserved[slot] = 0
        self.chain_len[slot] = 0
        self.block_tables[slot, :] = 0
