"""Block-table page allocator (host side) with per-page refcounts.

Pages are rows of the device-resident pools; this module only moves
int32 page ids around.  Invariants the serving engine relies on:

  * a page id belongs to the free list or has ``refcount >= 1``; a page
    with ``refcount == 1`` has exactly ONE writer (its owning chain), so
    concurrent slots can scatter into the shared pool without write
    aliasing — a chain about to WRITE into a page with ``refcount > 1``
    must first :meth:`cow` it (copy-on-write);
  * reservations are conservative UNDER SHARING: ``reserve`` charges
    every chain its full worst-case page count even when it currently
    shares pages with a parent chain or the prefix cache, so ``grow``
    (allocate-on-decode-append) and ``cow`` can never fail mid-stream —
    shared pages are a bonus, never load-bearing capacity.  Formally:
    every live chain's length is ``<= _reserved[slot]``, each physical
    page is counted at most once per chain holding it, so
    ``pages_in_use <= reserved_total + held_external`` and after the
    reclaim hook drains external holds ``len(_free) >= num_pages -
    reserved_total >= 0`` whenever a reserve-covered pop happens;
  * ``release`` decrements refcounts and returns only pages that hit
    zero (plus the unused reservation tail) — forks/prefix holds keep
    shared pages alive;
  * external holders (the prefix cache) pin pages via
    :meth:`incref`/:meth:`decref`; when the free list runs dry the pool
    calls its ``reclaim`` hook so the holder can drop unpinned pages
    before a reserve-covered allocation would fail.

Quantized pools (``kv_dtype="int8"``) change nothing here: a page id
names the page's int8 code row AND its float32 scale row in every pool
leaf, so refcounts, COW, and release move them as one unit — the device
side (``DecoderStepModel.copy_pages`` / ``_write_impl_paged``) copies
and installs ``<key>_scale`` leaves page-for-page with their codes.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.telemetry import NULL_TELEMETRY


class PagedConfig:
    """Paged-KV knobs.  ``num_pages == 0`` means auto-size the pool to
    dense-equivalent capacity (slots × pages-per-max-length-request) —
    useful for bitwise paged-vs-dense testing; production deployments
    set it below that to actually save memory."""

    def __init__(self, page_size: int = 16, num_pages: int = 0):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if num_pages < 0:
            raise ValueError(f"num_pages must be >= 0 (0 = auto-size), "
                             f"got {num_pages}")
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)

    def validate_for(self, max_len: int, pages_per_request: int):
        """A pool that cannot hold ONE max-length request can never
        serve anything — fail at construction, not mid-traffic."""
        if self.num_pages and self.num_pages < pages_per_request:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold one max-length "
                f"request: max_len={max_len} at page_size={self.page_size} "
                f"needs {pages_per_request} pages (raise num_pages to >= "
                f"{pages_per_request}, or 0 to auto-size)")
        return self

    def resolve_num_pages(self, slots: int, pages_per_request: int) -> int:
        return self.num_pages or int(slots) * int(pages_per_request)

    def __repr__(self):
        return (f"PagedConfig(page_size={self.page_size}, "
                f"num_pages={self.num_pages})")


class PagePool:
    """Refcounted free-list page allocator over ``num_pages`` pages for
    ``slots`` concurrent requests, each owning up to ``max_pages`` chain
    entries (one block-table row)."""

    def __init__(self, num_pages: int, slots: int, max_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = int(num_pages)
        self.slots = int(slots)
        self.max_pages = int(max_pages)
        # LIFO free list: pop() hands out the lowest ids first
        self._free = list(range(self.num_pages - 1, -1, -1))
        # unallocated entries stay 0: reads through them are always
        # position-masked (they clamp harmlessly in gathers/kernels)
        self.block_tables = np.zeros((self.slots, self.max_pages),
                                     np.int32)
        self.chain_len = np.zeros(self.slots, np.int32)
        self._reserved = np.zeros(self.slots, np.int64)
        self.reserved_total = 0
        # one count per physical page: chains holding it + external holds
        self.refcount = np.zeros(self.num_pages, np.int32)
        #: called with the number of pages needed when the free list runs
        #: dry (the prefix cache evicts unpinned entries); may be None.
        self.reclaim: Optional[Callable[[int], None]] = None
        # telemetry
        self.n_cow = 0
        #: observability handle (no-op default; the engine passes its
        #: own) — occupancy gauges + reclaim/COW counters, host-side only
        self.telemetry = NULL_TELEMETRY

    def _tel_pages(self):
        """Refresh the pool occupancy gauges (cheap; enabled path only)."""
        tel = self.telemetry
        tel.gauge("pages_in_use", self.pages_in_use)
        tel.gauge("pages_free", len(self._free))
        tel.gauge("pages_reserved", self.reserved_total)

    # -- accounting -----------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        """Pages physically off the free list (refcount >= 1)."""
        return self.num_pages - len(self._free)

    @property
    def available(self) -> int:
        """Pages not yet promised to any admitted request.  Conservative
        under sharing: a forked/attached chain still charges its FULL
        worst case here, so shared pages never prop up admission."""
        return self.num_pages - self.reserved_total

    # -- admission ------------------------------------------------------
    def can_admit(self, n_pages: int) -> bool:
        return n_pages <= self.available

    def reserve(self, slot: int, n_pages: int):
        """Promise ``n_pages`` to ``slot`` (its worst-case chain)."""
        if not self.can_admit(n_pages):
            raise RuntimeError(
                f"reserve({n_pages}) exceeds available pages "
                f"({self.available}) — admit() must check can_admit first")
        if self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        self._reserved[slot] = n_pages
        self.reserved_total += n_pages
        if self.telemetry.enabled:
            self._tel_pages()

    def reserved_for(self, slot: int) -> int:
        """Pages currently promised to ``slot`` (0 when it holds no
        reservation) — what eviction would hand back, and what a
        preemption snapshot must record to re-admit safely."""
        return int(self._reserved[slot])

    # -- allocation core -------------------------------------------------
    def _pop(self) -> int:
        """Take one page off the free list (refcount 0 -> 1), asking the
        reclaim hook to drop external holds first if it is empty.  Every
        caller is reserve-covered, so after a full reclaim a free page
        provably exists — running dry here is an accounting bug."""
        if not self._free and self.reclaim is not None:
            if self.telemetry.enabled:
                self.telemetry.inc("pool_reclaims")
                self.telemetry.instant("pool_reclaim",
                                       in_use=self.pages_in_use)
            self.reclaim(1)
        if not self._free:
            raise RuntimeError(
                "page pool exhausted under a covered reservation — "
                "refcount/reservation accounting bug "
                f"(in_use={self.pages_in_use}, "
                f"reserved_total={self.reserved_total})")
        p = self._free.pop()
        self.refcount[p] = 1
        return p

    # -- allocate-on-append ---------------------------------------------
    def grow(self, slot: int, n_chain: int):
        """Extend ``slot``'s chain to ``n_chain`` pages, drawing on its
        reservation.  Called at admission (prompt pages) and before each
        decode step that crosses a page boundary."""
        if n_chain > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: chain of {n_chain} pages exceeds its "
                f"reservation of {int(self._reserved[slot])} — scheduler "
                "bug (reservations are sized to the worst case)")
        while self.chain_len[slot] < n_chain:
            self.block_tables[slot, self.chain_len[slot]] = self._pop()
            self.chain_len[slot] += 1
        if self.telemetry.enabled:
            self._tel_pages()

    # -- sharing ---------------------------------------------------------
    def share(self, slot: int, pages: Sequence[int]):
        """Seed ``slot``'s (empty) chain with existing live pages —
        fork / prefix-cache attach.  Each page's refcount goes up by
        one; the slot must already hold a reservation covering its full
        worst case (sharing saves memory only OPPORTUNISTICALLY)."""
        if self.chain_len[slot]:
            raise RuntimeError(f"slot {slot} already owns a chain")
        pages = [int(p) for p in pages]
        if len(pages) > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: sharing {len(pages)} pages exceeds its "
                f"reservation of {int(self._reserved[slot])}")
        for p in pages:
            if self.refcount[p] < 1:
                raise RuntimeError(f"page {p} is not live (cannot share)")
            self.refcount[p] += 1
        self.block_tables[slot, :len(pages)] = pages
        self.chain_len[slot] = len(pages)

    def cow(self, slot: int, i: int,
            materialize: bool = True) -> Optional[Tuple[int, int]]:
        """Copy-on-write: if chain entry ``i`` of ``slot`` points at a
        SHARED page (refcount > 1), replace it with a private page and
        return ``(src, dst)`` so the caller can copy device bytes.
        Returns None when the page is already private.

        ``materialize=False`` detaches WITHOUT requesting a device copy
        (the caller is about to fully overwrite the page, e.g. an
        attached ring page refilled by the tail prefill)."""
        if i >= self.chain_len[slot]:
            raise RuntimeError(
                f"slot {slot}: cow({i}) beyond chain length "
                f"{int(self.chain_len[slot])}")
        src = int(self.block_tables[slot, i])
        if self.refcount[src] <= 1:
            return None
        self.refcount[src] -= 1
        dst = self._pop()
        self.block_tables[slot, i] = dst
        self.n_cow += 1
        if self.telemetry.enabled:
            self.telemetry.inc("cow_detaches")
        return (src, dst) if materialize else None

    # -- external holds (prefix cache) -----------------------------------
    def incref(self, pages: Sequence[int]):
        """Pin live pages for an external holder (refcount +1 each)."""
        for p in pages:
            p = int(p)
            if self.refcount[p] < 1:
                raise RuntimeError(f"page {p} is not live (cannot pin)")
            self.refcount[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Drop an external hold; pages hitting refcount zero return to
        the free list.  Returns the freed page ids.

        Validates the WHOLE batch (with multiplicity — the same id may
        legally appear once per distinct hold) BEFORE mutating: a
        refcount underflow raises ValueError and leaves the pool
        untouched, instead of half-applying the batch and pushing a
        still-live page onto the free list where the next ``_pop``
        would hand it to a second writer."""
        pages = [int(p) for p in pages]
        need: dict = {}
        for p in pages:
            need[p] = need.get(p, 0) + 1
        for p, n in need.items():
            if not 0 <= p < self.num_pages:
                raise ValueError(f"page {p} is not a page id "
                                 f"(pool holds {self.num_pages})")
            if self.refcount[p] < n:
                raise ValueError(
                    f"page {p} refcount underflow (double-free): "
                    f"dropping {n} hold(s) but only "
                    f"{int(self.refcount[p])} exist")
        freed = []
        for p in pages:
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    # -- free ------------------------------------------------------------
    def release(self, slot: int):
        """Finish/cancel: decrement the chain's refcounts (pages return
        to the free list only at zero — a fork or prefix hold keeps them
        alive) and drop the remaining reservation.

        Raises ValueError on a double release: a slot holding neither a
        chain nor a reservation has nothing to give back, so a second
        release means two owners think they freed it — the old
        silent-no-op behavior let that bug ride until the free list
        aliased."""
        n = int(self.chain_len[slot])
        if n == 0 and not self._reserved[slot]:
            raise ValueError(
                f"slot {slot} double-release: it holds no chain and no "
                "reservation")
        self.decref(self.block_tables[slot, :n])
        self.reserved_total -= int(self._reserved[slot])
        self._reserved[slot] = 0
        self.chain_len[slot] = 0
        self.block_tables[slot, :] = 0
        if self.telemetry.enabled:
            self._tel_pages()
