"""Chunked prompt prefill.

Two program families per prompt-length class:

  * fast path (default) — **grid-padded masked prefill**: the prompt is
    padded up to a multiple of ``chunk`` and consumed as equal-shape
    chunks by ``DecoderLM.prefill``; the number of VALID tokens in each
    chunk rides along as a traced scalar, so every layer masks the
    padding out of its cache update inside ONE compiled program.  Each
    O(1)-state mixer runs ONE ``linear_scan`` per chunk
    (backend-selectable via ``ModelConfig.scan_backend``), global
    attention scatter-writes its K/V block, sliding-window attention does
    a wrap-aware masked ring scatter, and MLA scatter-writes its latent
    cache.  Any prompt length compiles exactly one chunk shape — the
    remainder-shape compile class is gone.
  * fallback — a ``lax.scan`` of single-token ``decode_step`` calls:
    still one XLA program, no Python-level loop.  Kept as the
    definitional reference (``force_scan=True``) and for any future
    mixer without a chunk path.

``pad_to_grid=False`` restores the legacy remainder behavior (chunk
pieces + one ragged remainder piece, one compile per distinct remainder)
— retained for the padded-vs-remainder benchmark comparison.

MoE request boundary: the batch axis of ``tokens`` IS the request axis
(one admission-wave row per request), and both prefill families thread
that boundary into the MoE layers — ``DecoderLM.prefill`` routes with
``route="prefill"`` (per-request grouped dispatch: one drop-free group
per batch row) and the scanned fallback's ``decode_step`` routes with
``route="decode"`` (capacity-free gather-GEMM).  Both reduce to pure
per-token top-k routing, so chunked and scanned prefill produce
IDENTICAL routing — and grid padding is routing-inert too (padded tokens
compete with nobody).  Routing identity, not bitwise output identity:
the two paths run differently-shaped expert GEMMs, so their outputs
agree only at numerical tolerance (like every other fast-vs-scan pair in
tests/test_serve_prefill.py).  Only ``MoEConfig.dispatch="pooled"``
reverts to the chunking-dependent pooled capacity dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _fast_prefill_fn(model):
    def run(params, tokens, cache, pos0, length):
        logits, cache = model.prefill(params, tokens, cache, pos0,
                                      length=length)
        return logits[:, -1, :], cache
    return run


def _scan_prefill_fn(model):
    def run(params, tokens, cache, pos0):
        P = tokens.shape[1]
        # step token 0 outside the scan: its logits seed the carry with
        # the exact dtype decode_step produces
        logits0, cache = model.decode_step(params, tokens[:, :1],
                                           cache, pos0)

        def body(carry, xs):
            cache, _ = carry
            tok, pos = xs
            logits, cache = model.decode_step(params, tok[:, None],
                                              cache, pos)
            return (cache, logits[:, -1, :]), None

        (cache, last), _ = jax.lax.scan(
            body, (cache, logits0[:, -1, :]),
            (tokens[:, 1:].T,
             pos0 + 1 + jnp.arange(P - 1, dtype=jnp.int32)))
        return last, cache
    return run


def chunked_prefill(step_model, params, tokens, *, chunk=256, pos0=0,
                    pad_to_grid=True, force_scan=False, cache0=None,
                    start=0):
    """Consume a whole prompt. tokens: (B, P) -> (last-valid-token logits
    (B, V_pad), cache carry with batch B) ready for the decode loop.

    ``cache0``/``start``: TAIL prefill for a prefix-cache attach — resume
    from a seeded cache holding positions [0, start') for some
    start' >= start and consume only the chunks from ``start`` (must sit
    on the chunk grid) onward.  Chunk widths and boundaries are
    unchanged, so every computed chunk is the bitwise-identical program
    a from-scratch prefill of the same prompt would run."""
    model = step_model.model
    tokens = jnp.asarray(tokens, jnp.int32)
    if step_model.mesh is not None:
        # wave batch over "data" (divisibility-gated); the chunk scatter
        # then lands in TP-sharded K/V heads / MLA latents without any
        # layer knowing — GSPMD partitions the same masked update.
        tokens = step_model.put_slot(tokens)
    B, P = tokens.shape
    chunk = max(1, int(chunk))
    start = int(start)
    if start % chunk:
        raise ValueError(f"tail prefill start={start} must sit on the "
                         f"chunk grid (chunk={chunk})")
    if not 0 <= start < P:
        raise ValueError(f"start={start} outside prompt of {P} tokens")
    if cache0 is not None:
        cache = cache0
    else:
        if start:
            raise ValueError("start > 0 needs a seeded cache0")
        tmpl = step_model._cache_templates
        if B not in tmpl:   # zeros are immutable, never donated: reusable
            tmpl[B] = step_model.place_cache(
                model.init_cache(B, step_model.max_len))
        cache = tmpl[B]
    if force_scan or not model.supports_prefill():
        if step_model._jit_prefill_scan is None:
            step_model._jit_prefill_scan = jax.jit(_scan_prefill_fn(model))
        fn = step_model._jit_prefill_scan
        last = None
        for s in range(start, P, chunk):
            piece = tokens[:, s:s + chunk]
            last, cache = fn(params, piece, cache, jnp.int32(pos0 + s))
        return last, cache
    if step_model._jit_prefill_fast is None:
        step_model._jit_prefill_fast = jax.jit(_fast_prefill_fn(model))
    fn = step_model._jit_prefill_fast
    if pad_to_grid and P % chunk:
        tokens = jnp.pad(tokens, ((0, 0), (0, chunk - P % chunk)))
    last = None
    for s in range(start, tokens.shape[1], chunk):
        piece = tokens[:, s:s + chunk]
        # valid-token count is a TRACED scalar: every chunk of a given
        # width shares one compiled program regardless of padding
        valid = min(P - s, piece.shape[1])
        last, cache = fn(params, piece, cache, jnp.int32(pos0 + s),
                         jnp.int32(valid))
    return last, cache
