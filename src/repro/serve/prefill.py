"""Chunked prompt prefill.

Replaces the per-token Python prefill loop of the old ``launch.serve`` with
at most two compiled programs per prompt-length class:

  * fast path — the model consumes a whole chunk per call
    (``DecoderLM.prefill``): each O(1)-state mixer runs ONE ``linear_scan``
    over the chunk (backend-selectable via ``ModelConfig.scan_backend``:
    seq / xla / pallas / pallas_tpu) and global attention bulk-writes its
    K/V block.  The final carry feeds the decode loop.
  * fallback — stacks with a mixer that cannot consume chunks against its
    cache (sliding-window rings, MLA) run a ``lax.scan`` of single-token
    ``decode_step`` calls: still one XLA program, no Python-level loop.

Prompts are split into ``chunk``-sized pieces plus one remainder piece, so
any prompt length compiles at most two chunk shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _fast_prefill_fn(model):
    def run(params, tokens, cache, pos0):
        logits, cache = model.prefill(params, tokens, cache, pos0)
        return logits[:, -1, :], cache
    return run


def _scan_prefill_fn(model):
    def run(params, tokens, cache, pos0):
        P = tokens.shape[1]
        # step token 0 outside the scan: its logits seed the carry with
        # the exact dtype decode_step produces
        logits0, cache = model.decode_step(params, tokens[:, :1],
                                           cache, pos0)

        def body(carry, xs):
            cache, _ = carry
            tok, pos = xs
            logits, cache = model.decode_step(params, tok[:, None],
                                              cache, pos)
            return (cache, logits[:, -1, :]), None

        (cache, last), _ = jax.lax.scan(
            body, (cache, logits0[:, -1, :]),
            (tokens[:, 1:].T,
             pos0 + 1 + jnp.arange(P - 1, dtype=jnp.int32)))
        return last, cache
    return run


def chunked_prefill(step_model, params, tokens, *, chunk=256, pos0=0):
    """Consume a whole prompt. tokens: (B, P) -> (last logits (B, V_pad),
    cache carry with batch B) ready for the decode loop."""
    model = step_model.model
    B, P = tokens.shape
    if model.supports_prefill():
        if step_model._jit_prefill_fast is None:
            step_model._jit_prefill_fast = jax.jit(_fast_prefill_fn(model))
        fn = step_model._jit_prefill_fast
    else:
        if step_model._jit_prefill_scan is None:
            step_model._jit_prefill_scan = jax.jit(_scan_prefill_fn(model))
        fn = step_model._jit_prefill_scan
    tmpl = step_model._cache_templates
    if B not in tmpl:   # zeros are immutable and never donated: reusable
        tmpl[B] = model.init_cache(B, step_model.max_len)
    cache = tmpl[B]
    chunk = max(1, int(chunk))
    last = None
    for start in range(0, P, chunk):
        piece = tokens[:, start:start + chunk]
        last, cache = fn(params, piece, cache, jnp.int32(pos0 + start))
    return last, cache
