"""Architecture configuration schema.

One ``ModelConfig`` describes any architecture in the assigned pool: dense /
MoE decoder LMs, MLA, sliding-window patterns, Mamba/hybrid stacks, the
Whisper encoder-decoder backbone, the LLaVA VLM backbone, and the paper's
minGRU time-mixing blocks.  Per-layer heterogeneity (Jamba 1:7, Gemma-3 5:1
local:global, DeepSeek first-k-dense) is expressed as a repeating
``pattern`` of LayerSpec entries plus optional head/tail layers; the model
stack scans over pattern repeats so HLO size stays O(|pattern|).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

# Block kinds
ATTN = "attn"            # global self-attention (GQA)
ATTN_LOCAL = "attn_local"  # sliding-window self-attention
MLA = "mla"              # DeepSeek multi-head latent attention
MAMBA = "mamba"          # Mamba-1 selective SSM
MINGRU = "mingru"        # paper's minGRU time-mixing block


#: Legal values of :attr:`MoEConfig.dispatch`.
MOE_DISPATCH_MODES = ("pooled", "per_request", "auto")

#: Legal values of :attr:`ModelConfig.paged_impl`.
PAGED_IMPLS = ("gather", "pallas", "pallas_tpu")

#: Legal values of :attr:`ModelConfig.kv_dtype`.
KV_DTYPES = ("bf16", "int8")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    # dispatch groups (typically = DP degree): scatter/gather stay local to
    # each group's shard; only the combine's partial-sum crosses the mesh
    # (§Perf cell B). groups=1 reproduces single-pool dispatch.
    groups: int = 1
    # How tokens reach their experts (see models.moe):
    #   "pooled"      — every token of a call shares one capacity-limited
    #                   dispatch (Switch-style drops, EP sharding, aux loss;
    #                   the training semantics).  Routing depends on the
    #                   co-batched tokens, so served outputs vary with
    #                   concurrent traffic and prefill chunking.
    #   "per_request" — tokens are grouped by request (batch row) at the
    #                   drop-free capacity bound: routing is pure per-token
    #                   top-k, independent of neighbors and of chunking.
    #   "auto"        — training keeps "pooled"; serving prefill uses
    #                   "per_request" and the slot-batch decode step uses
    #                   the capacity-free gather-GEMM path.  This is the
    #                   default: training semantics are untouched while
    #                   serving becomes batch-invariant.
    dispatch: str = "auto"

    def __post_init__(self):
        if self.dispatch not in MOE_DISPATCH_MODES:
            raise ValueError(
                f"dispatch must be one of {MOE_DISPATCH_MODES}, "
                f"got {self.dispatch!r}")
        if self.groups < 1:
            raise ValueError(f"groups must be >= 1, got {self.groups}")
        if not 1 <= self.top_k <= self.n_experts:
            raise ValueError(
                f"top_k must be in [1, n_experts={self.n_experts}], "
                f"got {self.top_k}")


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = ATTN          # one of the block kinds above
    moe: bool = False         # MoE MLP instead of dense MLP
    d_ff: Optional[int] = None  # dense-MLP width override (DeepSeek head)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    vocab: int
    # attention geometry
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    # layer structure: n_head_layers of head_pattern, then pattern repeated,
    # then tail. len(head) + repeats*len(pattern) + len(tail) == n_layers.
    pattern: Sequence[LayerSpec] = (LayerSpec(),)
    head_layers: Sequence[LayerSpec] = ()
    tail_layers: Sequence[LayerSpec] = ()
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    # attention details
    sliding_window: int = 4096
    rope_theta: float = 1e4
    # model kind: "decoder" | "encdec" | "vlm" | "audio"
    arch_type: str = "decoder"
    # enc-dec: encoder geometry (defaults mirror decoder)
    n_enc_layers: int = 0
    # vlm/audio stub frontend: inputs are precomputed embeddings of this dim
    frontend_embed_dim: int = 0
    frontend_seq: int = 0       # e.g. 1500 whisper frames / image patches
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # paper technique hooks
    mingru_quant: str = "float"   # float | quantized | hardware
    # multi-token prediction depth (DeepSeek-V3 MTP); 0 = off
    mtp_depth: int = 0
    # kernel implementations (§Perf hillclimb):
    #   attention_impl: naive | flash (Pallas kernel) | stub (dry-run cost
    #     accounting stand-in — cheap op with correct shapes/grads; the
    #     analytic kernel cost is added by launch.dryrun)
    #   ssm_impl: xla | fused (Pallas kernel) | stub
    attention_impl: str = "naive"
    ssm_impl: str = "xla"
    # linear-scan backend for recurrent mixers (minGRU/Mamba prefill):
    #   seq | xla | pallas (interpret) | pallas_tpu (compiled)
    scan_backend: str = "xla"
    # paged-KV decode attention read (serving, kv_layout="paged"):
    #   pallas     — kernels.paged_attention block-table kernel, platform-
    #                adaptive: interpret mode off-TPU, compiled on TPU.
    #                The DEFAULT fast path: no dense-view materialization;
    #                fp32 online softmax, within the pinned per-family
    #                tolerance of gather, not bitwise (README §Paged KV)
    #   pallas_tpu — same kernel, compiled unconditionally (fails off-TPU)
    #   gather     — block-table gather to a dense view + the exact dense
    #                decode math (bitwise-identical to the dense cache;
    #                the oracle the kernels are pinned against)
    paged_impl: str = "pallas"
    # paged KV-pool storage dtype (serving, kv_layout="paged"):
    #   bf16 — pages stored in the model dtype (bitwise-dense gather math)
    #   int8 — symmetric per-page quantized codes + float32 scales per
    #          page per KV head (kernels.paged_attention.quant); halves
    #          pool bytes so ~2x the concurrent requests fit a fixed pool
    kv_dtype: str = "bf16"
    # explicit sharding constraints on MoE dispatch buffers (cell B fix)
    moe_constraints: bool = False

    def __post_init__(self):
        if self.paged_impl not in PAGED_IMPLS:
            raise ValueError(
                f"paged_impl must be one of {PAGED_IMPLS}, "
                f"got {self.paged_impl!r}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, "
                f"got {self.kv_dtype!r}")

    # ---- derived ----
    def layer_specs(self) -> list:
        n_rep = (self.n_layers - len(self.head_layers) - len(self.tail_layers))
        assert n_rep % len(self.pattern) == 0, (
            f"{self.name}: {self.n_layers} layers do not decompose into "
            f"head({len(self.head_layers)}) + k*pattern({len(self.pattern)}) "
            f"+ tail({len(self.tail_layers)})")
        reps = n_rep // len(self.pattern)
        return list(self.head_layers) + list(self.pattern) * reps + list(self.tail_layers)

    @property
    def n_repeats(self) -> int:
        return (self.n_layers - len(self.head_layers) - len(self.tail_layers)) \
            // len(self.pattern)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 512 (shardable over any mesh
        axis ≤ 512; Megatron-style padding, logits masked at the loss)."""
        return (self.vocab + 511) // 512 * 512

    def param_count(self) -> int:
        """Analytical parameter count (for 6·N·D model-FLOPs estimates)."""
        d = self.d_model
        total = self.vocab_padded * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_padded * d
        for spec in self.layer_specs():
            if spec.kind in (ATTN, ATTN_LOCAL):
                total += d * self.n_heads * self.head_dim      # q
                total += 2 * d * self.n_kv_heads * self.head_dim  # k, v
                total += self.n_heads * self.head_dim * d      # o
            elif spec.kind == MLA:
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                total += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += self.n_heads * m.v_head_dim * d
            elif spec.kind == MAMBA:
                mc = self.mamba
                di = mc.d_inner(d)
                total += d * 2 * di                  # in_proj
                total += di * mc.d_conv              # conv
                total += di * (2 * mc.d_state + 1)   # B, C, dt proj (approx)
                total += di * mc.d_state + di        # A_log, D
                total += di * d                      # out_proj
            elif spec.kind == MINGRU:
                total += 2 * (d * d + d)             # W^h, W^z + biases
            # MLP follows ANY mixer kind when configured (Jamba puts MoE
            # after Mamba layers too) — mirrors models.transformer exactly
            if spec.moe:
                e = self.moe
                total += d * e.n_experts              # router
                total += e.n_experts * 3 * d * e.d_ff_expert
                total += e.n_shared * 3 * d * e.d_ff_expert
            else:
                ff = spec.d_ff or self.d_ff
                total += 3 * d * ff                   # SwiGLU
            total += 2 * d                            # norms
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        e = self.moe
        n_moe_layers = sum(1 for s in self.layer_specs() if s.moe)
        total -= n_moe_layers * e.n_experts * 3 * d * e.d_ff_expert
        total += n_moe_layers * (e.top_k + e.n_shared) * 3 * d * e.d_ff_expert
        return int(total)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching serving knobs (consumed by
    repro.launch.serve.build_engine)."""
    slots: int = 8            # fixed slot-batch capacity (jit shape)
    max_len: int = 256        # cache length for attention-bearing stacks
    prefill_chunk: int = 256  # chunked-prefill chunk size (tokens)
    # KV-cache layout for attention-bearing stacks (README §Paged KV):
    #   "dense" — every slot preallocates (max_len, ...) cache rows
    #   "paged" — a shared page pool + per-request block tables; memory
    #             scales with live tokens, not slots × worst case
    kv_layout: str = "dense"
    page_size: int = 16       # tokens per KV page (paged layout)
    num_pages: int = 0        # pool capacity; 0 = auto (dense-equivalent)
    # hash-keyed prompt-prefix reuse (paged layout only): requests whose
    # page-aligned prompt prefix is resident attach to the existing
    # pages and prefill only the tail (README §Prefix caching)
    prefix_cache: bool = False
    # admission/preemption policy (repro.serve.scheduler.POLICIES):
    #   "fifo"     — strict arrival order, defer-at-head (the historical
    #                behavior, byte for byte); never preempts
    #   "priority" — higher submit(priority=...) first; may evict a
    #                strictly-lower-priority running request when a
    #                high-priority arrival is blocked (paged layout)
    #   "sjf"      — shortest-prefill-first with aging (README
    #                §Scheduling & preemption)
    #   "edf"      — earliest submit(deadline=...) first; may evict a
    #                strictly-later-deadline running request (paged)
    policy: str = "fifo"
    # speculative decoding (README §Speculative decoding): ``drafter``
    # names the draft arch (a pure O(1)-state stack, e.g.
    # "minimalist-lm-360m-smoke"); ``spec_k`` is the verify width — the
    # target scores spec_k positions per wave and commits the accepted
    # prefix.  spec_k == 1 (the default) is plain decode.
    spec_k: int = 1
    drafter: str = ""

    def __post_init__(self):
        if self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
        if self.spec_k > 1 and not self.drafter:
            raise ValueError(
                f"spec_k={self.spec_k} needs a drafter — name a pure "
                "O(1)-state arch (ServeConfig.drafter) to propose the "
                "speculative tokens")
        if self.drafter and self.kv_layout != "paged":
            raise ValueError(
                "speculative decoding needs kv_layout='paged': rollback "
                "relies on uncommitted pages (the pool never holds a "
                f"rejected token), got kv_layout={self.kv_layout!r}")
        if self.drafter and self.prefix_cache:
            raise ValueError(
                "speculative decoding and prefix_cache are mutually "
                "exclusive (singleton admission waves would serialize "
                "the drafter's wave prefill; lift when needed)")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding knobs (see repro.serve.sampling).

    The defaults are greedy argmax.  ``seed`` is folded with the request
    uid and the absolute token position into a counter-based PRNG key, so
    a request's tokens are bitwise reproducible regardless of co-batched
    traffic; knobs travel as per-slot ARRAYS through the one jitted
    decode step, never as retrace-triggering constants.
    """
    temperature: float = 0.0  # <= 0 means greedy
    top_k: int = 0            # 0 disables
    top_p: float = 1.0        # >= 1 disables; else minimal nucleus
    seed: int = 0

    def validate(self):
        """Bounds match the per-slot knob dtypes (serve.sampling): values
        outside them would overflow the slot arrays at admission time."""
        if not self.temperature >= 0:          # NaN fails this too
            raise ValueError("temperature must be >= 0 and not NaN")
        if not 0 <= self.top_k <= 2**31 - 1:
            raise ValueError("top_k must be in [0, 2**31)")
        if self.top_p <= 0:
            raise ValueError("top_p must be > 0 (>= 1 disables the filter)")
        if not 0 <= self.seed <= 2**32 - 1:
            raise ValueError("seed must be a uint32 (in [0, 2**32))")
        return self


# The four assigned input-shape regimes
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}
