from repro.configs.base import (ATTN, ATTN_LOCAL, MAMBA, MINGRU, MLA,
                                LayerSpec, MambaConfig, MLAConfig, ModelConfig,
                                MoEConfig, SamplingParams, ServeConfig,
                                SHAPES)
from repro.configs.archs import (ARCHS, ASSIGNED, LONG_CONTEXT_OK,
                                 MINIMALIST_SMNIST_DIMS, get_config,
                                 input_specs, reduced, shape_supported)
