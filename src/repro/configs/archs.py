"""The 10 assigned architectures (exact dims from the assignment) + the
paper's own MINIMALIST configs, with reduced smoke variants and per-shape
``input_specs``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_LOCAL, MAMBA, MINGRU, MLA,
                                LayerSpec, MambaConfig, MLAConfig, ModelConfig,
                                MoEConfig, SHAPES)

# ---------------------------------------------------------------------------
# LM-family transformers (assignment pool)
# ---------------------------------------------------------------------------

QWEN3_MOE_30B = ModelConfig(
    # [hf:Qwen/Qwen3-30B-A3B; hf]
    name="qwen3-moe-30b-a3b",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    pattern=(LayerSpec(ATTN, moe=True),),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    rope_theta=1e6, tie_embeddings=False,
)

DEEPSEEK_V3_671B = ModelConfig(
    # [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed top-8, MTP,
    # first 3 layers dense (d_ff 18432), the rest MoE (expert d_ff 2048)
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab=129280,
    head_layers=(LayerSpec(MLA, d_ff=18432),) * 3,
    pattern=(LayerSpec(MLA, moe=True),),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    rope_theta=1e4, tie_embeddings=False, mtp_depth=1,
)

STABLELM_12B = ModelConfig(
    # [hf:stabilityai/stablelm-2-12b; hf]
    name="stablelm-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352,
    pattern=(LayerSpec(ATTN),), rope_theta=1e4, tie_embeddings=False,
)

MISTRAL_LARGE_123B = ModelConfig(
    # [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
    name="mistral-large-123b",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=32768,
    pattern=(LayerSpec(ATTN),), rope_theta=1e6, tie_embeddings=False,
)

SMOLLM_360M = ModelConfig(
    # [hf:HuggingFaceTB/SmolLM-360M; hf] — llama-arch small
    name="smollm-360m",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab=49152,
    pattern=(LayerSpec(ATTN),), rope_theta=1e4, tie_embeddings=True,
)

GEMMA3_4B = ModelConfig(
    # [hf:google/gemma-3-4b-pt; unverified] — 5:1 local:global, window 1024
    name="gemma3-4b",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144, sliding_window=1024,
    pattern=(LayerSpec(ATTN_LOCAL),) * 5 + (LayerSpec(ATTN),),
    tail_layers=(LayerSpec(ATTN_LOCAL),) * 3 + (LayerSpec(ATTN),),
    rope_theta=1e6, tie_embeddings=True,
)

LLAVA_NEXT_34B = ModelConfig(
    # [hf:llava-hf/llava-v1.6-34b-hf; unverified] — anyres tiling stubbed:
    # input_specs provides precomputed patch embeddings (B, n_patches, D)
    name="llava-next-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000,
    pattern=(LayerSpec(ATTN),), arch_type="vlm",
    frontend_embed_dim=7168, frontend_seq=576,
    rope_theta=5e6, tie_embeddings=False,
)

WHISPER_SMALL = ModelConfig(
    # [arXiv:2212.04356; unverified] — enc-dec, conv frontend stubbed
    name="whisper-small",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    head_dim=64, d_ff=3072, vocab=51865,
    pattern=(LayerSpec(ATTN),), arch_type="audio",
    frontend_embed_dim=768, frontend_seq=1500, tie_embeddings=True,
)

FALCON_MAMBA_7B = ModelConfig(
    # [arXiv:2410.05355; unverified] — mamba1 arch, attention-free
    name="falcon-mamba-7b",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=65024,
    pattern=(LayerSpec(MAMBA),),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
)

# Jamba block: 8 layers, attention at position 4, Mamba elsewhere (1:7);
# MoE every other layer (16 experts top-2). [arXiv:2403.19887; hf]
_JAMBA_UNIT = tuple(
    LayerSpec(ATTN if i == 4 else MAMBA, moe=(i % 2 == 1))
    for i in range(8)
)

JAMBA_15_LARGE_398B = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    pattern=_JAMBA_UNIT,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
)

# ---------------------------------------------------------------------------
# The paper's own architectures
# ---------------------------------------------------------------------------

# sMNIST network of paper Fig. 5: dims 1-64-64-64-64-10 (built directly via
# core.mingru.MinimalistNetwork — see configs/minimalist.py helpers).
MINIMALIST_SMNIST_DIMS = (1, 64, 64, 64, 64, 10)

# The paper's technique at LM scale: smollm geometry with minGRU time mixing
MINIMALIST_LM_360M = dataclasses.replace(
    SMOLLM_360M,
    name="minimalist-lm-360m",
    pattern=(LayerSpec(MINGRU),),
    mingru_quant="float",
)

# ~100M-param variant for the end-to-end training example (examples/train_lm)
MINIMALIST_LM_100M = ModelConfig(
    name="minimalist-lm-100m",
    n_layers=16, d_model=1152, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=49152,
    pattern=(LayerSpec(MINGRU),),
    tie_embeddings=True, mingru_quant="float",
)

MINIMALIST_LM_100M_HW = dataclasses.replace(
    MINIMALIST_LM_100M, name="minimalist-lm-100m-hw",
    mingru_quant="hardware")

MINIMALIST_LM_HW = dataclasses.replace(
    MINIMALIST_LM_360M, name="minimalist-lm-360m-hw", mingru_quant="hardware")


ARCHS = {c.name: c for c in [
    QWEN3_MOE_30B, DEEPSEEK_V3_671B, STABLELM_12B, MISTRAL_LARGE_123B,
    SMOLLM_360M, GEMMA3_4B, LLAVA_NEXT_34B, WHISPER_SMALL, FALCON_MAMBA_7B,
    JAMBA_15_LARGE_398B, MINIMALIST_LM_360M, MINIMALIST_LM_HW,
    MINIMALIST_LM_100M, MINIMALIST_LM_100M_HW,
]}

ASSIGNED = [c.name for c in [
    QWEN3_MOE_30B, DEEPSEEK_V3_671B, STABLELM_12B, MISTRAL_LARGE_123B,
    SMOLLM_360M, GEMMA3_4B, LLAVA_NEXT_34B, WHISPER_SMALL, FALCON_MAMBA_7B,
    JAMBA_15_LARGE_398B,
]]

# long_500k eligibility (DESIGN.md §Arch-applicability): sub-quadratic decode
LONG_CONTEXT_OK = {"gemma3-4b", "falcon-mamba-7b", "jamba-1.5-large-398b",
                   "minimalist-lm-360m", "minimalist-lm-360m-hw"}
# encoder-prefill-only archs with no 32k self-decode regime
DECODE_OK = {n for n in ARCHS} - set()


def shape_supported(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.name in LONG_CONTEXT_OK
    if shape == "decode_32k" and cfg.arch_type == "audio":
        # decoder self-cache regime exists (enc-dec); supported
        return True
    return True


# ---------------------------------------------------------------------------
# Reduced smoke variants (per assignment: same family, tiny dims)
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving structure."""
    n_unit = len(cfg.pattern)
    kw = dict(
        name=cfg.name + "-smoke",
        d_model=64, n_layers=len(cfg.head_layers) + n_unit * 2 +
        len(cfg.tail_layers),
        vocab=512,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=2 if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        frontend_embed_dim=64 if cfg.frontend_embed_dim else 0,
        frontend_seq=12 if cfg.frontend_seq else 0,
        sliding_window=8,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                              n_shared=cfg.moe.n_shared,
                              dispatch=cfg.moe.dispatch)
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.mamba:
        kw["mamba"] = MambaConfig(d_state=4, d_conv=4, expand=2)
    if cfg.head_layers:
        kw["head_layers"] = cfg.head_layers[:1]
        kw["n_layers"] = 1 + n_unit * 2 + len(cfg.tail_layers)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# input_specs: abstract inputs per (arch × shape) for the dry-run
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: str, *, batch_override=None,
                seq_override=None):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   {"tokens", "labels"} (+ "embeds" for vlm/audio stubs)
    prefill: {"tokens"} (or {"embeds"} for audio encoder prefill)
    decode:  {"tokens" (B,1), "pos" scalar} — cache specs come from the
             model (see launch.dryrun), seq_len = KV-cache length.
    """
    sh = SHAPES[shape]
    B = batch_override or sh["global_batch"]
    S = seq_override or sh["seq_len"]
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if sh["kind"] == "train":
        spec = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.arch_type in ("vlm", "audio"):
            spec["embeds"] = sds((B, cfg.frontend_seq, cfg.d_model),
                                 jnp.bfloat16)
        return spec
    if sh["kind"] == "prefill":
        if cfg.arch_type == "audio":
            # encoder prefill over S frames (stub embeddings)
            return {"embeds": sds((B, S, cfg.d_model), jnp.bfloat16)}
        spec = {"tokens": sds((B, S), i32)}
        if cfg.arch_type == "vlm":
            spec["embeds"] = sds((B, cfg.frontend_seq, cfg.d_model),
                                 jnp.bfloat16)
        return spec
    if sh["kind"] == "decode":
        return {"tokens": sds((B, 1), i32),
                "pos": sds((), i32)}
    raise ValueError(shape)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(ARCHS[name[:-len("-smoke")]])
    return ARCHS[name]
