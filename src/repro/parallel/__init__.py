from repro.parallel.sharding import (make_rules, param_specs, cache_specs,
                                     batch_specs, named_sharding_tree,
                                     DP_AXES)
