from repro.parallel.sharding import (make_rules, param_specs, cache_specs,
                                     serve_cache_specs, batch_specs,
                                     slot_specs, dim0_dp_spec,
                                     named_sharding_tree, DP_AXES,
                                     SERVE_CACHE_RULES)
