"""Logical-axis → mesh-axis sharding rules (MaxText-style, flax-free).

Every module declares logical axis names per parameter dimension
(``Module.axes()``).  This layer maps them onto the production mesh
(pod, data, model):

  * TP ("model"):  vocab, attention heads / kv heads, MLP hidden, experts
    (expert parallelism), Mamba d_inner, minGRU hidden.
  * DP ("pod","data"): the batch dimension of activations and inputs;
    with ``zero1`` the optimizer state is additionally sharded over "data"
    on the first shardable dimension (ZeRO-1).
  * SP ("data"): KV-cache length for the long-context decode regime where
    batch==1 (flash-decoding-style sequence sharding).

Assignments are *divisibility-checked per parameter* — a rule only applies
if the actual dim is divisible by the mesh axis size and the mesh axis is
not already used by an earlier dim of the same parameter.  This is what
lets one rule table serve heads=96 (mistral, sharded) and heads=8 (gemma,
replicated) without per-arch special cases.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# DP axes: pod × data (both used for the batch dimension)
DP_AXES = ("pod", "data")

# logical name -> preferred mesh axis (None = replicate)
DEFAULT_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "d_inner": "model",
    "q_lora": None,
    "kv_lora": None,
    "head_dim": None,
    "embed": None,
    "layers": None,
}


def make_rules(overrides=None):
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return rules


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return int(mesh.shape[name]) if name in mesh.shape else 0


def _dp_axes(mesh: Mesh):
    axes = tuple(a for a in DP_AXES if a in mesh.shape)
    return axes if axes else None


def spec_for(axes_tuple, shape, rules, mesh: Mesh) -> P:
    """PartitionSpec for one param given its logical axes and real shape."""
    used = set()
    out = []
    for name, dim in zip(axes_tuple, shape):
        ax = rules.get(name) if name else None
        if isinstance(ax, tuple):  # drop axes absent from this mesh
            ax = tuple(a for a in ax if a in mesh.shape)
            ax = ax if len(ax) > 1 else (ax[0] if ax else None)
        elif ax is not None and ax not in mesh.shape:
            ax = None
        members = (set(ax) if isinstance(ax, tuple)
                   else {ax} if ax else set())
        sz = _axis_size(mesh, ax)
        if ax and not (members & used) and 0 < sz <= dim and dim % sz == 0:
            out.append(ax)
            used |= members
        else:
            out.append(None)
    return P(*out)


def _tree_specs(axes_tree, shapes_tree, rules, mesh):
    is_axes_leaf = lambda x: x is None or isinstance(x, tuple)
    return jax.tree_util.tree_map(
        lambda a, s: spec_for(a or (), s.shape, rules, mesh),
        axes_tree, shapes_tree, is_leaf=is_axes_leaf)


def param_specs(model, params_shapes, mesh: Mesh, rules=None):
    """PartitionSpec pytree for a model's params (shapes from eval_shape)."""
    rules = rules or make_rules()
    return _tree_specs(model.axes(), params_shapes, rules, mesh)


def opt_state_specs(param_spec_tree, params_shapes, mesh: Mesh,
                    zero1: bool = False):
    """Optimizer (m, v) specs: same as params, optionally ZeRO-1-sharded
    over 'data' on the first dimension that is divisible and unused."""
    def z1(spec, shape):
        if not zero1:
            return spec
        data = _axis_size(mesh, "data")
        parts = list(spec)
        parts += [None] * (len(shape.shape) - len(parts))
        if "data" in parts or data <= 1:
            return spec
        for i, (p, dim) in enumerate(zip(parts, shape.shape)):
            if p is None and dim % data == 0:
                parts[i] = "data"
                return P(*parts)
        return spec

    mv = jax.tree_util.tree_map(z1, param_spec_tree, params_shapes,
                                is_leaf=lambda x: isinstance(x, P))
    return {"m": mv, "v": mv, "step": P()}


def dim0_dp_spec(shape, mesh: Mesh) -> P:
    """PartitionSpec sharding dim 0 over (pod, data) when divisible —
    scalars and non-divisible leading dims replicate."""
    dp = _dp_axes(mesh)
    dp_size = _axis_size(mesh, dp)
    if shape and shape[0] % max(dp_size, 1) == 0 and dp_size > 1:
        return P(dp, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_specs(batch_shapes, mesh: Mesh):
    """Input batch: shard dim0 (batch) over (pod, data) when divisible."""
    return jax.tree_util.tree_map(
        lambda s: dim0_dp_spec(s.shape, mesh), batch_shapes)


def slot_specs(shapes_tree, mesh: Mesh):
    """Decode-side per-slot arrays (next-token ids, positions, active
    masks, sampling knobs, admission-wave prompts): dim 0 IS the slot /
    request axis, so it shards over the DP axes exactly like a training
    batch; trailing dims (prompt length, frame features) replicate and
    scalars (e.g. the decode position of a single-sequence cell) get
    ``P()``.  Shared by the serving engine (repro.serve.protocol) and the
    dry-run decode cells (repro.launch.dryrun) so the two stacks place
    decode inputs identically."""
    return jax.tree_util.tree_map(
        lambda s: dim0_dp_spec(s.shape, mesh), shapes_tree)


# Cache rules: batch→DP when divisible; the cache length falls back to
# 'data' (sequence parallelism — the long-context batch-1 decode regime);
# kv-heads / latent / d_inner → 'model'.  Ordering in spec_for's used-set
# guarantees batch-DP and length-SP are mutually exclusive.
CACHE_RULES = {
    "batch": DP_AXES,
    "kv_len": "data",
    "kv_heads": "model",
    "kv_lora": None,
    "head_dim": None,
    "d_inner": "model",
    "state": None,
    "conv": None,
    "mlp": "model",
    "layers": None,
    "heads": "model",
    "frames": None,
    "embed": None,
    # paged KV pools (serve.paged): the page-id axis and the in-page
    # position are NEVER sharded — a page is the allocator's indivisible
    # unit and any decode step may read any page, so sharding either
    # would split softmax reductions across devices (the same reason
    # kv_len is pinned unsharded when serving).  Pools still TP-shard
    # their kv_heads / latent dims via the rules above; block tables are
    # per-slot arrays and DP-shard over "data" like every slot array.
    # Prefix-cache pins and copy-on-write forks ride these same axes for
    # free: a shared or pinned page is just a page id held by more than
    # one block-table row / cache entry, and a COW page copy is a
    # row-to-row copy WITHIN each device's own pool shard (page rows are
    # whole on every device; only head/latent dims are split), so page
    # sharing never adds a collective to the decode step.
    # Int8 pools (kv_dtype="int8") add per-page float32 scale leaves
    # with axes ("pages", "kv_heads") / ("pages",): the same table
    # places them — page axis replicated next to its codes, kv_heads
    # TP-sharded exactly like the pool dim they scale — so COW copies
    # and page installs move a page's codes and its scale row together
    # without any extra rule.
    "pages": None,
    "page": None,
}


def cache_specs(cache_axes_tree, cache_shapes, mesh: Mesh, rules=None):
    """PartitionSpec pytree for decode caches from their logical axes."""
    rules = rules or CACHE_RULES
    return _tree_specs(cache_axes_tree, cache_shapes, rules, mesh)


# Serving variant of the cache rules: the slot batch IS the DP axis, and
# kv_len must stay unsharded — a decode step reads the whole cache, so a
# length-sharded cache splits every attention softmax reduction across
# devices, and the engine's contract (a request's tokens are invariant to
# its placement) would silently become partition-dependent.  The dry-run's
# long-context batch-1 SP regime keeps CACHE_RULES.
SERVE_CACHE_RULES = dict(CACHE_RULES, kv_len=None)


def serve_cache_specs(cache_axes_tree, cache_shapes, mesh: Mesh,
                      rules=None):
    """Cache specs for the serving engine's slot-batch state: slot batch
    over DP, TP-shardable cache dims (kv_heads / d_inner / latent heads)
    over 'model', cache length replicated (see SERVE_CACHE_RULES).  The
    paged layout rides the same table: page pools place as
    (pages=never-sharded, page=never-sharded, kv_heads='model', ...) so
    a pool is pages x TP-sharded heads, and the engine's block tables go
    through the slot placement (DP over 'data')."""
    return cache_specs(cache_axes_tree, cache_shapes, mesh,
                       rules or SERVE_CACHE_RULES)


def named_sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def attach(shapes_tree, sharding_tree):
    """ShapeDtypeStruct pytree with shardings attached (for jit.lower)."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, sharding_tree)
