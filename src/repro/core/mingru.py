"""minGRU cell and the MINIMALIST block/network (paper §2).

The model family (Feng et al. 2024, adapted per the paper):

    h̃_t = W^h · x_t + b^h                      (Eq. 2 — NO activation on h̃,
                                                 required for hw compatibility)
    z_t  = σ_z(W^z · x_t + b^z)                 (Eq. 3)
    h_t  = z_t ⊙ h̃_t + (1 − z_t) ⊙ h_{t−1}     (Eq. 1)
    out  = σ_h(h_t)                             (Eq. 4 — Heaviside when binary)

Gates and candidates depend only on the input → the recurrence is a diagonal
linear scan (repro.kernels.linear_scan) and training parallelizes over time.

``MinGRUBlock`` honors a QuantConfig so the same module expresses all three
models of paper Fig. 5 (float baseline / quantized / hardware-compatible).
``MinimalistNetwork`` is the feed-forward stack of Fig. 1 (no skips, no
channel mixing).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QuantConfig
from repro.kernels.linear_scan import ops as scan_ops
from repro.models.module import Module, fan_in_init


class MinGRUBlock(Module):
    """One GRU block: fused (W^h | W^z) input projection + gated scan."""

    def __init__(self, in_dim: int, dim: int, *, qcfg: QuantConfig = QuantConfig(),
                 scan_backend: str = "xla", dtype=jnp.float32, name="mingru"):
        self.in_dim, self.dim = int(in_dim), int(dim)
        self.qcfg = qcfg
        self.scan_backend = scan_backend
        self.dtype = dtype
        self.name = name

    def init(self, key):
        kh, kz = jax.random.split(key)
        return {
            "wh": fan_in_init(kh, (self.in_dim, self.dim), self.dtype),
            "bh": jnp.zeros((self.dim,), self.dtype),
            "wz": fan_in_init(kz, (self.in_dim, self.dim), self.dtype),
            # bias the gate towards "keep state" at init (z ≈ 0.27 under σ)
            "bz": jnp.full((self.dim,), -1.0, self.dtype),
        }

    def axes(self):
        return {"wh": ("embed", "mlp"), "bh": ("mlp",),
                "wz": ("embed", "mlp"), "bz": ("mlp",)}

    def projections(self, params, x):
        """Return (h̃, z) for input x: (B, T, in_dim)."""
        cfg = self.qcfg
        if cfg.quantize_weights:
            # the four weight-voltage rails are shared per row between the
            # interleaved h and z synapses (paper Fig. 2A) → ONE quantization
            # scale per layer, matching analog.export_layer exactly.
            scale = jax.lax.stop_gradient(jnp.maximum(
                quant.weight_scale(params["wh"]),
                quant.weight_scale(params["wz"])))
            wh = quant.quantize_weights_2b(params["wh"], scale)[0].astype(x.dtype)
            wz = quant.quantize_weights_2b(params["wz"], scale)[0].astype(x.dtype)
        else:
            wh = params["wh"].astype(x.dtype)
            wz = params["wz"].astype(x.dtype)
        bh = quant.maybe_quant_bias(params["bh"], cfg).astype(x.dtype)
        bz = quant.maybe_quant_gate_bias(params["bz"], cfg).astype(x.dtype)
        htilde = x @ wh + bh
        z = quant.gate_fn(cfg)(x @ wz + bz)
        return htilde, z

    def __call__(self, params, x, h0=None, *, backend=None):
        """x: (B, T, in_dim) -> (out (B,T,dim), h (B,T,dim)).

        ``backend`` overrides the construction-time scan backend — the
        serving prefill selects seq/xla/pallas/pallas_tpu per request.
        """
        B = x.shape[0]
        if h0 is None:
            h0 = jnp.zeros((B, self.dim), x.dtype)
        htilde, z = self.projections(params, x)
        h = scan_ops.mingru_scan(z, htilde, h0,
                                 backend=backend or self.scan_backend)
        return quant.output_fn(self.qcfg)(h), h

    def step(self, params, x_t, h_prev):
        """Single inference step. x_t: (B, in_dim); h_prev: (B, dim)."""
        htilde, z = self.projections(params, x_t[:, None, :])
        htilde, z = htilde[:, 0], z[:, 0]
        h = z * htilde + (1.0 - z) * h_prev
        return quant.output_fn(self.qcfg)(h), h


class MinimalistNetwork(Module):
    """Feed-forward stack of MinGRU blocks (paper Fig. 1).

    ``dims`` includes input and output sizes, e.g. the paper's sMNIST net is
    dims = (1, 64, 64, 64, 64, 10).  Classification reads the final layer's
    hidden state at the last time step (the analog h is read out once; no
    Heaviside on the readout layer).
    """

    def __init__(self, dims: Sequence[int], *, qcfg: QuantConfig = QuantConfig(),
                 scan_backend: str = "xla", dtype=jnp.float32, name="minimalist"):
        self.dims = tuple(int(d) for d in dims)
        self.qcfg = qcfg
        self.blocks = []
        for i, (din, dout) in enumerate(zip(self.dims[:-1], self.dims[1:])):
            last = i == len(self.dims) - 2
            cfg = qcfg if not last else QuantConfig(
                # readout layer: h is read in the analog domain (no Θ);
                # weights/biases still quantized when the stage says so.
                quantize_weights=qcfg.quantize_weights,
                quantize_biases=qcfg.quantize_biases,
                binary_output=False,
                hard_sigmoid_gate=qcfg.hard_sigmoid_gate,
                quantize_gate_6b=qcfg.quantize_gate_6b,
                surrogate_width=qcfg.surrogate_width)
            self.blocks.append(MinGRUBlock(din, dout, qcfg=cfg,
                                           scan_backend=scan_backend,
                                           dtype=dtype, name=f"block{i}"))
        self.name = name

    def init(self, key):
        keys = jax.random.split(key, len(self.blocks))
        return {b.name: b.init(k) for b, k in zip(self.blocks, keys)}

    def axes(self):
        return {b.name: b.axes() for b in self.blocks}

    def __call__(self, params, x, collect_traces: bool = False):
        """x: (B, T, dims[0]) -> logits (B, dims[-1]).

        With ``collect_traces`` also returns {layer: {"z","htilde","h","out"}}
        used by the mixed-signal comparison (paper Fig. 4).
        """
        traces = {}
        out = x
        h = None
        for b in self.blocks:
            p = params[b.name]
            if collect_traces:
                htilde, z = b.projections(p, out)
                traces[b.name] = {"htilde": htilde, "z": z}
            out, h = b(p, out)
            if collect_traces:
                traces[b.name]["h"] = h
                traces[b.name]["out"] = out
        logits = h[:, -1, :]  # final layer's hidden state at last step
        if collect_traces:
            return logits, traces
        return logits

    def initial_state(self, batch, dtype=jnp.float32):
        return [jnp.zeros((batch, b.dim), dtype) for b in self.blocks]

    def step(self, params, x_t, states):
        """Recurrent single-step inference through the whole stack."""
        new_states = []
        out = x_t
        for b, s in zip(self.blocks, states):
            out, h = b.step(params[b.name], out, s)
            new_states.append(h)
        return out, new_states

    def prefill(self, params, x, states=None, *, backend=None):
        """Consume a chunk of frames with an O(1) carry.

        x: (B, T, dims[0]); ``states`` as from :meth:`initial_state` (or a
        previous prefill/step).  Returns (y (B, T, dims[-1]), new_states)
        where y is the readout block's output sequence — y[:, -1] equals
        what :meth:`__call__` returns for the concatenated stream, and
        new_states is the carry to hand to the decode loop.  One
        ``linear_scan`` call per block, backend-selectable.
        """
        B = x.shape[0]
        if states is None:
            states = self.initial_state(B, x.dtype)
        out = x
        new_states = []
        for b, s in zip(self.blocks, states):
            out, h = b(params[b.name], out, h0=s.astype(out.dtype),
                       backend=backend)
            new_states.append(h[:, -1])
        return out, new_states
