# The paper's primary contribution:
#   mingru.py — minGRU cell + MINIMALIST feed-forward stack (paper §2)
#   quant.py  — hardware quantizers (2 b W, 6 b b, Θ, hard-σ 6 b) + QAT phases
#   analog.py — behavioral switched-capacitor circuit simulator (paper §3)
from repro.core.quant import QuantConfig, QAT_PHASES
from repro.core.mingru import MinGRUBlock, MinimalistNetwork
from repro.core.analog import AnalogConfig, export_layer, analog_forward, energy_per_step
