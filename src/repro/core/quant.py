"""Quantizers + QAT schedule for the MINIMALIST architecture (paper §2).

The paper constrains the model to:
  * 2 b weights   — four equidistant levels, two positive / two negative
                    (circuit: voltages V_00..V_11 around the zero level V_0,
                    i.e. values {-3/2, -1/2, +1/2, +3/2} · Δ for step Δ)
  * 6 b biases    — uniform symmetric fixed-point
  * binary output activations σ_h = Θ(·) (Heaviside)
  * hard-sigmoid gate σ_z(x) = clip(x/6 + 1/2, 0, 1), quantized to 6 b
    (the SAR-ADC resolution; the state-update capacitor bank has 64
    segments, so the convex mix itself is 6 b-quantized)

All quantizers are straight-through (STE): forward = quantized value,
backward = identity on the clipped range, so the whole network remains
trainable with standard autodiff. The 4-phase QAT schedule of §4.1 is
expressed as a list of QuantConfig stages.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Relative 2 b weight levels (units of the level spacing Δ): the circuit's
# four equidistant voltages straddling V_0 symmetrically.
W2B_LEVELS = jnp.array([-1.5, -0.5, 0.5, 1.5], dtype=jnp.float32)


def _ste(x_quant, x):
    """Straight-through: forward x_quant, gradient of identity wrt x.

    Written as x − sg(x) + sg(x_quant): the x − sg(x) term is an exact IEEE
    zero (same-value subtraction), so the forward value is *bit-exactly*
    x_quant — `x + sg(x_quant − x)` is not, and XLA's FMA contraction can
    additionally perturb product forms.  Exactness matters: the analog
    circuit equivalence tests compare against these forward values."""
    return x - jax.lax.stop_gradient(x) + jax.lax.stop_gradient(x_quant)


# ---------------------------------------------------------------------------
# Weight / bias quantizers
# ---------------------------------------------------------------------------

def weight_scale(w, *, axis=None):
    """Per-tensor (or per-axis) Δ so that ±1.5Δ covers ~|w|_max."""
    m = jnp.max(jnp.abs(w), axis=axis, keepdims=axis is not None)
    return jnp.maximum(m, 1e-8) / 1.5


def quantize_weights_2b(w, scale=None):
    """Project w onto {±0.5, ±1.5}·Δ with STE. Returns (w_q, codes ∈ [0,4))."""
    if scale is None:
        scale = jax.lax.stop_gradient(weight_scale(w))
    wn = w / scale
    # nearest of the four levels; decision boundaries at -1, 0, +1
    codes = (wn > -1.0).astype(jnp.int32) + (wn > 0.0) + (wn > 1.0)
    wq = W2B_LEVELS[codes] * scale
    return _ste(wq, w), codes


def weight_codes_2b(w, scale=None):
    """Non-differentiable export path: 2 b codes + Δ for the hardware map."""
    if scale is None:
        scale = weight_scale(w)
    _, codes = quantize_weights_2b(w, scale)
    return codes, scale


def quantize_bias_6b(b, scale=None):
    """Uniform symmetric 6 b fixed point: levels {-31..31}·δ (63 live codes).

    SIGNED-CODE GRID NOTE — the repo carries two deliberately DIFFERENT
    signed 6 b grids, matching two different circuits (paper §3.1.2,
    Fig. 3C), and they are pinned by exact-value tests (test_quant):

      * THIS one (weight/bias DACs): SYMMETRIC, codes in [-31, +31] —
        63 live codes out of 64; code -32 is never emitted.  The DAC's
        levels straddle zero symmetrically (the same 63-unit segmented
        bank as GATE_UNITS), and a scale of absmax/31 means
        quantize(-x) == -quantize(x) exactly.
      * :func:`quantize_gate_bias_adc` (the ADC's capacitive-DAC
        preset): FULL TWO'S-COMPLEMENT, codes in [-32, +31] on the
        FIXED grid δ = 6/63 — the preset register is a plain signed
        6 b word, so the asymmetric -32 code physically exists and is
        kept (it buys one extra step of negative bias range; nothing
        is dequantized back through a symmetric DAC there).

    Derived quantizers must pick one convention explicitly; the serving
    int8 KV quantizer (kernels.paged_attention.quant) follows the
    symmetric convention, with QMAX=127 of the int8 range mirroring the
    31-of-6b here."""
    if scale is None:
        scale = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(jnp.abs(b)), 1e-8) / 31.0)
    q = jnp.clip(jnp.round(b / scale), -31, 31) * scale
    return _ste(q, b)


# ---------------------------------------------------------------------------
# Activation functions (paper Eq. 4, 5)
# ---------------------------------------------------------------------------

def hard_sigmoid(x):
    """σ_z(x) = 0 for x ≤ −3, 1 for x ≥ +3, x/6 + 1/2 in between."""
    return jnp.clip(x / 6.0 + 0.5, 0.0, 1.0)


# The state-update capacitor bank is segmented with binary scaling
# (paper §3.1.2: "Segmenting the IMC matrix into groups with a binary
# scaling"): 6 groups of {1,2,4,8,16,32} unit capacitors = 63 units total,
# driven directly by the 6 b ADC code k ∈ [0, 63].  Realizable mixing
# ratios are therefore k/63 — including both endpoints (z=0: untouched,
# z=1: all 63 units swapped), exactly the software grid below.
GATE_UNITS = 63


def quantize_unit_6b(z):
    """Quantize z ∈ [0,1] to the 6 b capacitor-swap grid {k/63, k=0..63}.

    Mid-rise TRUNCATION (floor), not rounding: the quantizer *is* the SAR
    ADC, whose transfer is code = floor((v − v_bottom)/LSB).  With the ADC
    preset at (32 + offset − ½)·LSB the decision thresholds sit at
    half-LSB positions, away from the exact s = 0 value that binary
    activations produce constantly — so software and circuit break ties
    identically and the mapping is bit-exact (tests/test_analog.py)."""
    zq = jnp.floor(z * GATE_UNITS) / GATE_UNITS
    return _ste(zq, z)


# The z-bias is realized by pre-setting the ADC's capacitive DAC (paper
# §3.1.2), so its grid is fixed by the ADC: one input-referred LSB is
# 6/63 model units (dynamic range 6 spread over 63 steps), signed 6 b code.
ADC_GATE_BIAS_LSB = 6.0 / GATE_UNITS


def quantize_gate_bias_adc(b):
    """Quantize the gate bias b^z onto the ADC-offset grid (codes -32..31
    ≈ ±3, i.e. ±half the hard sigmoid's dynamic range, paper Fig. 3C).

    Unlike :func:`quantize_bias_6b` this is the full TWO'S-COMPLEMENT
    range including -32: the ADC preset is a signed 6 b register, not a
    symmetric DAC (see the grid note on quantize_bias_6b)."""
    q = jnp.clip(jnp.round(b / ADC_GATE_BIAS_LSB), -32, 31) * ADC_GATE_BIAS_LSB
    return _ste(q, b)


def hard_sigmoid_q6(x):
    """Hardware gate: hard sigmoid followed by the 6 b ADC quantization."""
    return quantize_unit_6b(hard_sigmoid(x))


import functools


@functools.partial(jax.custom_jvp, nondiff_argnums=(1,))
def _heaviside(x, width):
    return (x > 0.0).astype(x.dtype)


@_heaviside.defjvp
def _heaviside_jvp(width, primals, tangents):
    (x,), (dx,) = primals, tangents
    y = _heaviside(x, width)
    mask = (jnp.abs(x) < width).astype(x.dtype) / (2.0 * width)
    return y, mask * dx


def heaviside_ste(x, *, surrogate_width=3.0):
    """Binary output activation Θ(x) with a boxcar STE surrogate.

    The surrogate gradient is 1/(2w) on |x| < w — w defaults to 3 so that it
    matches the support of the hard sigmoid the gate uses, which keeps the
    two nonlinearities' trainable ranges aligned.  Implemented as a
    custom_jvp so the forward value is exactly {0, 1} (no FMA artifacts).
    """
    return _heaviside(x, surrogate_width)


# ---------------------------------------------------------------------------
# QAT configuration & the 4-phase schedule (paper §4.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Which hardware constraints are active."""
    quantize_weights: bool = False    # 2 b weights
    quantize_biases: bool = False     # 6 b biases
    binary_output: bool = False       # σ_h = Θ (else identity / tanh-free)
    hard_sigmoid_gate: bool = False   # σ_z = hard sigmoid (else logistic σ)
    quantize_gate_6b: bool = False    # 6 b z (ADC resolution)
    surrogate_width: float = 3.0

    # --- the three models of paper Fig. 5 ---
    @staticmethod
    def float_baseline():
        return QuantConfig()

    @staticmethod
    def quantized():
        """2 b W / 6 b b / binary σ_h, original gate activation."""
        return QuantConfig(quantize_weights=True, quantize_biases=True,
                           binary_output=True)

    @staticmethod
    def hardware():
        """Fully hardware-compatible (adds hard-σ gate + 6 b z)."""
        return QuantConfig(quantize_weights=True, quantize_biases=True,
                           binary_output=True, hard_sigmoid_gate=True,
                           quantize_gate_6b=True)


# The paper's "multistage process of 4 gradual phases of quantization-aware
# training": constraints are introduced one at a time so the network can
# re-adapt between phases.
QAT_PHASES = (
    QuantConfig.float_baseline(),                                   # phase 0
    QuantConfig(quantize_weights=True, quantize_biases=True),       # phase 1
    QuantConfig.quantized(),                                        # phase 2
    QuantConfig.hardware(),                                         # phase 3
)


def gate_fn(cfg: QuantConfig):
    if cfg.hard_sigmoid_gate:
        return hard_sigmoid_q6 if cfg.quantize_gate_6b else hard_sigmoid
    return jax.nn.sigmoid


def output_fn(cfg: QuantConfig):
    if cfg.binary_output:
        return lambda x: heaviside_ste(x, surrogate_width=cfg.surrogate_width)
    return lambda x: x


def maybe_quant_weights(w, cfg: QuantConfig):
    if cfg.quantize_weights:
        wq, _ = quantize_weights_2b(w)
        return wq
    return w


def maybe_quant_bias(b, cfg: QuantConfig):
    return quantize_bias_6b(b) if cfg.quantize_biases else b


def maybe_quant_gate_bias(b, cfg: QuantConfig):
    """Gate bias: fixed ADC-offset grid in full hardware mode, else 6 b."""
    if cfg.quantize_gate_6b:
        return quantize_gate_bias_adc(b)
    return maybe_quant_bias(b, cfg)
