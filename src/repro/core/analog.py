"""Behavioral switched-capacitor simulator for the MINIMALIST cores (§3).

This module plays the role of the paper's Cadence AMS mixed-signal
simulation: it executes the *circuit* — charge sharing on capacitor banks,
a 6 b SAR ADC with tunable slope/offset, capacitor-swap state updates, and a
comparator output stage — in the voltage domain, including component
non-idealities (capacitor mismatch, comparator noise).  Tests and
``benchmarks/mixed_signal_match.py`` reproduce paper Fig. 4 by comparing the
voltage traces (converted back to model units) against the software model.

Circuit ↔ model correspondence
------------------------------
A column with K synapse rows plus one always-on bias row settles, after
charge sharing (paper Eq. 6, extended with the bias row), at

    v − V0 = α · (W·x + b) ,      α = ΔV / (Δ_sw · (K + 1))   [volts/unit]

where ΔV is the weight-voltage spacing, Δ_sw the software weight step
(W = (codes − 1.5)·Δ_sw) and V0 the zero level.  Every downstream element is
affine or threshold-based, so the circuit is an exact scaled image of the
quantized software model:

  * gate: the SAR ADC realizes  z = q6(hard_sigmoid(s))  — the slope is set
    by the C_ADC/C_IMC segment ratio (input-referred LSB = 6α/63 volts) and
    the bias b^z by the capacitive-DAC preset (integer codes on that LSB
    grid, hence quant.quantize_gate_bias_adc);
  * state update: swapping k = ADC-code units of the 63-unit binary-scaled
    segment bank realizes  h ← (k/63)·h̃ + (1 − k/63)·h — exactly
    quant.quantize_unit_6b's grid;
  * output: the comparator realizes Θ(h) (threshold V0).

Bias placement: the paper puts the z-bias in the ADC DAC preset (§3.1.2) and
an h-threshold bias in the comparator reference (§3.1.4).  To realize Eq. 2's
b^h *inside* the accumulated state (as the software model defines it), this
implementation adds an always-on bias row driven by a per-column 6 b DAC
voltage — standard IMC practice; recorded as an implementation choice in
DESIGN.md.  The ADC-preset mechanism is implemented as published (Fig. 3C).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalogConfig:
    v_dd: float = 0.8            # supply [V]
    v0_frac: float = 0.5         # zero level V0 = v0_frac * v_dd
    delta_v: float = 0.1         # weight-level spacing ΔV [V]
    c_unit_f: float = 1.0e-15    # unit sampling capacitor [F]
    mismatch_sigma: float = 0.0  # relative capacitor mismatch σ(C)/C
    comparator_noise_v: float = 0.0  # comparator input-referred noise σ [V]
    adc_bits: int = 6
    gate_units: int = quant.GATE_UNITS  # 63 binary-scaled segment units

    @property
    def v0(self):
        return self.v0_frac * self.v_dd

    def weight_voltages(self):
        """The four equidistant potentials V_00..V_11 around V0."""
        lv = np.array([-1.5, -0.5, 0.5, 1.5]) * self.delta_v
        return self.v0 + lv


# ---------------------------------------------------------------------------
# Weight export: trained (quantized) software params -> hardware images
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerImage:
    """Hardware image of one MinGRU block."""
    codes_h: np.ndarray    # (K, N) int 2 b codes for W^h
    codes_z: np.ndarray    # (K, N)
    bias_h_v: np.ndarray   # (N,) bias-row voltage offsets [V] (h̃ columns)
    adc_offset_code: np.ndarray  # (N,) signed DAC preset codes (z bias)
    alpha: float           # volts per software model-unit
    scale: float           # shared software weight step Δ_sw
    k_rows: int


def export_layer(params, cfg: AnalogConfig) -> LayerImage:
    """Map a trained MinGRUBlock's params onto circuit quantities."""
    wh, wz = np.asarray(params["wh"]), np.asarray(params["wz"])
    bh, bz = np.asarray(params["bh"]), np.asarray(params["bz"])
    K = wh.shape[0]

    # one shared Δ_sw per layer (both matrices share the 4 row rails)
    scale = float(max(np.asarray(quant.weight_scale(jnp.asarray(wh))),
                      np.asarray(quant.weight_scale(jnp.asarray(wz)))))
    codes_h = np.asarray(quant.quantize_weights_2b(jnp.asarray(wh), scale)[1])
    codes_z = np.asarray(quant.quantize_weights_2b(jnp.asarray(wz), scale)[1])

    alpha = cfg.delta_v / (scale * (K + 1))

    # h̃ bias: 6 b quantized, realized on the bias row. Voltage so that the
    # (K+1)-way share contributes α·b:  v_bias = (K+1)·α·b_q
    bh_q = np.asarray(quant.quantize_bias_6b(jnp.asarray(bh)))
    bias_h_v = (K + 1) * alpha * bh_q

    # z bias: DAC preset — integer codes on the 6/63 model-unit LSB grid
    bz_q = np.asarray(quant.quantize_gate_bias_adc(jnp.asarray(bz)))
    adc_offset_code = np.round(bz_q / quant.ADC_GATE_BIAS_LSB).astype(np.int32)

    return LayerImage(codes_h=codes_h, codes_z=codes_z, bias_h_v=bias_h_v,
                      adc_offset_code=adc_offset_code, alpha=alpha,
                      scale=scale, k_rows=K)


# ---------------------------------------------------------------------------
# Circuit primitives
# ---------------------------------------------------------------------------


def charge_sharing_mvm(x_bin, codes, bias_v, cfg: AnalogConfig, caps=None):
    """Column charge sharing (Eq. 6 + bias row).

    x_bin: (B, K) in {0,1}; codes: (K, N); bias_v: (N,) volts around V0.
    caps: optional (K+1, N) per-capacitor values (mismatch); defaults 1.
    Returns settled column voltages (B, N).
    """
    codes = jnp.asarray(codes)
    vw = jnp.asarray(cfg.weight_voltages())          # (4,)
    v_syn = vw[codes]                                # (K, N) sampled volts
    B, K = x_bin.shape
    N = codes.shape[1]
    if caps is None:
        caps = jnp.ones((K + 1, N))
    c_syn, c_bias = caps[:K], caps[K]
    # x_i = 0 clamps that row's rails to V0 (paper §3.1.1)
    v_eff = x_bin[:, :, None] * v_syn[None] + (1 - x_bin[:, :, None]) * cfg.v0
    num = jnp.einsum("bkn,kn->bn", v_eff, c_syn) + c_bias * (cfg.v0 + bias_v)
    den = c_syn.sum(0) + c_bias
    return num / den


def sar_adc(v_in, cfg: AnalogConfig, *, lsb_volts, offset_code=0, key=None):
    """6 b SAR ADC (Fig. 3) as an explicit successive-approximation loop.

    ``lsb_volts`` is the input-referred LSB, set in hardware by the
    C_ADC/C_IMC segment ratio (the slope mechanism: connecting more IMC
    capacitance attenuates the DAC's authority over the shared node, which
    *shrinks* the input range ⇒ steeper transfer).  ``offset_code`` is the
    signed DAC preset (§3.1.2), shifting the transfer by ±half range.

    The transfer is code = clip(floor((v−V0)/lsb) + 32 + offset, 0, 63):
    mid-rise around V0, matching q6(hard_sigmoid) when lsb = 6α/63.
    Returns integer codes in [0, 2^bits − 1].
    """
    bits = cfg.adc_bits
    full = 2 ** bits
    # comparator decisions; optional input-referred noise per SAR step
    if key is not None and cfg.comparator_noise_v > 0:
        noise = cfg.comparator_noise_v * jax.random.normal(
            key, v_in.shape + (bits,))
    else:
        noise = jnp.zeros(v_in.shape + (bits,))

    # −0.5 LSB preset: thresholds at half-LSB positions (mid-rise), so the
    # exact s = 0 pre-activation binary activations constantly produce never
    # sits on a decision boundary.  Matches quant.quantize_unit_6b:
    # code = floor(63·(s+b)/6 + 31.5) on both sides.
    v_eff = v_in - cfg.v0 + (full // 2 + offset_code - 0.5) * lsb_volts
    code = jnp.zeros(jnp.shape(v_eff), jnp.int32)
    for b in range(bits - 1, -1, -1):
        trial = code + (1 << b)
        v_dac = trial * lsb_volts
        keep = (v_eff + noise[..., bits - 1 - b]) >= v_dac
        code = jnp.where(keep, trial, code)
    return code


def adc_transfer_closed_form(v_in, cfg: AnalogConfig, *, lsb_volts,
                             offset_code=0):
    """Noise-free closed form of sar_adc (cross-check for the SAR loop)."""
    full = 2 ** cfg.adc_bits
    code = jnp.floor((v_in - cfg.v0) / lsb_volts - 0.5) + full // 2 + offset_code
    return jnp.clip(code, 0, full - 1).astype(jnp.int32)


def state_update_swap(v_h, v_htilde, z_code, cfg: AnalogConfig, seg_caps=None):
    """Capacitor-swap state update (§3.1.3).

    v_h, v_htilde: (B, N) bank voltages; z_code: (B, N) ADC codes in [0,63]
    = number of unit segments (of 63, binary-scaled groups) to swap.
    seg_caps: optional (63, N) unit-segment capacitances for mismatch.
    Ideal: v ← (k/63)·h̃ + (1−k/63)·h.  With mismatch the ratio becomes
    Σ_{i<k} C_i / ΣC_i (thermometer expansion of the binary groups).
    """
    S = cfg.gate_units
    if seg_caps is None:
        frac = z_code.astype(jnp.float32) / S
    else:
        csum = jnp.concatenate(
            [jnp.zeros((1, seg_caps.shape[1])), jnp.cumsum(seg_caps, 0)], 0)
        total = csum[-1]
        frac = jnp.take_along_axis(
            csum, z_code.astype(jnp.int32), axis=0) / total
    return frac * v_htilde + (1.0 - frac) * v_h


def comparator(v, v_ref, cfg: AnalogConfig, key=None):
    """Clocked comparator: Θ(v − v_ref) with optional input noise."""
    if key is not None and cfg.comparator_noise_v > 0:
        v = v + cfg.comparator_noise_v * jax.random.normal(key, v.shape)
    return (v > v_ref).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Full analog network (mirror of core.mingru.MinimalistNetwork)
# ---------------------------------------------------------------------------


def make_mismatch(key, images: Sequence[LayerImage], cfg: AnalogConfig):
    """Draw per-device capacitor mismatch for every layer (fixed per chip)."""
    out = []
    for i, img in enumerate(images):
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, i), 3)
        K1, N = img.k_rows + 1, img.codes_h.shape[1]
        out.append({
            "caps_h": jnp.abs(1.0 + cfg.mismatch_sigma * jax.random.normal(k1, (K1, N))),
            "caps_z": jnp.abs(1.0 + cfg.mismatch_sigma * jax.random.normal(k2, (K1, N))),
            "segs": jnp.abs(1.0 + cfg.mismatch_sigma * jax.random.normal(
                k3, (cfg.gate_units, N))),
        })
    return out


def analog_forward(images: Sequence[LayerImage], x_seq, cfg: AnalogConfig,
                   mismatch=None, key=None, collect_traces=True,
                   forced_inputs=None):
    """Run the switched-capacitor network on a binary input sequence.

    x_seq: (B, T, K0), entries in {0,1}.  Returns (readout in software model
    units (B, N_last), per-layer traces dict with z/htilde/h/out stacked over
    time in model units) — the paper-Fig.-4 payload.

    ``forced_inputs``: optional list of (B, T, K_li) binary arrays, one per
    layer ≥ 1, substituting the software model's inter-layer activations for
    the analog ones (open-loop / teacher-forced verification).  A comparator
    decision on a state sitting exactly at threshold (|h| ≲ float-eps) is
    noise-determined in any real circuit; forcing isolates each layer so the
    per-layer mapping can be asserted bit-exact, while the closed-loop mode
    measures end-to-end agreement like the paper's Fig. 4.
    """
    B, T, _ = x_seq.shape
    n_layers = len(images)
    v_h = [jnp.full((B, img.codes_h.shape[1]), cfg.v0) for img in images]
    traces = [{"z": [], "htilde": [], "h": [], "out": []} for _ in images]

    for t in range(T):
        x = x_seq[:, t, :]
        for li, img in enumerate(images):
            if forced_inputs is not None and li >= 1:
                x = forced_inputs[li - 1][:, t, :]
            mm = mismatch[li] if mismatch is not None else {}
            kk = (jax.random.fold_in(key, t * n_layers + li)
                  if key is not None else None)

            v_ht = charge_sharing_mvm(x, img.codes_h, img.bias_h_v, cfg,
                                      caps=mm.get("caps_h"))
            v_z = charge_sharing_mvm(x, img.codes_z,
                                     jnp.zeros(img.codes_z.shape[1]), cfg,
                                     caps=mm.get("caps_z"))
            # ADC slope: input LSB = 6α/63 volts matches q6(hard_sigmoid)
            lsb = 6.0 * img.alpha / quant.GATE_UNITS
            z_code = sar_adc(v_z, cfg, lsb_volts=lsb,
                             offset_code=img.adc_offset_code, key=kk)

            v_h[li] = state_update_swap(v_h[li], v_ht, z_code, cfg,
                                        seg_caps=mm.get("segs"))
            x = comparator(
                v_h[li], cfg.v0, cfg,
                key=(jax.random.fold_in(kk, 7) if kk is not None else None))

            if collect_traces:
                traces[li]["htilde"].append((v_ht - cfg.v0) / img.alpha)
                traces[li]["z"].append(z_code.astype(jnp.float32) /
                                       quant.GATE_UNITS)
                traces[li]["h"].append((v_h[li] - cfg.v0) / img.alpha)
                traces[li]["out"].append(x)

    readout = (v_h[-1] - cfg.v0) / images[-1].alpha
    if collect_traces:
        traces = [
            {k: jnp.stack(v, axis=1) for k, v in tr.items()} for tr in traces
        ]
    return readout, traces


# ---------------------------------------------------------------------------
# Energy model (paper §4.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnergyConfig:
    c_sample_f: float = 2.0e-15        # sampling capacitor [F]
    c_switch_f: float = 0.5e-15        # transmission-gate gate cap [F]
    c_line_f_per_row: float = 1.0e-15  # shared-line parasitic per synapse [F]
    v_dd: float = 0.8


def energy_per_step(rows: int, cols: int, n_cores: int,
                    ecfg: EnergyConfig = EnergyConfig(),
                    z_mean: float = 1.0) -> dict:
    """Structural energy estimate per time step (worst case z_mean = 1).

    Counted events per synapse per step (paper §3.1.1–3.1.3): precharge of
    the h̃ and z sampling caps; the 4 shared weight rails driven per row;
    S1/S2 switch toggles; swap switches ∝ z.  The SAR DAC (≪ IMC
    capacitance), event routing (sparse 1 b), digital control and clocking
    are excluded — exactly the paper's accounting.
    """
    n_syn = rows * cols * n_cores
    e_cap = ecfg.c_sample_f * ecfg.v_dd ** 2
    e_sw = ecfg.c_switch_f * ecfg.v_dd ** 2
    e_line = ecfg.c_line_f_per_row * ecfg.v_dd ** 2

    e_precharge = n_syn * 2 * e_cap            # h̃ + z sampling (worst case)
    e_lines = n_syn * 4 * e_line               # 4 weight rails per row
    e_switches = n_syn * (2 + 2) * 2 * e_sw    # S1*/S2* toggle pairs
    e_swap = n_syn * 2 * e_sw * z_mean + n_syn * e_cap * z_mean * 0.5
    total = e_precharge + e_lines + e_switches + e_swap
    return {
        "precharge_J": e_precharge,
        "lines_J": e_lines,
        "switches_J": e_switches,
        "swap_J": e_swap,
        "total_J": total,
        "total_pJ": total * 1e12,
    }
