"""Sequential-MNIST data for the paper's Fig. 5 reproduction.

This container has no network access and no bundled MNIST copy, so by
default we use a *procedurally generated surrogate* with the identical
interface: 784-step 1-D sequences, 10 classes.  Each class is a smooth
random prototype curve (class-specific Fourier coefficients) plus noise and
random temporal warping — hard enough that the quantization LADDER of the
paper (fp32 → quantized → hardware-compatible) is meaningfully resolved,
which is what Fig. 5 measures (relative degradation, not absolute MNIST
accuracy).  DESIGN.md records this substitution.

If a real ``mnist.npz`` (keys x_train/y_train/x_test/y_test) is present at
``data/mnist.npz`` (repo root) or ``$MNIST_NPZ``, it is used instead.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

SEQ_LEN = 784
N_CLASSES = 10


def _mnist_path():
    for p in (os.environ.get("MNIST_NPZ", ""),
              os.path.join(os.path.dirname(__file__), "../../../data/mnist.npz")):
        if p and os.path.exists(p):
            return p
    return None


@dataclasses.dataclass
class SequentialMNISTLike:
    seed: int = 0
    n_train: int = 4096
    n_test: int = 1024
    n_fourier: int = 12
    noise: float = 0.15

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        t = np.linspace(0, 1, SEQ_LEN)
        # class prototypes: random low-frequency Fourier curves in [0, 1]
        self.protos = np.zeros((N_CLASSES, SEQ_LEN), np.float32)
        for c in range(N_CLASSES):
            coef = rng.normal(size=(self.n_fourier, 2)) / np.arange(
                1, self.n_fourier + 1)[:, None]
            curve = sum(coef[k, 0] * np.sin(2 * np.pi * (k + 1) * t)
                        + coef[k, 1] * np.cos(2 * np.pi * (k + 1) * t)
                        for k in range(self.n_fourier))
            curve = (curve - curve.min()) / (np.ptp(curve) + 1e-9)
            self.protos[c] = curve

    def _make(self, n, rng):
        y = rng.integers(0, N_CLASSES, size=(n,))
        # random temporal warp + amplitude jitter + additive noise
        shift = rng.integers(-40, 40, size=(n,))
        amp = rng.uniform(0.7, 1.3, size=(n, 1))
        x = np.stack([np.roll(self.protos[c], s)
                      for c, s in zip(y, shift)]).astype(np.float32)
        x = np.clip(x * amp + self.noise * rng.normal(size=x.shape), 0, 1)
        return x[..., None].astype(np.float32), y.astype(np.int32)

    def splits(self):
        rng = np.random.default_rng(self.seed + 1)
        xtr, ytr = self._make(self.n_train, rng)
        xte, yte = self._make(self.n_test, rng)
        return (xtr, ytr), (xte, yte)


def load_smnist(seed=0, n_train=4096, n_test=1024, binarize=False):
    """Returns ((x_train, y_train), (x_test, y_test)); x: (N, 784, 1)."""
    path = _mnist_path()
    if path:
        z = np.load(path)
        xtr = z["x_train"].reshape(-1, SEQ_LEN, 1).astype(np.float32) / 255.0
        xte = z["x_test"].reshape(-1, SEQ_LEN, 1).astype(np.float32) / 255.0
        tr = (xtr[:n_train], z["y_train"][:n_train].astype(np.int32))
        te = (xte[:n_test], z["y_test"][:n_test].astype(np.int32))
    else:
        tr, te = SequentialMNISTLike(seed=seed, n_train=n_train,
                                     n_test=n_test).splits()
    if binarize:
        tr = ((tr[0] > 0.5).astype(np.float32), tr[1])
        te = ((te[0] > 0.5).astype(np.float32), te[1])
    return tr, te
