"""Deterministic, shardable data pipeline.

``SyntheticLMDataset`` generates structured token streams (orderk-Markov
with per-document seeds) so language-model training has real, learnable
signal without an external corpus — losses decrease, making the end-to-end
examples meaningful rather than noise-fitting.

``ShardedLoader`` handles multi-host sharding the way a production input
pipeline does: each host materializes only its slice of the global batch
(host_id/num_hosts), with step-indexed seeds so restarts resume the stream
deterministically from a checkpointed step — no data-order drift across
failures (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    order: int = 2
    n_modes: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse order-k transition structure: each (mode, prev) maps to a
        # small candidate set — gives ~2-3 bits/token of learnable structure
        self.tables = rng.integers(
            0, self.vocab, size=(self.n_modes, 257, 8)).astype(np.int32)

    def sample(self, batch: int, step: int, host_salt: int = 0) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + host_salt)
        modes = rng.integers(0, self.n_modes, size=(batch,))
        toks = np.empty((batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=(batch,))
        choice = rng.integers(0, 8, size=(batch, self.seq_len))
        noise = rng.random((batch, self.seq_len)) < 0.05
        rand_tok = rng.integers(0, self.vocab, size=(batch, self.seq_len))
        for t in range(self.seq_len):
            prev = toks[:, t] % 257
            nxt = self.tables[modes, prev, choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class ShardedLoader:
    dataset: "SyntheticLMDataset"
    global_batch: int
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for `step` — resume-safe after restart."""
        return self.dataset.sample(self.host_batch, step,
                                   host_salt=self.host_id)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
