from repro.data.pipeline import SyntheticLMDataset, ShardedLoader
from repro.data.smnist import SequentialMNISTLike, load_smnist
