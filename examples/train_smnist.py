"""Paper Fig. 5 reproduction: the 4-phase QAT ladder on sequential data.

Trains the three models of Fig. 5 (fp32 / quantized / hardware-compatible)
via gradual quantization-aware training and prints the accuracy ladder next
to the paper's numbers.

Run:   PYTHONPATH=src python examples/train_smnist.py            (fast)
       PYTHONPATH=src python examples/train_smnist.py --full     (long)

With a real mnist.npz at data/mnist.npz (or $MNIST_NPZ) this runs on real
sequential MNIST; otherwise the procedurally generated surrogate task is
used (DESIGN.md §3 records the substitution — the measured quantity is the
relative degradation down the ladder, as in Fig. 5).
"""
import argparse

from repro.data.smnist import load_smnist
from repro.train.qat import QATConfig, train_qat

PAPER = {"float (phase 0)": 0.981, "quantized (phase 2)": 0.977,
         "hardware (phase 3)": 0.969}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_train = 8192 if args.full else 1024
    stride = 1 if args.full else 8
    (xtr, ytr), (xte, yte) = load_smnist(seed=args.seed, n_train=n_train,
                                         n_test=1024)
    train = (xtr[:, ::stride], ytr)
    test = (xte[:, ::stride], yte)
    dims = (1, 64, 64, 64, 64, 10) if args.full else (1, 48, 48, 10)
    cfg = QATConfig(dims=dims,
                    phase_epochs=(30, 15, 15, 15) if args.full
                    else (12, 8, 8, 8),
                    batch=64, lr=5e-3, seed=args.seed)
    print(f"dims={dims} n_train={n_train} seq_stride={stride}")
    params, results = train_qat(train, test, cfg, verbose=True)

    print("\n=== Fig. 5 ladder (this run vs paper) ===")
    ladder = [("float (phase 0)", results[0]["test_acc"]),
              ("quantized (phase 2)", results[2]["test_acc"]),
              ("hardware (phase 3)", results[3]["test_acc"])]
    base = ladder[0][1]
    for name, acc in ladder:
        print(f"{name:24s} acc={acc:.4f}  drop={base-acc:+.4f}   "
              f"paper={PAPER[name]:.3f} (drop {PAPER['float (phase 0)']-PAPER[name]:+.3f})")


if __name__ == "__main__":
    main()
