"""Quickstart: the paper's full pipeline in one minute on CPU.

1. Build a MINIMALIST network under full hardware constraints (2 b weights,
   6 b biases, binary activations, hard-σ 6 b gate — paper §2).
2. Train it briefly on the sequential-pattern task.
3. Export the trained weights to switched-capacitor circuit quantities
   (capacitor codes, bias-row voltages, ADC presets — paper §3).
4. Replay the circuit simulation and verify it reproduces the software
   model (paper Fig. 4 verification flow).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.analog import AnalogConfig, analog_forward, export_layer
from repro.core.mingru import MinimalistNetwork
from repro.data.smnist import load_smnist
from repro.optim import AdamW


def main():
    print("== 1. hardware-constrained MINIMALIST network ==")
    dims = (1, 32, 32, 10)
    net = MinimalistNetwork(dims, qcfg=quant.QuantConfig.hardware())
    params = net.init(jax.random.PRNGKey(0))
    print(f"dims {dims}, quantization: 2b W / 6b b / Θ outputs / hard-σ 6b z")

    print("== 2. short QAT run (float warm-up -> hardware constraints) ==")
    (xtr, ytr), (xte, yte) = load_smnist(n_train=1024, n_test=256)
    xtr, xte = xtr[:, ::8], xte[:, ::8]  # subsample time for CPU speed
    float_net = MinimalistNetwork(dims, qcfg=quant.QuantConfig.float_baseline())
    opt = AdamW(lr=5e-3, weight_decay=0.0)
    opt_state = opt.init(params)

    def make_step(n):
        @jax.jit
        def step(params, opt_state, xb, yb):
            def loss_fn(p):
                logp = jax.nn.log_softmax(n(p, xb).astype(jnp.float32))
                return -jnp.take_along_axis(logp, yb[:, None], -1).mean()
            loss, g = jax.value_and_grad(loss_fn)(params)
            params, opt_state, _ = opt.update(g, opt_state, params)
            return params, opt_state, loss
        return step

    for phase, n, epochs in (("float", float_net, 8), ("hardware", net, 6)):
        step = make_step(n)
        for epoch in range(epochs):
            for i in range(0, len(xtr), 64):
                params, opt_state, loss = step(
                    params, opt_state, jnp.asarray(xtr[i:i + 64]),
                    jnp.asarray(ytr[i:i + 64]))
        print(f"phase {phase}: final loss {float(loss):.3f}")

    logits = net(params, jnp.asarray(xte))
    acc = (np.argmax(np.asarray(logits), -1) == yte).mean()
    print(f"test accuracy (software, hardware-constrained): {acc:.3f}")

    print("== 3. export to switched-capacitor circuit ==")
    acfg = AnalogConfig()
    images = [export_layer(params[b.name], acfg) for b in net.blocks]
    for li, img in enumerate(images):
        print(f"layer {li}: codes {img.codes_h.shape} (2b), "
              f"alpha {img.alpha*1e3:.2f} mV/unit, "
              f"ADC offsets {img.adc_offset_code[:4]}...")

    print("== 4. mixed-signal verification (Fig. 4 flow) ==")
    xb = jnp.asarray((xte[:64] > 0.5).astype(np.float32))
    sw_logits = net(params, xb)
    readout, _ = analog_forward(images, xb, acfg, collect_traces=False)
    agree = (np.argmax(np.asarray(sw_logits), -1)
             == np.argmax(np.asarray(readout), -1)).mean()
    print(f"software vs circuit prediction agreement: {agree:.3f}")
    assert agree > 0.9
    print("OK — the circuit reproduces the trained model.")


if __name__ == "__main__":
    main()
