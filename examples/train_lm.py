"""End-to-end driver: train the ~100M-parameter MINIMALIST-LM.

The paper's minGRU technique as the time-mixing layer of a 12-layer,
d_model=1024 language model (~101 M params with the tied embedding), trained
on the structured synthetic token stream with the production training loop
(AdamW + cosine, grad clipping, async checkpointing, crash recovery,
straggler monitoring).

    PYTHONPATH=src python examples/train_lm.py --steps 300

On a TPU pod this exact script scales out via the mesh in
repro.launch.mesh (the dry-run proves the sharded lowering); on the CPU
container expect ~10-60 s/step at the default batch — pass --steps 5 for a
quick verification, or --hardware to train under the full paper constraints
(2 b weights / binary activations / 6 b gate).
"""
import argparse

from repro.configs import get_config
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--hardware", action="store_true",
                    help="full paper constraints (QAT mode)")
    args = ap.parse_args()

    arch = "minimalist-lm-100m" + ("-hw" if args.hardware else "")
    cfg = get_config(arch)
    print(f"arch={cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"params≈{cfg.param_count()/1e6:.0f}M "
          f"(minGRU time mixing, quant={cfg.mingru_quant})")
    argv = ["--arch", arch,
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--seq", str(args.seq), "--ckpt-dir", "/tmp/minimalist_lm_ckpt"]
    train_main(argv)


if __name__ == "__main__":
    main()
