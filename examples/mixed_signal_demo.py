"""Paper Fig. 4 demo: overlay software-model and circuit-simulation traces.

Prints ASCII trace overlays of z, h̃ and h for one unit over time — the
software (hardware-constrained) model vs the behavioral switched-capacitor
simulation — plus agreement statistics, with and without component
non-idealities.

    PYTHONPATH=src python examples/mixed_signal_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.analog import (AnalogConfig, analog_forward, export_layer,
                               make_mismatch)
from repro.core.mingru import MinimalistNetwork


def ascii_trace(name, sw, an, lo, hi, width=64):
    """Two-row ASCII overlay: '·' software, 'x' analog, '*' overlap."""
    def quantize(v):
        return np.clip(((v - lo) / (hi - lo + 1e-9) * 7).astype(int), 0, 7)

    qs, qa = quantize(np.asarray(sw)), quantize(np.asarray(an))
    rows = []
    for level in range(7, -1, -1):
        line = []
        for t in range(min(len(qs), width)):
            s, a = qs[t] == level, qa[t] == level
            line.append("*" if s and a else "·" if s else "x" if a else " ")
        rows.append("".join(line))
    print(f"--- {name} (·=software x=circuit *=both) ---")
    for r in rows:
        print("|" + r + "|")


def main():
    dims = (6, 16, 16, 5)
    net = MinimalistNetwork(dims, qcfg=quant.QuantConfig.hardware())
    key = jax.random.PRNGKey(7)
    params = net.init(key)
    B, T = 1, 64
    x = (jax.random.uniform(jax.random.fold_in(key, 1), (B, T, dims[0]))
         > 0.6).astype(jnp.float32)

    logits, sw = net(params, x, collect_traces=True)
    acfg = AnalogConfig()
    images = [export_layer(params[b.name], acfg) for b in net.blocks]
    _, an = analog_forward(images, x, acfg)

    unit = 3
    layer = "block1"
    li = 1
    for sig, (lo, hi) in (("z", (0, 1)), ("htilde", (-3, 3)), ("h", (-3, 3))):
        ascii_trace(f"{layer}.{sig}[unit {unit}]",
                    np.asarray(sw[layer][sig])[0, :, unit],
                    np.asarray(an[li][sig])[0, :, unit], lo, hi)

    z_match = np.mean([(np.asarray(sw[b.name]["z"])
                        == np.asarray(an[i]["z"])).mean()
                       for i, b in enumerate(net.blocks)])
    print(f"\nz-code agreement (ideal circuit): {z_match:.4f}")

    acfg_mm = AnalogConfig(mismatch_sigma=0.01, comparator_noise_v=0.002)
    mm = make_mismatch(jax.random.PRNGKey(2), images, acfg_mm)
    _, an_mm = analog_forward(images, x, acfg_mm, mismatch=mm,
                              key=jax.random.PRNGKey(3))
    z_match_mm = np.mean([(np.asarray(sw[b.name]["z"])
                           == np.asarray(an_mm[i]["z"])).mean()
                          for i, b in enumerate(net.blocks)])
    print(f"z-code agreement (1% mismatch + comparator noise): "
          f"{z_match_mm:.4f}")


if __name__ == "__main__":
    main()
