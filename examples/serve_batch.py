"""Batched serving example: prefill + greedy decode with per-layer caches.

Serves three different state-management regimes through the same API:
  * smollm-360m      — GQA KV cache (grows with context)
  * falcon-mamba-7b  — O(1) SSM state (the long-context serving case)
  * minimalist-lm    — the paper's minGRU: O(1) analog-state inference,
                       which is exactly the edge-serving story of the paper

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import build_model


def main():
    for arch in ("smollm-360m", "falcon-mamba-7b", "minimalist-lm-360m"):
        cfg = get_config(arch + "-smoke")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, P, G = 4, 16, 24
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                     cfg.vocab)
        t0 = time.time()
        out = generate(model, params, prompts, max_len=P + G + 1,
                       gen_tokens=G)
        jax.block_until_ready(out)
        dt = time.time() - t0
        print(f"{arch:24s} batch={B} prompt={P} gen={G} "
              f"-> {B*(P+G)/dt:7.1f} tok/s  sample={np.asarray(out[0,:8])}")


if __name__ == "__main__":
    main()
