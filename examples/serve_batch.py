"""Batched serving example: continuous batching vs the static-batch loop.

Serves three different state-management regimes through the same
StepModel protocol:
  * smollm-360m      — GQA KV cache (grows with context; per-slot pos)
  * falcon-mamba-7b  — O(1) SSM state (the long-context serving case)
  * minimalist-lm    — the paper's minGRU: O(1) analog-state inference,
                       which is exactly the edge-serving story of the paper

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import SamplingParams, ServeConfig, get_config
from repro.launch.serve import build_engine, generate
from repro.models import build_model


def main():
    for arch in ("smollm-360m", "falcon-mamba-7b", "minimalist-lm-360m"):
        cfg = get_config(arch + "-smoke")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, P, G = 4, 16, 24
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, cfg.vocab, size=(B, P))

        # static-batch baseline: every row locked for P + G steps
        t0 = time.time()
        out = generate(model, params, jax.numpy.asarray(prompts, "int32"),
                       max_len=P + G + 1, gen_tokens=G)
        jax.block_until_ready(out)
        dt_base = time.time() - t0

        # continuous batching: mixed lengths, slots recycle as requests
        # end; every other request samples (temperature/top-k/top-p) with
        # its own seed — greedy and sampled share ONE compiled step, and
        # each sampled stream is reproducible regardless of co-batching
        eng = build_engine(model, params,
                           ServeConfig(slots=B, max_len=2 * (P + G),
                                       prefill_chunk=P))
        t0 = time.time()
        for i in range(2 * B):           # twice the requests, same slots
            plen = int(rng.integers(P // 2, P + 1))
            sampling = SamplingParams(temperature=0.8, top_k=50,
                                      top_p=0.95, seed=i) if i % 2 else None
            eng.submit(rng.integers(0, cfg.vocab, size=plen),
                       max_new_tokens=int(rng.integers(G // 2, G + 1)),
                       sampling=sampling)
        done = eng.run()
        dt_eng = time.time() - t0
        print(f"{arch:24s} baseline {B*(P+G)/dt_base:7.1f} tok/s | "
              f"engine {eng.n_emitted} tok from {len(done)} reqs in "
              f"{dt_eng:.1f}s, util {eng.utilization:.2f}, "
              f"sample={done[0].tokens[:8]}")


if __name__ == "__main__":
    main()
